"""Sharded, async, atomic checkpointing (no orbax in the container).

Layout:
    <dir>/step_000123.tmp-<nonce>/   shard files being written
    <dir>/step_000123/               atomically renamed when complete
        meta.json                    tree structure + shapes + step
        arrays.npz                   flattened leaves (per-host shard)

Fault-tolerance properties:
  * atomic rename — a crash mid-save never corrupts the latest checkpoint;
  * async — `save()` snapshots to host RAM (device_get) and writes on a
    background thread; training continues immediately;
  * restore-with-resharding — `restore()` rebuilds leaves then applies the
    CURRENT mesh's NamedShardings, so a 16-way checkpoint restores onto any
    surviving topology (elastic restart);
  * keeps the newest `keep` checkpoints, deletes older ones only AFTER a
    newer one is durable.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import uuid
from typing import Any

import jax
import numpy as np


@dataclasses.dataclass
class CheckpointSpec:
    directory: str
    keep: int = 3


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, spec: CheckpointSpec):
        self.spec = spec
        os.makedirs(spec.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot now, write in the background (async checkpointing)."""
        self.wait()  # only one in-flight save
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def write():
            try:
                self._write(step, host_tree)
            except Exception as e:                      # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any) -> None:
        d = self.spec.directory
        final = os.path.join(d, f"step_{step:08d}")
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        leaves, treedef = _flatten(host_tree)
        # npz can't hold ml_dtypes (bf16) — widen on disk, restore() narrows.
        leaves = [np.asarray(l, np.float32) if str(np.asarray(l).dtype) == "bfloat16"
                  else np.asarray(l) for l in leaves]
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(leaves)})
        meta = {
            "step": step,
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "time": time.time(),
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(tmp)       # concurrent writer already won
        else:
            os.replace(tmp, final)   # atomic publish
        self._gc()

    def _gc(self) -> None:
        d = self.spec.directory
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(d)
            if n.startswith("step_") and ".tmp" not in n)
        for s in steps[: -self.spec.keep]:
            shutil.rmtree(os.path.join(d, f"step_{s:08d}"), ignore_errors=True)
        # orphaned tmp dirs from crashes
        for n in os.listdir(d):
            if ".tmp-" in n:
                age = time.time() - os.path.getmtime(os.path.join(d, n))
                if age > 3600:
                    shutil.rmtree(os.path.join(d, n), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Rebuild `like`-structured tree; apply `shardings` if given
        (cross-topology reshard: the checkpoint doesn't care what mesh wrote
        it)."""
        d = os.path.join(self.spec.directory, f"step_{step:08d}")
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        n = len(leaves_like)
        loaded = [data[f"leaf_{i}"] for i in range(n)]
        restored = []
        for arr, ref in zip(loaded, leaves_like):
            a = np.asarray(arr)
            want = np.dtype(jax.numpy.asarray(ref).dtype
                            if not hasattr(ref, "dtype") else ref.dtype)
            if str(want) == "bfloat16":
                a = a.astype("float32").astype(jax.numpy.bfloat16)
            else:
                a = a.astype(want)
            restored.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, restored)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree
