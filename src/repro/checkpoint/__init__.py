from .checkpointer import Checkpointer, CheckpointSpec, latest_step

__all__ = ["Checkpointer", "CheckpointSpec", "latest_step"]
