"""Deterministic fault injection for the schedule/serve stack.

A :class:`FaultPlan` is a set of keyed injection sites that raise, corrupt
or delay when a guarded code path reaches them — so every recovery rung of
the degradation ladder (see ``docs/robustness.md``) is testable without
flaky real failures.  The plan is **clock-free and deterministic**: a site
fires on its first ``times`` activations (in program order) and then
disarms; nothing depends on wall time, thread timing or randomness.

Injection sites (one per ladder rung):

======================  ====================================================
``kernel_compile``      fused ``branch_gemm`` route at capture time (and the
                        wrapper's Pallas launch for direct callers)
``grouped_gemm_route``  ragged grouped-GEMM route at capture time (and the
                        wrapper's Pallas launch)
``calibration_measure`` the profiling inference behind measured calibration
``calib_disk_read``     calibration disk-tier load (corrupt mode mangles the
                        JSON payload before parsing)
``calib_disk_write``    calibration disk-tier store (corrupt mode mangles
                        the payload; raise mode aborts before publish)
``plan_validate``       wave-schedule validation at the top of ``capture()``
``decode_step``         the serving engine's jitted decode step (corrupt
                        mode poisons one slot's logits — a poisoned request)
``admission_enqueue``   the serving admission tier's enqueue path (raise
                        mode sheds the incoming request with provenance)
``slot_preempt``        the engine's priority-preemption decision (raise
                        mode skips the preemption; the victim keeps running)
``deadline_check``      the engine's per-tick deadline sweep (raise mode
                        skips ONE tick of expiry)
``page_alloc``          the paged-KV pool's page grant (admission or
                        decode-time growth; raise mode becomes page
                        pressure — requeue/shed, never a crash)
``block_table_build``   assembly of the device block-table for a paged
                        decode tick (raise mode takes the tick down the
                        dense-gather fallback rung)
``page_release``        page release on request eviction (raise mode LEAKS
                        the pages — counted and visible in ``health()`` —
                        instead of corrupting the free list)
======================  ====================================================

Activation is either **per-session** (``SessionConfig(fault_plan=...)``,
or ``InferenceEngine(fault_plan=...)``) or **process-wide** for chaos CI
via the ``REPRO_FAULT_PLAN`` environment variable / :func:`activate`::

    REPRO_FAULT_PLAN="calibration_measure:raise:-1" pytest ...

Env grammar: ``site[:mode[:times[:arg]]]`` joined by ``;`` or ``,`` —
``mode`` one of ``raise`` / ``corrupt`` / ``delay`` (default ``raise``),
``times`` an int (``-1`` = every activation; default ``-1`` so a chaos run
keeps the fault live), ``arg`` a float whose meaning is per-mode (delay
seconds, or the row index corrupt mode poisons in an array payload).

This module is dependency-free (no jax import at module level) so the
kernel wrappers and the core compiler can both reach it without cycles.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Iterable

ENV_VAR = "REPRO_FAULT_PLAN"

SITES = (
    "kernel_compile",
    "grouped_gemm_route",
    "calibration_measure",
    "calib_disk_read",
    "calib_disk_write",
    "plan_validate",
    "decode_step",
    "admission_enqueue",
    "slot_preempt",
    "deadline_check",
    "page_alloc",
    "block_table_build",
    "page_release",
)

MODES = ("raise", "corrupt", "delay")


class FaultInjected(RuntimeError):
    """Raised by a ``raise``-mode site.  Carries the site name so recovery
    paths and provenance records can attribute the failure."""

    def __init__(self, site: str):
        super().__init__(f"injected fault at site {site!r}")
        self.site = site


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed site: what happens there and how many times."""

    site: str
    mode: str = "raise"
    times: int = -1          # activations that fire; -1 = every activation
    arg: float = 0.0         # delay seconds / corrupt row index

    def __post_init__(self) -> None:
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites: {', '.join(SITES)}")
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; "
                             f"modes: {', '.join(MODES)}")


def _corrupt(payload: Any, arg: float) -> Any:
    """Deterministically mangle a payload the way real corruption would:
    strings/bytes are truncated mid-token (a torn write), arrays get one
    row (``int(arg)``) of NaNs (a poisoned batch slot), everything else is
    replaced by an unparseable sentinel."""
    if isinstance(payload, str):
        return payload[: max(1, len(payload) // 2)] + "\x00~CORRUPT~"
    if isinstance(payload, (bytes, bytearray)):
        return bytes(payload[: max(1, len(payload) // 2)]) + b"\x00~CORRUPT~"
    if hasattr(payload, "at") and getattr(payload, "ndim", 0) >= 1:
        # jax array: poison one row, leave the rest of the batch intact
        return payload.at[int(arg)].set(float("nan"))
    return {"__corrupt__": True}


class FaultPlan:
    """Keyed, counted injection sites.  Mutable state is only the per-site
    activation counters — specs are frozen, so replaying the same program
    against the same plan fires identically every run."""

    def __init__(self, specs: Iterable[FaultSpec] = ()):
        self.specs: dict[str, FaultSpec] = {}
        for s in specs:
            if s.site in self.specs:
                raise ValueError(f"duplicate spec for site {s.site!r}")
            self.specs[s.site] = s
        self.activations: dict[str, int] = {s: 0 for s in self.specs}
        self.fired: dict[str, int] = {s: 0 for s in self.specs}
        # injectable clock for delay mode — the default is a no-op so plans
        # stay clock-free unless a harness explicitly wires a sleeper in
        self.sleep = lambda seconds: None

    # -- construction --------------------------------------------------------
    @classmethod
    def single(cls, site: str, mode: str = "raise", times: int = 1,
               arg: float = 0.0) -> "FaultPlan":
        return cls([FaultSpec(site=site, mode=mode, times=times, arg=arg)])

    @classmethod
    def parse(cls, text: str) -> "FaultPlan":
        """Parse the ``REPRO_FAULT_PLAN`` grammar (see module docstring)."""
        specs = []
        for token in text.replace(",", ";").split(";"):
            token = token.strip()
            if not token:
                continue
            parts = token.split(":")
            spec = FaultSpec(
                site=parts[0],
                mode=parts[1] if len(parts) > 1 and parts[1] else "raise",
                times=int(parts[2]) if len(parts) > 2 and parts[2] else -1,
                arg=float(parts[3]) if len(parts) > 3 and parts[3] else 0.0,
            )
            specs.append(spec)
        return cls(specs)

    # -- firing --------------------------------------------------------------
    def armed(self, site: str) -> bool:
        spec = self.specs.get(site)
        if spec is None:
            return False
        return spec.times < 0 or self.activations[site] < spec.times

    def fire(self, site: str, payload: Any = None) -> Any:
        """Activate ``site``: raise (``raise`` mode), return a corrupted
        ``payload`` (``corrupt``), or call the injected sleeper and pass the
        payload through (``delay``).  Disarmed / unkeyed sites are free:
        the payload passes through untouched and nothing is counted."""
        if not self.armed(site):
            return payload
        spec = self.specs[site]
        self.activations[site] += 1
        self.fired[site] += 1
        if spec.mode == "raise":
            raise FaultInjected(site)
        if spec.mode == "delay":
            self.sleep(spec.arg)
            return payload
        return _corrupt(payload, spec.arg)

    def describe(self) -> dict[str, dict[str, Any]]:
        return {
            site: {"mode": s.mode, "times": s.times, "arg": s.arg,
                   "fired": self.fired[site]}
            for site, s in self.specs.items()
        }

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan({', '.join(self.specs) or 'empty'})"


# =========================================================================
# Process-wide activation (chaos CI / direct kernel-wrapper callers)
# =========================================================================

_ACTIVE: FaultPlan | None = None
_ENV_CACHE: tuple[str, FaultPlan] | None = None


def get_active() -> FaultPlan | None:
    """The process-wide plan: an explicit :func:`activate` plan wins, else
    ``$REPRO_FAULT_PLAN`` is parsed (cached per env-string so the fault-free
    hot path costs one dict lookup)."""
    if _ACTIVE is not None:
        return _ACTIVE
    text = os.environ.get(ENV_VAR)
    if not text:
        return None
    global _ENV_CACHE
    if _ENV_CACHE is None or _ENV_CACHE[0] != text:
        _ENV_CACHE = (text, FaultPlan.parse(text))
    return _ENV_CACHE[1]


class activate:
    """Context manager installing a process-wide plan (overrides the env)::

        with faults.activate(FaultPlan.single("kernel_compile")):
            ...
    """

    def __init__(self, plan: FaultPlan | None):
        self.plan = plan
        self._prev: FaultPlan | None = None

    def __enter__(self) -> FaultPlan | None:
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self.plan
        return self.plan

    def __exit__(self, *exc: Any) -> None:
        global _ACTIVE
        _ACTIVE = self._prev


def maybe_fire(site: str, payload: Any = None) -> Any:
    """Fire ``site`` on the process-wide plan, if any — the entry point for
    layers with no session in scope (the kernel wrappers)."""
    plan = get_active()
    if plan is None:
        return payload
    return plan.fire(site, payload)
