"""Fault tolerance primitives for 1000+-node runs.

Deterministic, dependency-free implementations of the control-plane logic
(the data plane — checkpoint/restore/reshard — lives in repro.checkpoint):

  * :class:`HeartbeatMonitor` — per-host liveness ledger;
  * :class:`FailureDetector`  — ϕ-accrual-lite detector over heartbeat gaps;
  * :class:`StragglerDetector`— step-time outlier detection (μ+kσ) with a
    mitigation decision (rebalance data / evict host);
  * :class:`ElasticController` — failure → new mesh shape → restore plan
    (which checkpoint, how to re-partition data, new mesh axes).

All classes take explicit clocks so tests drive them deterministically.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Callable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_times: list[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, hosts: list[int], now: Callable[[], float]):
        self._now = now
        self.hosts = {h: HostState(h, now()) for h in hosts}

    def beat(self, host_id: int, step_time: float | None = None) -> None:
        st = self.hosts[host_id]
        st.last_heartbeat = self._now()
        if step_time is not None:
            st.step_times.append(step_time)
            if len(st.step_times) > 64:
                st.step_times.pop(0)

    def silence(self, host_id: int) -> float:
        return self._now() - self.hosts[host_id].last_heartbeat


class FailureDetector:
    """Declare a host dead when its heartbeat gap exceeds
    mean + k·stdev of its own recent gaps (ϕ-accrual simplification),
    floored at ``min_timeout``."""

    def __init__(self, monitor: HeartbeatMonitor, k: float = 6.0,
                 min_timeout: float = 30.0):
        self.monitor = monitor
        self.k = k
        self.min_timeout = min_timeout
        self._gaps: dict[int, list[float]] = {h: [] for h in monitor.hosts}
        self._last: dict[int, float] = {
            h: st.last_heartbeat for h, st in monitor.hosts.items()}

    def observe(self) -> None:
        for h, st in self.monitor.hosts.items():
            if st.last_heartbeat > self._last[h]:
                self._gaps[h].append(st.last_heartbeat - self._last[h])
                self._last[h] = st.last_heartbeat
                if len(self._gaps[h]) > 128:
                    self._gaps[h].pop(0)

    def dead_hosts(self) -> list[int]:
        out = []
        for h, st in self.monitor.hosts.items():
            if not st.alive:
                out.append(h)
                continue
            gaps = self._gaps[h]
            mu = statistics.mean(gaps) if gaps else self.min_timeout
            sd = statistics.pstdev(gaps) if len(gaps) > 1 else mu / 2
            threshold = max(self.min_timeout, mu + self.k * sd)
            if self.monitor.silence(h) > threshold:
                st.alive = False
                out.append(h)
        return out


class StragglerDetector:
    """Flag hosts whose recent mean step time exceeds the fleet median by
    k robust deviations (median/MAD — a straggler must not inflate its own
    threshold, which μ/σ statistics allow).

    Mitigation ladder (returned as the decision string):
      1 "rebalance"  — shave the straggler's data shard (first offence);
      2 "evict"      — treat as failed → elastic rescale (repeat offender).
    """

    def __init__(self, k: float = 3.0, min_samples: int = 8,
                 min_rel_dev: float = 0.05):
        self.k = k
        self.min_samples = min_samples
        self.min_rel_dev = min_rel_dev
        self.offences: dict[int, int] = {}

    def check(self, monitor: HeartbeatMonitor) -> dict[int, str]:
        means = {}
        for h, st in monitor.hosts.items():
            if st.alive and len(st.step_times) >= self.min_samples:
                means[h] = statistics.mean(st.step_times[-self.min_samples:])
        if len(means) < 3:
            return {}
        med = statistics.median(means.values())
        mad = statistics.median(abs(m - med) for m in means.values())
        dev = max(1.4826 * mad, self.min_rel_dev * med, 1e-9)
        decisions = {}
        for h, m in means.items():
            if m > med + self.k * dev:
                n = self.offences.get(h, 0) + 1
                self.offences[h] = n
                decisions[h] = "rebalance" if n < 3 else "evict"
        return decisions


@dataclasses.dataclass
class RestorePlan:
    checkpoint_step: int | None
    new_hosts: list[int]
    mesh_shape: tuple[int, ...]
    data_partition: dict[int, int]   # host_id -> data shard index


class ElasticController:
    """Failure → new topology decision.

    Given the surviving hosts and the per-pod geometry, pick the largest
    (data × model) mesh that the survivors can form (model axis preserved —
    TP degree is baked into the compiled program; data axis shrinks), and
    emit a restore plan pointing at the newest durable checkpoint.
    """

    def __init__(self, hosts_per_pod: int, model_axis: int):
        self.hosts_per_pod = hosts_per_pod
        self.model_axis = model_axis

    def plan(self, alive_hosts: list[int], checkpoint_step: int | None) -> RestorePlan:
        alive = sorted(alive_hosts)
        if not alive:
            raise RuntimeError("no survivors — cannot form any mesh")
        # keep whole model-parallel groups only
        usable = len(alive)
        data_axis = max(1, usable)  # hosts map 1:1 to data-parallel rows here
        # power-of-two data axis keeps collectives ring-friendly
        data_axis = 2 ** int(math.log2(data_axis))
        hosts = alive[:data_axis]
        return RestorePlan(
            checkpoint_step=checkpoint_step,
            new_hosts=hosts,
            mesh_shape=(data_axis, self.model_axis),
            data_partition={h: i for i, h in enumerate(hosts)},
        )
