"""Graceful-degradation primitives: the fallback-chain bookkeeping shared by
the compiler (``core/capture.py``, ``core/session.py``) and the serving
engine.

The philosophy (Nimble's, and this repo's differential harness): every
fused/compiled fast path has a slower-but-correct rung below it, down to
per-op sequential execution as the semantic ground truth.  A degradation is
never silent — each one is recorded as a structured :class:`Degradation`
event (surfaced through ``CompiledModel.explain()["degraded"]`` and
``Session.cache_stats()``) and announced once via a
:class:`DegradationWarning`.

The fault-free path pays only an exception handler per guarded stage —
nothing here runs unless something actually failed.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable


class DegradationWarning(UserWarning):
    """Category for "we kept serving, but on a slower path" warnings, so
    deployments can route them to structured logs (and tests can assert on
    exactly one being emitted)."""


@dataclasses.dataclass(frozen=True)
class Degradation:
    """One recorded fallback: which ladder site tripped, what the recovery
    action was (``from->to``), and why."""

    site: str
    action: str
    reason: str

    def as_dict(self) -> dict[str, str]:
        return dataclasses.asdict(self)


class DegradationLog:
    """Append-only event list with counters — cheap enough to attach to
    every ``CapturedGraph`` / ``Session`` unconditionally."""

    def __init__(self) -> None:
        self.events: list[Degradation] = []

    def note(self, site: str, action: str, reason: str,
             warn: bool = False) -> Degradation:
        d = Degradation(site=site, action=action, reason=reason)
        self.events.append(d)
        if warn:
            warnings.warn(
                f"degraded [{site}] {action}: {reason}", DegradationWarning,
                stacklevel=3)
        return d

    def count(self, site: str | None = None) -> int:
        if site is None:
            return len(self.events)
        return sum(1 for e in self.events if e.site == site)

    def extend(self, other: "DegradationLog") -> None:
        self.events.extend(other.events)

    def as_dicts(self) -> list[dict[str, str]]:
        return [e.as_dict() for e in self.events]

    def __len__(self) -> int:
        return len(self.events)


# =========================================================================
# Process-wide kernel-fallback log
# =========================================================================
#
# The kernel wrappers (kernels/*/ops.py) have no Session in scope — they are
# called from inside jit traces by whoever composed the model.  Their route
# decisions (off-lattice shapes, Pallas launch failures) used to be silent;
# they now land here: counted always, warned once per site per process so a
# serving loop cannot flood the log.  Notes fire at *trace* time, so each
# count is one route decision (a compiled program keeps its route), not one
# execution.

_KERNEL_LOG = DegradationLog()
_KERNEL_WARNED: set[str] = set()


def kernel_log() -> DegradationLog:
    """The process-wide :class:`DegradationLog` for session-less kernel
    wrappers.  ``kernel_log().count("decode_attention")`` is the counter
    the PR 6 ladder promises for every fallback."""
    return _KERNEL_LOG


def note_kernel_fallback(site: str, action: str, reason: str) -> Degradation:
    """Record a kernel-wrapper fallback: always counted on
    :func:`kernel_log`, announced via :class:`DegradationWarning` only on
    the first event per site (per process)."""
    warn = site not in _KERNEL_WARNED
    _KERNEL_WARNED.add(site)
    return _KERNEL_LOG.note(site, action, reason, warn=warn)


def reset_kernel_log() -> None:
    """Test hook: drop recorded kernel-fallback events and re-arm the
    once-per-site warning."""
    _KERNEL_LOG.events.clear()
    _KERNEL_WARNED.clear()


def retry_with_backoff(
    fn: Callable[[], Any],
    retries: int = 2,
    base_delay_s: float = 0.0,
    sleep: Callable[[float], None] = time.sleep,
    on_retry: Callable[[int, BaseException], None] | None = None,
) -> Any:
    """Run ``fn`` with up to ``retries`` retries and doubling backoff.

    Bounded and clock-injectable: ``sleep`` defaults to ``time.sleep`` but
    tests (and the default ``SessionConfig.calib_backoff_s=0``) keep it a
    no-op, so retry behavior is deterministic.  ``on_retry(attempt, exc)``
    fires before each re-attempt (the caller's counter hook).  The last
    failure propagates unchanged once the budget is exhausted — the caller
    owns the next rung of the ladder.
    """
    delay = base_delay_s
    for attempt in range(retries + 1):
        try:
            return fn()
        except Exception as exc:
            if attempt == retries:
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            if delay > 0:
                sleep(delay)
                delay *= 2
