from .fault_tolerance import (
    ElasticController,
    FailureDetector,
    HeartbeatMonitor,
    StragglerDetector,
)

__all__ = ["ElasticController", "FailureDetector", "HeartbeatMonitor",
           "StragglerDetector"]
