from .fault_tolerance import (
    ElasticController,
    FailureDetector,
    HeartbeatMonitor,
    StragglerDetector,
)
from .faults import FaultInjected, FaultPlan, FaultSpec, activate, maybe_fire
from .guard import (
    Degradation,
    DegradationLog,
    DegradationWarning,
    retry_with_backoff,
)

__all__ = ["ElasticController", "FailureDetector", "HeartbeatMonitor",
           "StragglerDetector",
           "FaultInjected", "FaultPlan", "FaultSpec", "activate",
           "maybe_fire",
           "Degradation", "DegradationLog", "DegradationWarning",
           "retry_with_backoff"]
