"""Gradient compression with error feedback (cross-pod bandwidth saver).

Modes (ParallelConfig.grad_compression):
  * "int8" — per-tensor int8 quantization before the dp all-reduce
    (4× traffic), residual carried to the next step;
  * "topk" — Deep Gradient Compression-style magnitude sparsification
    with momentum-free error feedback.

The all-reduce itself happens via GSPMD (sharded grads); these hooks
transform the gradient pytree inside the train step and keep the error
state alongside the optimizer state.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any   # residual pytree (fp32), zeros when compression off


def init_compression(params, mode: str) -> CompressionState:
    if mode == "none":
        return CompressionState(error=None)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return CompressionState(error=jax.tree_util.tree_map(zeros, params))


def _int8_roundtrip(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127)
    return q * scale


def _topk_mask(g, k_frac: float = 0.01):
    flat = jnp.abs(g.reshape(-1))
    k = max(1, int(flat.size * k_frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_grads(grads, state: CompressionState, mode: str):
    """Returns (compressed_grads, new_state).  Error feedback: the part of
    the gradient destroyed by compression is added back next step."""
    if mode == "none" or state.error is None:
        return grads, state

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        if mode == "int8":
            sent = _int8_roundtrip(gf)
        elif mode == "topk":
            sent = _topk_mask(gf)
        else:
            raise ValueError(f"unknown compression mode {mode}")
        return sent.astype(g.dtype), gf - sent

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, CompressionState(error=new_e)
