"""AdamW, hand-rolled (no optax in the container).

State: fp32 first/second moments + step counter.  Sharded the same as the
params (FSDP/ZeRO-3: the moments inherit the param sharding, so optimizer
memory scales down with the dp axes).  Global-norm clipping included —
it is the one cross-param reduction in the update and shows up in the
dry-run's collective schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array       # int32 scalar
    mu: Any               # pytree like params, fp32
    nu: Any               # pytree like params, fp32


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
