"""LR schedules: cosine w/ warmup, and WSD (warmup-stable-decay — MiniCPM)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, base_lr: float, warmup: int, stable: int, decay: int,
                 min_ratio: float = 0.01):
    """MiniCPM's warmup-stable-decay: linear warmup, flat, exp decay tail."""
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    in_decay = step > (warmup + stable)
    t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
    dec = base_lr * (min_ratio ** t)
    return jnp.where(step < warmup, warm, jnp.where(in_decay, dec, base_lr))
