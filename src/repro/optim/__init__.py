from .adamw import AdamWState, adamw_init, adamw_update
from .schedule import cosine_schedule, wsd_schedule
from .compression import compress_grads, init_compression, CompressionState

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "wsd_schedule",
           "compress_grads", "init_compression", "CompressionState"]
