"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

For multi-pod runs the ``pod`` axis can carry pipeline stages instead of
data parallelism (``ParallelConfig.pod_axis_role="pipeline"``): each pod
holds a contiguous slice of layers; microbatches stream through with
``ppermute`` hand-offs.  Implemented with ``shard_map`` so the schedule is
explicit (no reliance on GSPMD inferring the pipeline).

This module is exercised by tests on a small host-device mesh and wired as
a launcher option; the default dry-run path keeps pods data-parallel
(DESIGN.md §6).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map


def pipeline_apply(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,            # params with leading [n_stages, layers_per_stage, ...]
    x: jax.Array,                 # [n_micro, mb, ...] microbatched input
    mesh: Mesh,
    axis: str = "pod",
) -> jax.Array:
    """Run ``layer_fn`` stacks as a GPipe pipeline over ``axis``.

    stage s applies its layer slice to microbatch m at step t = s + m;
    total steps = n_stages + n_micro - 1.  Hand-off via ppermute ring.
    """
    n_stages = mesh.shape[axis]

    def stage_fn(p_stage, x_all):
        # p_stage arrives [1, layers_per_stage, ...] (stage axis sharded to
        # local size 1) — drop the stage dim.
        # x_all: [n_micro, mb, ...] microbatches (replicated across axis)
        p_stage = jax.tree_util.tree_map(lambda a: a[0], p_stage)
        sid = jax.lax.axis_index(axis)
        n_micro = x_all.shape[0]
        steps = n_stages + n_micro - 1

        def apply_stack(h):
            def body(h, p_l):
                return layer_fn(p_l, h), None
            h, _ = jax.lax.scan(body, h, p_stage)
            return h

        def step(carry, t):
            buf, outs = carry                       # buf: [mb, ...] in-flight
            m = t - sid                             # microbatch index at stage
            active = (m >= 0) & (m < n_micro)
            # stage 0 ingests microbatch t; others use the handed-off buf
            inject = x_all[jnp.clip(t, 0, n_micro - 1)]
            h_in = jnp.where(sid == 0, inject, buf)
            h_out = jnp.where(active, apply_stack(h_in), h_in)
            # last stage records its finished microbatch
            outs = jax.lax.cond(
                active & (sid == n_stages - 1),
                lambda o: o.at[jnp.clip(m, 0, n_micro - 1)].set(h_out),
                lambda o: o,
                outs)
            # hand off to next stage
            buf_next = jax.lax.ppermute(
                h_out, axis, [(j, (j + 1) % n_stages) for j in range(n_stages)])
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(x_all[0])
        outs0 = jnp.zeros_like(x_all)
        (buf, outs), _ = jax.lax.scan(step, (buf0, outs0), jnp.arange(steps))
        # every stage's `outs` is only valid on the last stage; broadcast it
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    in_specs = (P(axis), P())       # params stage-sharded; x replicated
    out_specs = P()
    fn = shard_map(stage_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs)
    return fn(stage_params, x)


def split_microbatches(x: jax.Array, n_micro: int) -> jax.Array:
    b = x.shape[0]
    assert b % n_micro == 0
    return x.reshape(n_micro, b // n_micro, *x.shape[1:])
