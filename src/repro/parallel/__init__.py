from .sharding import (
    activation_rules,
    batch_specs,
    cache_specs,
    param_shardings,
    safe_spec,
)

__all__ = ["activation_rules", "batch_specs", "cache_specs",
           "param_shardings", "safe_spec"]
