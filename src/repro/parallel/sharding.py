"""Sharding rule engine: FSDP × TP × EP × SP over the production mesh.

Strategy (DESIGN.md §6):
  * params — TP (Megatron column/row split) over ``model``; FSDP (ZeRO-3)
    over the data-parallel axes on the non-TP dim; experts over ``model``
    (EP).  Rules match on the parameter's path suffix; any sharding whose
    dimension does not divide the axis size is dropped (``safe_spec``).
  * activations — logical-axis rules consumed by ``repro.utils.shard``:
    batch→dp, heads/kv_heads/mlp/expert/vocab→model, seq→data only in the
    long-context (batch=1) decode cells (sequence parallelism).
  * KV caches — batch→dp when divisible, kv-heads→model when divisible,
    sequence→data for batch=1 cells.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeCell


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def safe_spec(mesh: Mesh, shape: tuple[int, ...], *axes) -> P:
    """PartitionSpec that drops any axis not dividing its dimension."""
    out = []
    used: set[str] = set()
    for dim, ax in zip(shape, axes):
        if ax is None:
            out.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        ax_t = tuple(a for a in ax_t if a in mesh.shape and a not in used)
        if ax_t and dim % _axis_size(mesh, ax_t) == 0:
            out.append(ax_t if len(ax_t) > 1 else ax_t[0])
            used.update(ax_t)
        else:
            out.append(None)
    return P(*out)


# -- parameter rules ----------------------------------------------------------
# (path-suffix regex, role); roles resolved per-shape below.
_PARAM_RULES: list[tuple[str, str]] = [
    (r"experts.*gate|experts.*up", "expert_in"),     # [E, d, f]
    (r"experts.*down", "expert_out"),                # [E, f, d]
    (r"embed.*table|head.*table", "embedding"),      # [V, d]
    (r"(wq_b|wk_b|wv_b)", "col"),                    # MLA up-proj [r, H*dh]
    (r"(wq_a|wkv_a)", "vec_in"),                     # MLA down-proj [d, r]
    (r"attn.*wo|out_proj|cm_v|time_mix.*wo", "row"),  # [model_dim, d]
    (r"(wq|wk|wv|wg|wr|gate|up|in_proj|cm_k|frontend|proj1|proj2)", "col"),
    (r"(w_lora_a|w_lora_b|x_proj|router|conv_w|mtp.*proj)", "vec_in"),
    (r"down", "row"),
]


def _spec_for(mesh: Mesh, path: str, shape: tuple[int, ...], dp, tp) -> P:
    ndim = len(shape)
    role = None
    for pat, r in _PARAM_RULES:
        if re.search(pat, path):
            role = r
            break
    # strip leading layer-stack dims: rules describe the trailing dims.
    def lead(n: int) -> list:
        return [None] * (ndim - n)

    if role == "expert_in" and ndim >= 3:
        return safe_spec(mesh, shape, *lead(3), tp, dp, None)
    if role == "expert_out" and ndim >= 3:
        return safe_spec(mesh, shape, *lead(3), tp, None, dp)
    if role == "embedding" and ndim >= 2:
        return safe_spec(mesh, shape, *lead(2), tp, dp)
    if role == "col" and ndim >= 2:
        return safe_spec(mesh, shape, *lead(2), dp, tp)
    if role == "row" and ndim >= 2:
        return safe_spec(mesh, shape, *lead(2), tp, dp)
    if role == "vec_in" and ndim >= 2:
        return safe_spec(mesh, shape, *lead(2), dp, None)
    if ndim >= 2:
        return safe_spec(mesh, shape, *lead(2), None, dp)
    return P(*([None] * ndim))


def param_shardings(mesh: Mesh, params_shapes: Any, fsdp: bool = True,
                    tensor_parallel: bool = True,
                    expert_2d: bool = False) -> Any:
    """NamedSharding pytree for a params ShapeDtypeStruct pytree.

    ``expert_2d`` (§Perf): shard the expert axis over data×model jointly —
    each chip owns whole experts, so expert weights are never gathered;
    tokens move via all-to-all instead (the EP-for-decode layout)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape) if fsdp else None
    tp = "model" if tensor_parallel else None
    ep = (tuple(a for a in ("pod", "data") if a in mesh.shape) + ("model",)
          if expert_2d else tp)

    def assign(path, leaf):
        p = jax.tree_util.keystr(path)
        if expert_2d and re.search(r"experts", p):
            nd = len(leaf.shape)
            lead = [None] * (nd - 3)
            spec = safe_spec(mesh, leaf.shape, *lead, ep, None, None)
            return NamedSharding(mesh, spec)
        spec = _spec_for(mesh, p, leaf.shape, dp, tp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


# -- activation logical rules --------------------------------------------------

def activation_rules(mesh: Mesh, cell: ShapeCell | None = None,
                     tensor_parallel: bool = True,
                     sequence_parallel: bool = False,
                     expert_2d: bool = False) -> dict[str, Any]:
    """Logical-axis → mesh-axis mapping for ``repro.utils.shard``.

    ``tensor_parallel=False`` (§Perf: tiny models on big meshes) drops every
    model-axis activation constraint — combined with TP-free param
    shardings this removes per-layer activation exchanges entirely.
    ``sequence_parallel`` = Megatron-SP: the residual stream's seq axis
    shards over `model` between attention/MLP regions.
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    long_ctx = cell is not None and cell.global_batch < _axis_size(mesh, dp)
    tp = "model" if tensor_parallel else None
    # with TP off the model axis is idle for activations — fold it into the
    # batch axes (pure-DP over the whole mesh) so per-device activations and
    # logits shrink by the TP degree.
    batch_axes = dp if tensor_parallel else dp + ("model",)
    seq = dp if long_ctx else ("model" if (sequence_parallel and tensor_parallel)
                               else None)
    return {
        "batch": None if long_ctx else batch_axes,
        "seq": seq,
        "embed": None,
        "heads": tp,
        "kv_heads": tp,
        "mlp": tp,
        "expert": (dp + ("model",)) if expert_2d else tp,
        "vocab": tp,
    }


# -- input/cache specs ---------------------------------------------------------

def batch_specs(mesh: Mesh, cfg: ModelConfig, inputs: dict[str, jax.ShapeDtypeStruct],
                cell: ShapeCell, tensor_parallel: bool = True) -> dict[str, NamedSharding]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if not tensor_parallel:
        dp = dp + ("model",)
    seq_parallel = cell.global_batch < _axis_size(mesh, dp)
    out = {}
    for name, sds in inputs.items():
        nd = len(sds.shape)
        if seq_parallel and nd >= 2:
            # batch=1 long-context: shard the sequence axis instead (SP)
            axes = [None, dp] + [None] * (nd - 2)
        elif seq_parallel:
            axes = [None] * nd
        else:
            axes = [dp] + [None] * (nd - 1)
        out[name] = NamedSharding(mesh, safe_spec(mesh, sds.shape, *axes))
    return out


def cache_specs(mesh: Mesh, cfg: ModelConfig, caches_shapes: Any,
                cell: ShapeCell) -> Any:
    """Shardings for decode caches.

    KV tensors [L, B, S, KVH, D] (GQA) / [L, B, S, R] (MLA) / states.
    batch→dp when divisible; kv_heads→model when divisible; for batch=1
    long-context cells the sequence axis shards over data (SP decode).
    """
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    seq_parallel = cell.global_batch < _axis_size(mesh, dp)

    cache_seq = cell.seq_len + cfg.meta_tokens

    def assign(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 3 and shape[2] == cache_seq:
            # quantization scales [L, B, T]
            return NamedSharding(mesh, safe_spec(
                mesh, shape, None, None if seq_parallel else dp,
                dp if seq_parallel else None))
        if nd >= 4 and shape[2] == cache_seq:
            # KV cache [L, B, S, KVH, D] (GQA) or [L, B, S, R] (MLA)
            axes: list = [None,
                          None if seq_parallel else dp,
                          dp if seq_parallel else None]
            axes += (["model", None] if nd == 5 else [None] * (nd - 3))
            return NamedSharding(mesh, safe_spec(mesh, shape, *axes))
        # states / misc [L, B, feat...]: batch over dp, first feature → model
        axes = [None, None if seq_parallel else dp] + [None] * (nd - 2)
        if nd >= 3:
            axes[2] = "model"
        return NamedSharding(mesh, safe_spec(mesh, shape, *axes))

    return jax.tree_util.tree_map(assign, caches_shapes)
