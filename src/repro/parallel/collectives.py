"""Distributed-optimization collectives.

* :func:`collective_matmul` — ring all-gather ⊗ GEMM overlap (Wang et al.,
  "Overlap communication with computation"): instead of all-gathering the
  TP-sharded activation and then one big GEMM, each of the A axis-steps
  multiplies the resident shard while ``ppermute`` streams the next shard —
  ICI transfer hides under MXU work.  Used as a §Perf beyond-paper
  optimization; it is the device-level twin of Opara's compute/memory
  operator overlap.
* :func:`quantized_psum` — int8-compressed gradient all-reduce with error
  feedback handled by the caller (optim.compression).
* :func:`topk_psum` — top-k sparsified gradient exchange.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import axis_size


def collective_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Ring-overlapped x_full @ w_shard inside ``shard_map``.

    x: [m, k_shard] — the local shard of an activation whose k axis is
    sharded over ``axis_name`` (size A).  w: [k_shard*A, n] replicated rows
    belonging to this device's output:  conceptually out = concat_k(x) @ w.

    Each step multiplies the currently-resident x shard against the matching
    row block of w, then rotates x around the ring.  The ppermute for step
    i+1 is issued before the GEMM of step i consumes its operand, so XLA's
    latency-hiding scheduler overlaps ICI with MXU.
    """
    a = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    k_shard = x.shape[-1]

    def body(i, carry):
        acc, cur = carry
        src_block = (idx - i) % a          # which global shard `cur` holds
        nxt = jax.lax.ppermute(cur, axis_name,
                               [(j, (j + 1) % a) for j in range(a)])
        w_block = jax.lax.dynamic_slice_in_dim(w, src_block * k_shard, k_shard, 0)
        acc = acc + jnp.dot(cur, w_block, preferred_element_type=jnp.float32)
        return acc, nxt

    acc = jnp.zeros(x.shape[:-1] + (w.shape[-1],), jnp.float32)
    acc, _ = jax.lax.fori_loop(0, a, body, (acc, x))
    return acc.astype(x.dtype)


def quantized_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """int8 all-reduce: quantize per-tensor, psum int32, dequantize.

    4× ICI traffic reduction on the gradient exchange (cross-pod axis is the
    slow one). Caller accumulates the quantization error (error feedback).
    """
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    scale = jax.lax.pmax(scale, axis_name)         # shared scale
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def topk_psum(g: jax.Array, axis_name: str, k_frac: float = 0.01) -> jax.Array:
    """Top-k magnitude sparsified all-reduce (Deep Gradient Compression).

    Keeps the k_frac largest-|g| entries locally, zeroes the rest, psums the
    sparse tensor densely (TPU all-reduce is dense; the win modeled here is
    the compression hook + error feedback at the optimizer level).
    """
    flat = g.reshape(-1)
    k = max(1, int(flat.size * k_frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return jax.lax.psum(kept.reshape(g.shape), axis_name)


def psum_scatter_grads(grads, axis_name: str):
    """reduce-scatter gradients over the dp axis (ZeRO-2 exchange)."""
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum_scatter(g, axis_name, scatter_dimension=0,
                                       tiled=True)
        if g.ndim > 0 and g.shape[0] % axis_size(axis_name) == 0
        else jax.lax.psum(g, axis_name),
        grads)
