"""jax version compatibility for the distribution layer.

``shard_map`` moved from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``) around jax 0.6.
This wrapper presents one call shape against either API, with replication
checking disabled (our pipeline/collective kernels intentionally produce
per-device-divergent intermediates).
"""
from __future__ import annotations

from typing import Any, Callable

import jax


def shard_map(f: Callable[..., Any], mesh, in_specs, out_specs) -> Callable[..., Any]:
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def axis_size(axis_name: str) -> int:
    """Static size of a mapped mesh axis (``jax.lax.axis_size`` is only
    available on newer jax; ``psum`` of a python literal constant-folds to
    the axis size on older versions)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
