"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Wires every substrate together: config → model init → (optional mesh +
shardings) → data pipeline → jit'd train step (loss/grad/AdamW, optional
grad compression) → async checkpointing → fault-tolerance hooks
(heartbeat + straggler monitor; single-host here, same control plane the
multi-host launcher drives).  ``--resume`` restarts from the latest
durable checkpoint, replaying the data stream to the exact step.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer, CheckpointSpec, latest_step
from ..configs import get_config
from ..configs.base import ParallelConfig
from ..data import make_dataset
from ..models import Model
from ..optim import adamw_init
from ..runtime import HeartbeatMonitor, StragglerDetector
from .steps import make_train_step


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, resume: bool, ckpt_every: int = 20,
          compression: str = "none", log_every: int = 10) -> dict:
    cfg = get_config(arch, smoke=smoke)
    pcfg = ParallelConfig(grad_compression=compression, remat="none")
    model = Model(cfg)
    rng = jax.random.key(0)
    params = model.init(rng)
    opt_state = adamw_init(params)
    warmup = max(10, min(steps // 10, 200))
    step_fn = jax.jit(make_train_step(model, pcfg, base_lr=1e-3,
                                      warmup=warmup, total_steps=max(steps, 1000)))

    data = make_dataset(cfg.vocab_size, seq, batch)
    ckpt = Checkpointer(CheckpointSpec(ckpt_dir)) if ckpt_dir else None
    start = 0
    if ckpt and resume:
        last = latest_step(ckpt_dir)
        if last is not None:
            state = ckpt.restore(last, {"params": params, "opt": opt_state,
                                        "data": {"step": 0}})
            params, opt_state = state["params"], state["opt"]
            data.load_state_dict({"step": int(np.asarray(state["data"]["step"]))})
            start = last
            print(f"[train] resumed from step {last}")

    monitor = HeartbeatMonitor([0], time.monotonic)
    straggler = StragglerDetector()
    losses = []
    t_total = time.perf_counter()
    for step in range(start, steps):
        t0 = time.perf_counter()
        batch_np = data.batch_at(step)
        batch_j = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch_j,
                                             jnp.int32(step))
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        monitor.beat(0, dt)
        straggler.check(monitor)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"grad_norm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state,
                                 "data": {"step": step + 1}})
    if ckpt:
        ckpt.save(steps, {"params": params, "opt": opt_state,
                          "data": {"step": steps}}, blocking=True)
    wall = time.perf_counter() - t_total
    result = {
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps": len(losses),
        "wall_s": wall,
    }
    print(f"[train] done: {result}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    args = ap.parse_args(argv)
    res = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                args.ckpt_dir, args.resume, compression=args.compression)
    return 0 if res["last_loss"] is not None and np.isfinite(res["last_loss"]) else 1


if __name__ == "__main__":
    sys.exit(main())
