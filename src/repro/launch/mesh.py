"""Production meshes.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state: the dry-run sets XLA_FLAGS for 512 host devices
BEFORE calling this; tests/benches see the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Data-parallel mesh axes: ('pod','data') on multi-pod, else ('data',)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
