import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

DOC = """Roofline analysis (§Roofline of EXPERIMENTS.md).

Per (arch × shape × mesh) cell, derive the three roofline terms from the
compiled dry-run artifact:

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis`` counts ``lax.scan`` bodies ONCE (verified), so totals are
corrected by lowering one BLOCK of each stack separately:

    corrected = full + Σ_stacks (n_i × block_full_i − block_partial_i)

where ``block_full`` forces single-chunk attention (inner scans trip=1 →
exactly counted) and ``block_partial`` uses the production chunking (≈ what
the full program's body-once already contains).  The single-chunk lowering
inflates attention HBM bytes (it round-trips the [S,T] probabilities that
the real flash kernel keeps in VMEM); we subtract that inflation
analytically (3 × fp32 round-trips of [b,h,s,t]) — documented here, visible
in the record as ``attn_bytes_adjustment``.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
collective_bytes are per-device (SPMD HLO shapes are per-device), so the
term divides by link_bw only.
"""

import argparse
import contextlib
import json
import sys
from typing import Any

import jax
import jax.numpy as jnp

from ..configs import SHAPES, cell_applicable, get_config, list_archs
from ..configs.base import ModelConfig, ParallelConfig, ShapeCell
from ..core.profiler import V5E, HardwareSpec
from ..models import Model
from ..models.transformer import stack_meta
from ..parallel.sharding import activation_rules, param_shardings
from ..utils import logical_axis_rules
from .dryrun import lower_cell
from .hlo_analysis import cost_dict, parse_collectives
from .mesh import make_production_mesh


# ---------------------------------------------------------------- block costs

@contextlib.contextmanager
def _single_chunk_attention():
    from ..models import attention as att
    prev = att._CHUNK_OVERRIDE
    att._CHUNK_OVERRIDE = "single"
    try:
        yield
    finally:
        att._CHUNK_OVERRIDE = prev


def _block_record(cfg: ModelConfig, cell: ShapeCell, mesh, kind: str,
                  windows, single_chunk: bool) -> dict[str, float]:
    """Lower ONE block (train: fwd+bwd; prefill: fwd; decode: one step) and
    return {flops, bytes, collective_bytes}."""
    from ..models import attention as att
    from ..models.transformer import block_seq, block_step, init_block
    from ..models.attention import init_cache
    from ..models.ssm import mamba_state_init, rwkv_state_init

    b = cell.global_batch
    s = cell.seq_len if cell.step != "decode" else 1
    d = cfg.d_model
    rng = jax.random.key(0)
    p_shapes = jax.eval_shape(lambda k: init_block(k, cfg, kind), rng)
    p_sh = param_shardings(mesh, p_shapes)
    x_sds = jax.ShapeDtypeStruct((b, s, d), cfg.dtype)
    win = windows[0] if windows else 0
    win = win if win > 0 else (1 << 30)
    rules = activation_rules(mesh, cell)

    if cell.step == "train":
        def fn(p, x):
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            def inner(p, x):
                y, _, aux = block_seq(p, x, cfg, positions, jnp.int32(win),
                                      None, False, kind)
                return (y.astype(jnp.float32).mean() + aux).sum()
            return jax.grad(inner, argnums=(0, 1))(p, x)
        args = (p_shapes, x_sds)
        in_sh = (p_sh, None)
    elif cell.step == "prefill":
        def fn(p, x):
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            y, cache, _ = block_seq(p, x, cfg, positions, jnp.int32(win),
                                    None, False, kind)
            return y, cache
        args = (p_shapes, x_sds)
        in_sh = (p_sh, None)
    else:  # decode
        length = cell.seq_len + cfg.meta_tokens
        if kind == "rwkv":
            cache = jax.eval_shape(lambda: rwkv_state_init(cfg, b))
        else:
            kv = jax.eval_shape(lambda: init_cache(cfg, b, length))
            if kind == "hybrid":
                ms = jax.eval_shape(lambda: mamba_state_init(cfg, b))
                cache = {"kv": kv, "mamba_conv": ms[0], "mamba_h": ms[1]}
            else:
                cache = kv
        pos_sds = jax.ShapeDtypeStruct((b,), jnp.int32)
        def fn(p, x, cache, pos):
            return block_step(p, x, cache, pos, cfg, jnp.int32(win), kind)
        args = (p_shapes, x_sds, cache, pos_sds)
        in_sh = (p_sh, None, None, None)

    ctx = _single_chunk_attention() if single_chunk else contextlib.nullcontext()
    with mesh, logical_axis_rules(rules, mesh), ctx:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    cost = cost_dict(compiled)
    coll = parse_collectives(compiled.as_text(), while_multiplier=1.0)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll.total_bytes,
    }


def _attn_bytes_inflation(cfg: ModelConfig, cell: ShapeCell) -> float:
    """fp32 [b,h,s,t] probability round-trips that single-chunk lowering
    claims but real flash keeps in VMEM (3 passes: logits write, read for
    softmax-normalize, p read for PV)."""
    if cell.step == "decode":
        return 0.0
    b, s = cell.global_batch, cell.seq_len + cfg.meta_tokens
    if cfg.family == "ssm":
        return 0.0
    h = cfg.n_heads
    per_layer = 3.0 * 4.0 * b * h * s * s
    if cell.step == "train":
        per_layer *= 2.5      # bwd recompute + ds/dp traffic
    return per_layer


# ---------------------------------------------------------------- terms

def roofline_terms(flops: float, bytes_: float, coll_bytes_per_dev: float,
                   chips: int, hw: HardwareSpec = V5E) -> dict[str, float]:
    compute_s = flops / (chips * hw.peak_flops)
    memory_s = bytes_ / (chips * hw.hbm_bw)
    collective_s = coll_bytes_per_dev / hw.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {**terms, "dominant": dominant,
            "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
            "step_time_lower_bound_s": bound}


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6·N_active·D for train, 2·N_active·D for inference (D = tokens)."""
    n = cfg.n_active_params()
    if cell.step == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.step == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch          # one token per sequence


# ---------------------------------------------------------------- driver

def analyse_cell(arch: str, shape_id: str, multi_pod: bool = False,
                 pcfg: ParallelConfig | None = None) -> dict[str, Any]:
    cfg = get_config(arch)
    cell = SHAPES[shape_id]
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape_id, "multi_pod": multi_pod,
                "status": "SKIP", "reason": reason}

    rec = lower_cell(arch, shape_id, multi_pod=multi_pod, pcfg=pcfg)
    if rec.get("status") != "OK":
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    # compute/memory terms: analytic model (see analytic_cost.py for why);
    # collective term: parsed from the compiled SPMD HLO (per-device bytes,
    # scan-depth multiplier applied in lower_cell).
    from .analytic_cost import cell_cost
    remat = (pcfg or ParallelConfig()).remat != "none" and cell.step == "train"
    ac = cell_cost(cfg, cell, remat=remat)
    coll = rec["collectives"]["total_bytes_per_device"]

    mf = model_flops(cfg, cell)
    terms = roofline_terms(ac.flops, ac.bytes, coll, chips)
    hlo_flops_per_dev = rec["cost"].get("flops", 0.0)
    rec.update(
        analytic={"flops": ac.flops, "bytes": ac.bytes, **ac.detail},
        hlo_flops_per_device=hlo_flops_per_dev,
        hlo_crosscheck_ratio=(hlo_flops_per_dev * chips / ac.flops
                              if ac.flops else 0.0),
        model_flops=mf,
        useful_flops_ratio=mf / ac.flops if ac.flops else 0.0,
        roofline=terms,
    )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    # §Perf hillclimb knobs (flags REPRO_* come via the environment)
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate params over dp axes (inference cells)")
    ap.add_argument("--no-tp", action="store_true",
                    help="disable tensor parallelism (tiny-model cells)")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="Megatron-SP residual-stream sharding")
    ap.add_argument("--ep2d", action="store_true",
                    help="experts sharded data×model (whole-expert ownership)")
    ap.add_argument("--remat", default="block", choices=["none", "block"])
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--tag", default=None, help="label recorded with --out")
    args = ap.parse_args(argv)
    pcfg = ParallelConfig(fsdp=not args.no_fsdp, remat=args.remat,
                          tensor_parallel=not args.no_tp,
                          sequence_parallel=args.seq_parallel,
                          expert_2d=args.ep2d,
                          grad_compression=args.compression)
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape_id in shapes:
            rec = analyse_cell(arch, shape_id, multi_pod=args.multi_pod,
                               pcfg=pcfg)
            if args.tag:
                rec["tag"] = args.tag
            r = rec.get("roofline", {})
            print(f"[roofline] {arch} × {shape_id}: {rec['status']} "
                  + (f"dominant={r.get('dominant')} "
                     f"frac={r.get('roofline_fraction', 0):.3f} "
                     f"c/m/x={r.get('compute_s', 0):.4f}/"
                     f"{r.get('memory_s', 0):.4f}/{r.get('collective_s', 0):.4f}s"
                     if r else ""))
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
