"""Step functions lowered by the dry-run / launchers.

``make_train_step``: loss → grad → (optional compression) → AdamW update.
``make_prefill_step`` / ``make_decode_step``: serving paths.

All are pure functions of (params/opt_state, batch) suitable for
``jax.jit(...).lower(...)`` with explicit in/out shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ParallelConfig, ShapeCell
from ..models import Model
from ..optim import adamw_update, compress_grads
from ..optim.schedule import cosine_schedule


def make_train_step(model: Model, pcfg: ParallelConfig,
                    base_lr: float = 3e-4, warmup: int = 2000,
                    total_steps: int = 100_000) -> Callable:
    remat = pcfg.remat != "none"

    def train_step(params, opt_state, batch, seed):
        rng = jax.random.PRNGKey(seed)

        def loss_fn(p):
            loss, metrics = model.loss(p, batch, rng, remat=remat)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        from ..optim import AdamWState
        has_comp = not isinstance(opt_state, AdamWState)
        comp_state = None
        if has_comp:
            adam, comp_state = opt_state
        else:
            adam = opt_state
        if pcfg.grad_compression != "none" and comp_state is not None:
            grads, comp_state = compress_grads(grads, comp_state,
                                               pcfg.grad_compression)
        lr = cosine_schedule(adam.step, base_lr, warmup=warmup, total=total_steps)
        new_params, new_adam, opt_metrics = adamw_update(grads, adam, params, lr)
        new_opt = (new_adam, comp_state) if has_comp else new_adam
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model: Model, cell: ShapeCell) -> Callable:
    cache_len = cell.seq_len + model.cfg.meta_tokens

    def prefill_step(params, inputs):
        logits, caches = model.prefill(params, inputs, cache_len=cache_len)
        return logits, caches

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, caches, token, pos):
        logits, new_caches = model.decode(params, token, caches, pos)
        return logits, new_caches

    return decode_step
