import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST be the first statements of this module —
# before ANY other import — since jax locks the device count on first init.
DOC = """Multi-pod dry-run: lower + compile EVERY (arch × shape × mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init): the dry-run — and only the dry-run — sees 512 host
placeholder devices so ``make_production_mesh`` can build the 16×16 and
2×16×16 production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape prefill_32k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Per cell this prints/records compiled.memory_analysis() (bytes per device —
proves it fits or quantifies by how much it doesn't), cost_analysis()
(FLOPs/bytes for §Roofline) and the parsed collective schedule.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp

from ..configs import SHAPES, cell_applicable, get_config, list_archs
from ..configs.base import ModelConfig, ParallelConfig, ShapeCell
from ..models import Model
from ..models.transformer import stack_meta
from ..optim import adamw_init
from ..parallel.sharding import (
    activation_rules,
    batch_specs,
    cache_specs,
    param_shardings,
)
from ..utils import logical_axis_rules
from .hlo_analysis import CollectiveStats, cost_dict, memory_dict, parse_collectives
from .mesh import make_production_mesh
from .steps import make_decode_step, make_prefill_step, make_train_step


def _replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    return NamedSharding(mesh, PartitionSpec())


def lower_cell(arch: str, shape_id: str, multi_pod: bool = False,
               pcfg: ParallelConfig | None = None, compile_: bool = True) -> dict[str, Any]:
    """Lower+compile one cell; returns the §Dry-run record."""
    cfg = get_config(arch)
    cell = SHAPES[shape_id]
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape_id, "multi_pod": multi_pod,
                "status": "SKIP", "reason": reason}
    pcfg = pcfg or ParallelConfig()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    model = Model(cfg)
    params_shapes = model.init_shapes()
    param_sh = param_shardings(mesh, params_shapes, fsdp=pcfg.fsdp,
                               tensor_parallel=pcfg.tensor_parallel,
                               expert_2d=pcfg.expert_2d)
    rules = activation_rules(mesh, cell, tensor_parallel=pcfg.tensor_parallel,
                             sequence_parallel=pcfg.sequence_parallel,
                             expert_2d=pcfg.expert_2d)
    inputs = model.input_specs(cell)
    input_sh = batch_specs(mesh, cfg, inputs, cell,
                           tensor_parallel=pcfg.tensor_parallel)

    t0 = time.time()
    with mesh:
        with logical_axis_rules(rules, mesh):
            if cell.step == "train":
                from ..optim import AdamWState
                opt_shapes = jax.eval_shape(lambda p: adamw_init(p), params_shapes)
                # mu/nu inherit the param shardings (ZeRO-3), step replicated
                opt_sh = AdamWState(step=_replicated(mesh), mu=param_sh, nu=param_sh)
                step = make_train_step(model, pcfg)
                seed = jax.ShapeDtypeStruct((), jnp.int32)
                lowered = jax.jit(
                    step,
                    in_shardings=(param_sh, opt_sh, input_sh, _replicated(mesh)),
                ).lower(params_shapes, opt_shapes, inputs, seed)
            elif cell.step == "prefill":
                step = make_prefill_step(model, cell)
                lowered = jax.jit(
                    step, in_shardings=(param_sh, input_sh),
                ).lower(params_shapes, inputs)
            else:  # decode
                caches_shapes = model.decode_state_specs(cell)
                cache_sh = cache_specs(mesh, cfg, caches_shapes, cell)
                step = make_decode_step(model)
                # caches are donated: the in-place-aliasable update is what
                # production decode does (temp memory would double otherwise)
                lowered = jax.jit(
                    step,
                    in_shardings=(param_sh, cache_sh,
                                  input_sh["token"], input_sh["pos"]),
                    donate_argnums=(1,),
                ).lower(params_shapes, caches_shapes,
                        inputs["token"], inputs["pos"])
    t_lower = time.time() - t0

    record: dict[str, Any] = {
        "arch": arch, "shape": shape_id, "multi_pod": multi_pod,
        "status": "LOWERED", "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
    }
    if not compile_:
        return record

    t0 = time.time()
    compiled = lowered.compile()
    record["compile_s"] = round(time.time() - t0, 1)
    record["status"] = "OK"
    record["memory"] = memory_dict(compiled)
    record["cost"] = {k: v for k, v in cost_dict(compiled).items()
                      if k in ("flops", "bytes accessed", "transcendentals",
                               "utilization")}
    # collective bytes: scan bodies scaled by max stack depth (conservative:
    # virtually all per-layer collectives sit in the layer scan)
    depth = max((n for _, n, _ in stack_meta(cfg)), default=1)
    if cfg.family == "encdec":
        depth = cfg.n_layers
    text = compiled.as_text()
    coll = parse_collectives(text, while_multiplier=float(depth))
    record["collectives"] = {
        "bytes_by_kind": coll.bytes_by_kind,
        "count_by_kind": coll.count_by_kind,
        "total_bytes_per_device": coll.total_bytes,
        "scan_depth_multiplier": depth,
    }
    record["hlo_bytes"] = len(text)
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all arch×shape×mesh cells")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args(argv)

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for arch in list_archs():
            for shape_id in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape_id, mp))
    else:
        archs = [args.arch] if args.arch else list_archs()
        shapes = [args.shape] if args.shape else list(SHAPES)
        pods = [args.multi_pod]
        cells = [(a, s, p) for a in archs for s in shapes for p in pods]

    failures = 0
    for arch, shape_id, mp in cells:
        tag = f"{arch} × {shape_id} × {'2x16x16' if mp else '16x16'}"
        try:
            rec = lower_cell(arch, shape_id, multi_pod=mp)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape_id, "multi_pod": mp,
                   "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        print(f"[dryrun] {tag}: {rec['status']}"
              + (f" mem={rec.get('memory')}" if rec.get("memory") else "")
              + (f" flops={rec.get('cost', {}).get('flops')}" if rec.get("cost") else ""))
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
