"""Analytic per-step FLOP / HBM-byte model for every (arch × shape) cell.

Why analytic: XLA's ``cost_analysis()`` counts every while-loop body ONCE —
the layer scan, the flash-attention chunk scans, and GSPMD's windowed-einsum
loops all divide the reported FLOPs by their (nested) trip counts, and the
factors differ per cell.  Rather than reverse-engineering loop trip counts
out of optimized HLO, the roofline's compute/memory terms come from the
explicit formulas below (the same quantities MaxText-style frameworks
napkin-math), while the compiled artifact contributes what it measures
reliably: per-device memory_analysis (capacity proof) and the collective
schedule.  HLO FLOPs are still recorded as a cross-check lower bound.

All numbers are GLOBAL per step; the roofline divides by chip count.
Conventions: matmul fwd = 2·m·k·n; bwd = 2× fwd; remat="block" recomputes
the fwd once during bwd (matmul train factor 8 instead of 6); causal
attention scores count the full rectangle /2.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeCell
from ..models.transformer import cfg_dense_prefix, stack_meta


@dataclasses.dataclass
class CellCost:
    flops: float
    bytes: float
    detail: dict


def _attn_ctx(cfg: ModelConfig, s: int) -> float:
    """Mean COMPUTED context per query across layers.

    The baseline flash implementation computes every KV chunk and masks
    (full rectangle, eff = s); with §Perf O5 (REPRO_CAUSAL_SKIP) fully
    masked chunks are skipped at runtime, so causal layers compute s/2 and
    windowed layers ≈ their window."""
    from ..flags import causal_skip
    skip = causal_skip()
    total = 0.0
    n = 0
    for _, cnt, windows in stack_meta(cfg):
        for w in windows:
            if skip:
                eff = s / 2 if (w == 0 or w >= s) else min(w, s)
            else:
                eff = s
            total += eff
            n += 1
    return total / max(n, 1)


def _layer_matmul_params(cfg: ModelConfig) -> tuple[float, float]:
    """(active matmul params per MoE/attn layer, dense-prefix layer params)."""
    d = cfg.d_model
    hd = cfg.head_dim
    if cfg.family == "ssm":
        hs = cfg.ssm.head_dim if cfg.ssm else 64
        p = 4 * d * d + d * d  # r,k,v,g,o  (w-lora ~ small)
        p += 2 * d * cfg.d_ff  # channel mix
        return float(p), 0.0
    if cfg.mla is not None:
        m = cfg.mla
        attn = (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads *
                (m.qk_nope_head_dim + m.qk_rope_head_dim)
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * d)
    else:
        attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
            + cfg.n_heads * hd * d
    if cfg.moe is not None:
        e = cfg.moe
        ffn_active = (e.top_k + e.n_shared) * 3 * d * e.d_expert + d * e.n_experts
    else:
        mult = 3 if cfg.act == "swiglu" else 2
        ffn_active = mult * d * cfg.d_ff
    layer = attn + ffn_active
    if cfg.family == "hybrid" and cfg.ssm is not None:
        di = cfg.ssm.expand * d
        layer += 2 * d * di + di * (2 * cfg.ssm.state_dim + 1) + di * d
    dense_layer = attn + (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    return float(layer), float(dense_layer)


def cell_cost(cfg: ModelConfig, cell: ShapeCell, remat: bool = True) -> CellCost:
    b = cell.global_batch
    s = cell.seq_len + cfg.meta_tokens if cell.step != "decode" else 1
    ctx = cell.seq_len + cfg.meta_tokens
    d = cfg.d_model
    v = cfg.vocab_size
    tokens = b * s
    if cfg.family == "vlm" and cfg.frontend and cell.step != "decode":
        tokens = b * cell.seq_len  # text + image tokens add to the budget
    if cfg.family == "encdec" and cell.step != "decode":
        tokens = b * cell.seq_len

    layer_p, dense_p = _layer_matmul_params(cfg)
    prefix = cfg_dense_prefix(cfg)
    n_moe = cfg.n_layers - prefix
    matmul_params = n_moe * layer_p + prefix * dense_p
    if cfg.family == "encdec":
        n_dec = cfg.n_dec_layers or cfg.n_layers
        matmul_params = (cfg.n_layers + n_dec) * layer_p \
            + n_dec * 2 * d * cfg.n_kv_heads * cfg.head_dim  # cross-attn KV

    # -- matmul flops ---------------------------------------------------------
    fwd_factor = {"train": 2.0, "prefill": 2.0, "decode": 2.0}[cell.step]
    train_factor = 8.0 if remat else 6.0   # fwd + (recompute) + bwd
    factor = train_factor if cell.step == "train" else fwd_factor
    mm_flops = factor * tokens * matmul_params

    # head + embedding matmul
    head_flops = factor * tokens * d * v
    if cell.step == "decode":
        head_flops = 2.0 * b * d * v

    # -- attention flops ------------------------------------------------------
    attn_flops = 0.0
    if cfg.family != "ssm":
        nh, hd = cfg.n_heads, cfg.head_dim
        if cfg.mla is not None:
            hd_k = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
            hd_v = cfg.mla.kv_lora_rank
        else:
            hd_k = hd_v = hd
        n_attn = cfg.n_layers + (cfg.n_dec_layers or 0)
        if cell.step == "decode":
            attn_flops = 2.0 * b * nh * ctx * (hd_k + hd_v) * n_attn
        else:
            mean_ctx = _attn_ctx(cfg, s)
            per_layer = 2.0 * b * s * mean_ctx * nh * (hd_k + hd_v)
            mult = (2.5 if remat else 2.0) if cell.step == "train" else 1.0
            # bwd of flash ≈ 2.5× fwd matmul work (dq, dk, dv + recompute p)
            attn_flops = per_layer * n_attn * (1.0 + mult
                                               if cell.step == "train" else 1.0)
        if cfg.family == "encdec" and cell.step != "decode":
            fe = cfg.frontend
            n_dec = cfg.n_dec_layers or cfg.n_layers
            attn_flops += 2.0 * b * (fe.n_tokens ** 2) * nh * 2 * hd * cfg.n_layers
            attn_flops += 2.0 * b * s * fe.n_tokens * nh * 2 * hd * n_dec

    # -- recurrence flops (ssm / hybrid) ---------------------------------------
    scan_flops = 0.0
    if cfg.family == "ssm":
        hs = cfg.ssm.head_dim if cfg.ssm else 64
        scan_flops = 10.0 * tokens * d * hs * cfg.n_layers
    elif cfg.family == "hybrid":
        di = cfg.ssm.expand * d
        scan_flops = 8.0 * tokens * di * cfg.ssm.state_dim * cfg.n_layers
    if cell.step == "train":
        scan_flops *= 3.0

    flops = mm_flops + head_flops + attn_flops + scan_flops

    # -- bytes ------------------------------------------------------------------
    p_total = cfg.n_params()
    p_active = cfg.n_active_params()
    dt = 2.0  # bf16
    if cell.step == "train":
        # params r (fwd) + r (bwd) + grads w+r + adam m,v fp32 r+w + master w
        param_traffic = p_total * (dt * 3 + 4 * 2 + 8 * 2 + 4)
        act_traffic = tokens * d * dt * 14 * (cfg.n_layers + (cfg.n_dec_layers or 0))
        from ..flags import chunked_ce
        if chunked_ce():
            # §Perf O3: logits live chunk-at-a-time and mostly fuse; residual
            # spill ≈ half of one pass over the logits volume.
            ce_traffic = 0.5 * tokens * v * 4.0
        else:
            ce_traffic = 3.0 * tokens * v * 4.0   # fp32 logits w + r + dlogits
        bytes_ = param_traffic + act_traffic + ce_traffic
    elif cell.step == "prefill":
        param_traffic = p_active * dt + (p_total - p_active) * dt * min(
            1.0, tokens / max(cfg.moe.n_experts if cfg.moe else 1, 1))
        act_traffic = tokens * d * dt * 10 * (cfg.n_layers + (cfg.n_dec_layers or 0))
        kv_write = _cache_bytes(cfg, b, ctx)
        bytes_ = param_traffic + act_traffic + kv_write + tokens * v * 4.0
    else:  # decode
        from ..flags import cache_update_mode, window_slice_decode
        cache = _cache_bytes(cfg, b, ctx)
        # baseline where-select cache update rewrites the buffer (read +
        # write on top of the attention read); §Perf O1 scatter touches one
        # slot per sequence.
        update = 2.0 if cache_update_mode() != "scatter" else 0.01
        read = 1.0
        if window_slice_decode() and cfg.window:
            # §Perf O6: windowed layers read window+1 slots, global layers
            # read the full cache.
            n_l = cfg.n_layers
            n_glob = len(cfg.global_layers)
            read = (n_glob + (n_l - n_glob) * min(1.0, (cfg.window + 1) / ctx)) / n_l
        param_traffic = p_active * dt if cfg.moe is None else \
            min(p_total, p_active * b) * dt
        bytes_ = param_traffic + cache * (read + update) + b * v * 4.0
    return CellCost(flops=float(flops), bytes=float(bytes_), detail={
        "matmul_flops": mm_flops, "head_flops": head_flops,
        "attn_flops": attn_flops, "scan_flops": scan_flops,
        "param_bytes": p_total * dt,
        "cache_bytes": _cache_bytes(cfg, b, ctx) if cell.step != "train" else 0.0,
    })


def _cache_bytes(cfg: ModelConfig, b: int, ctx: int) -> float:
    dt = 2.0
    if cfg.family == "ssm":
        hs = cfg.ssm.head_dim if cfg.ssm else 64
        h = cfg.d_model // hs
        return float(cfg.n_layers * b * (h * hs * hs * 4 + 2 * cfg.d_model * dt))
    if cfg.mla is not None:
        from ..flags import kv_quant
        if kv_quant():   # §Perf O8: int8 latent + f16 scale + bf16 rope keys
            per_tok_bytes = cfg.mla.kv_lora_rank + 2 + cfg.mla.qk_rope_head_dim * dt
            return float(cfg.n_layers * b * ctx * per_tok_bytes)
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return float(cfg.n_layers * b * ctx * per_tok * dt)
    kv = 2 * cfg.n_kv_heads * cfg.head_dim
    n_layers = cfg.n_layers + (cfg.n_dec_layers or 0)
    total = float(n_layers * b * ctx * kv * dt)
    if cfg.family == "hybrid":
        di = cfg.ssm.expand * cfg.d_model
        total += cfg.n_layers * b * di * (cfg.ssm.state_dim * 4 + 3 * dt)
    return total
