"""End-to-end serving driver (continuous batching on a smoke model).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --requests 8 --max-tokens 16
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import Model
from ..serving import InferenceEngine, Request


def serve(arch: str, n_requests: int, max_tokens: int, slots: int = 4,
          max_len: int = 128, temperature: float = 0.0,
          calibrate: bool = False) -> dict:
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    # one explicit Session for the whole serving process: every engine this
    # driver spins up shares its measured-profile / schedule caches
    from ..core import Session
    session = Session()
    engine = InferenceEngine(model, params, max_slots=slots, max_len=max_len,
                             session=session, calibrate=calibrate)
    if calibrate and engine.schedule_plan is not None:
        p = engine.schedule_plan
        stats = session.cache_stats()
        # non-profileable archs degrade to the analytic cost model inside
        # calibrate_schedule (one DegradationWarning) — surface it here too
        mode = ("analytic (degraded)" if stats["calib_degraded_analytic"]
                else "measured")
        print(f"[serve] opara schedule [{mode}]: streams={p.n_streams} "
              f"waves={p.waves.n_waves} (calibration "
              f"{stats['calib_misses']} timed / "
              f"{stats['calib_hits']} cached)")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(n_requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
        engine.submit(Request(rid=rid, prompt=prompt, max_tokens=max_tokens,
                              temperature=temperature))
    done = engine.run()
    wall = time.perf_counter() - t0
    from ..serving import RequestState
    failed = [r for r in done if r.state is RequestState.FAILED]
    total_tokens = sum(len(r.output) for r in done)
    result = {
        "completed": len(done) - len(failed),
        "failed": len(failed),
        "total_tokens": total_tokens,
        "wall_s": wall,
        "tok_per_s": total_tokens / wall if wall > 0 else 0.0,
    }
    for r in failed[:4]:
        print(f"[serve] rid={r.rid} FAILED: {r.error}")
    for r in done[:4]:
        print(f"[serve] rid={r.rid} prompt_len={len(r.prompt)} "
              f"out={r.output[:8]}{'...' if len(r.output) > 8 else ''}")
    print(f"[serve] {result}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--calibrate", action="store_true",
                    help="measured-profile Opara schedule of the step graph")
    args = ap.parse_args(argv)
    res = serve(args.arch, args.requests, args.max_tokens, args.slots,
                calibrate=args.calibrate)
    return 0 if res["completed"] == args.requests else 1


if __name__ == "__main__":
    sys.exit(main())
