"""End-to-end serving driver (continuous batching on a smoke model).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --requests 8 --max-tokens 16

Multi-tenant overload mode: ``--tenants N`` spreads the requests over N
tenants — each with its own isolated :class:`repro.core.Session` so
per-tenant shed/expire/preempt provenance lands on that tenant's
``guard_log`` — and ``--overload`` arms the admission tier (bounded queue,
per-tenant quotas, mixed priorities and tick deadlines) against a burst
trace, printing the goodput/shed/expiry ledger instead of falling over.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import Model
from ..serving import (AdmissionConfig, InferenceEngine, Request,
                       RequestState, TERMINAL_STATES)


def serve(arch: str, n_requests: int, max_tokens: int, slots: int = 4,
          max_len: int = 128, temperature: float = 0.0,
          calibrate: bool = False, tenants: int = 1,
          overload: bool = False, max_queue: int | None = None,
          tenant_quota: int | None = None, ttl: int | None = None) -> dict:
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    # one explicit Session for the whole serving process: every engine this
    # driver spins up shares its measured-profile / schedule caches.  Each
    # tenant additionally gets an ISOLATED Session (PR 4: cheap, composable
    # compilation state) that collects that tenant's degradation provenance.
    from ..core import Session
    session = Session()
    tenant_names = [f"tenant{i}" for i in range(max(1, tenants))]
    tenant_sessions = {name: Session() for name in tenant_names}
    admission = AdmissionConfig(max_queue=max_queue,
                                tenant_quota=tenant_quota)
    engine = InferenceEngine(model, params, max_slots=slots, max_len=max_len,
                             session=session, calibrate=calibrate,
                             admission=admission,
                             tenant_sessions=tenant_sessions)
    if calibrate and engine.schedule_plan is not None:
        p = engine.schedule_plan
        stats = session.cache_stats()
        # non-profileable archs degrade to the analytic cost model inside
        # calibrate_schedule (one DegradationWarning) — surface it here too
        mode = ("analytic (degraded)" if stats["calib_degraded_analytic"]
                else "measured")
        print(f"[serve] opara schedule [{mode}]: streams={p.n_streams} "
              f"waves={p.waves.n_waves} (calibration "
              f"{stats['calib_misses']} timed / "
              f"{stats['calib_hits']} cached)")
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(n_requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12)).tolist()
        req = Request(rid=rid, prompt=prompt, max_tokens=max_tokens,
                      temperature=temperature,
                      tenant=tenant_names[rid % len(tenant_names)])
        if overload:
            # mixed priorities and tick-TTLs: the admission tier sheds /
            # expires / preempts deterministically instead of queueing
            # forever — every request still ends in a terminal state
            req.priority = rid % 3
            req.ttl = ttl if ttl is not None else max_tokens * 2 + 8
        engine.submit(req)
    done = engine.drain()
    wall = time.perf_counter() - t0
    by_state = {s.value: 0 for s in TERMINAL_STATES}
    for r in done:
        by_state[r.state.value] += 1
    assert all(r.state in TERMINAL_STATES for r in done), \
        "engine returned a non-terminal request"
    total_tokens = sum(len(r.output) for r in done)
    result = {
        "completed": by_state["done"],
        "failed": by_state["failed"],
        "shed": by_state["shed"],
        "expired": by_state["expired"],
        "total_tokens": total_tokens,
        "wall_s": wall,
        "tok_per_s": total_tokens / wall if wall > 0 else 0.0,
    }
    for r in done[:8]:
        if r.state is RequestState.DONE:
            print(f"[serve] rid={r.rid} {r.tenant} prompt_len={len(r.prompt)} "
                  f"out={r.output[:8]}{'...' if len(r.output) > 8 else ''}")
        else:
            print(f"[serve] rid={r.rid} {r.tenant} {r.state.value.upper()}: "
                  f"{r.error}")
    if tenants > 1 or overload:
        for name in tenant_names:
            stats = engine.fault_stats["by_tenant"].get(name, {})
            events = len(tenant_sessions[name].guard_log)
            print(f"[serve] {name}: {stats} ({events} provenance events)")
        print(f"[serve] health: {engine.health()}")
    print(f"[serve] {result}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--calibrate", action="store_true",
                    help="measured-profile Opara schedule of the step graph")
    ap.add_argument("--tenants", type=int, default=1,
                    help="spread requests over N isolated tenants")
    ap.add_argument("--overload", action="store_true",
                    help="arm the admission tier: priorities + deadlines")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bound on the admission queue (shed beyond)")
    ap.add_argument("--tenant-quota", type=int, default=None,
                    help="max queued requests per tenant")
    ap.add_argument("--ttl", type=int, default=None,
                    help="per-request deadline in ticks from submission")
    args = ap.parse_args(argv)
    res = serve(args.arch, args.requests, args.max_tokens, args.slots,
                calibrate=args.calibrate, tenants=args.tenants,
                overload=args.overload, max_queue=args.max_queue,
                tenant_quota=args.tenant_quota, ttl=args.ttl)
    terminal = (res["completed"] + res["failed"] + res["shed"]
                + res["expired"])
    ok = (terminal == args.requests
          and (res["completed"] == args.requests
               or args.overload or args.max_queue is not None))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
