"""Parse compiled HLO for collective traffic + combine roofline terms.

``cost_analysis()`` counts a ``lax.scan`` body ONCE regardless of trip
count (verified empirically), so per-cell totals are corrected by lowering
ONE block separately and adding (n_layers − 1) × block_cost per stack
(exact for uniform stacks).  The same correction applies to collective
bytes parsed out of the HLO: collectives inside the scanned body are
counted once by the parser and scaled by the stack depth.

Collective byte accounting (per device): for each all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op we take the output
array bytes and weight by the ring-traffic factor (all-reduce ≈ 2×, others
≈ 1×).  The roofline collective term is per-device bytes / link bandwidth.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = {
    "all-reduce": 2.0,          # ring: 2(n-1)/n ≈ 2×
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[tok_dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, while_multiplier: float = 1.0) -> CollectiveStats:
    """Sum weighted output bytes of collective ops in (optimized) HLO text.

    ``while_multiplier`` scales collectives found inside computations that a
    while loop calls (scan bodies) — pass the stack depth when known.
    HLO computations print as blocks; we detect body computations by their
    name containing "while" or "body" (XLA's scan lowering convention).
    """
    bytes_by: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count_by: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    in_while_body = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # computation headers look like: `%name (param: ...) -> ... {` or `ENTRY`
        if stripped.endswith("{") and ("(" in stripped):
            header = stripped.split("(")[0]
            in_while_body = ("while" in header or "body" in header or
                             "cond" in header) and "ENTRY" not in header
            continue
        for kind, weight in _COLLECTIVES.items():
            # match op occurrence, skipping async -done halves
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                lhs = stripped.split(f" {kind}")[0]
                total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
                mult = while_multiplier if in_while_body else 1.0
                bytes_by[kind] += weight * total * mult
                count_by[kind] += 1
                break
    return CollectiveStats(bytes_by, count_by)


def cost_dict(compiled) -> dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca) if ca else {}


def memory_dict(compiled) -> dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = float(v)
    return out
