"""Pallas TPU kernels for the compute hot-spots.

Each kernel lives in its own subpackage with the mandated layout:

    <name>/kernel.py   pl.pallas_call + explicit BlockSpec VMEM tiling
    <name>/ops.py      jit'd public wrapper (+ CPU interpret fallback)
    <name>/ref.py      pure-jnp oracle used by tests

Kernels:
    branch_gemm       horizontally-fused multi-branch GEMM — the Opara wave
                      (N independent small GEMMs → one MXU-saturating kernel)
    grouped_gemm      ragged-M grouped GEMM (unequal branch row counts, MoE
                      expert fan-out) — scalar-prefetched tile→group table
    flash_attention   causal/windowed GQA flash attention (prefill/train)
    decode_attention  split-KV flash-decoding for single-token decode
    rwkv6             chunked WKV6 recurrence (memory-bound scan)
    moe_gemm          capacity-buffer grouped expert GEMM
    rmsnorm           fused RMSNorm (bandwidth-bound epilogue)

All kernels validate on CPU via ``interpret=True`` and are written for
TPU VMEM tiling (128-aligned MXU tiles, fp32 accumulation).
"""


# In interpret mode (CPU) a Pallas grid is unrolled at trace time; beyond
# this many grid points a non-Pallas fallback (vmap / einsum ref) compiles
# and runs faster.  Shared by the capturer's route decision and the kernel
# wrappers' internal fallbacks so the two can never drift.
INTERPRET_GRID_LIMIT = 64


def interpret_mode() -> bool:
    import jax
    return jax.default_backend() != "tpu"
