"""Paged split-KV flash decoding: one query token against block-table pages.

Same online-softmax structure as ``decode_attention`` (grid walks KV blocks
innermost, fp32 VMEM running max/sum/accumulator), but K/V live in physical
pages addressed through a **scalar-prefetched block table** — the
``PrefetchScalarGridSpec`` pattern of ``grouped_gemm``: the index map of the
K/V operands reads ``bt[b * MAXP + p]`` so the DMA for logical page ``p``
of sequence ``b`` streams the right physical page while page ``p-1``'s
matmul runs.  Nothing is ever gathered into a contiguous slab.

    q:  [B, H, Dk]          k: [P, KVH, ps, Dk]     v: [P, KVH, ps, Dv]
    bt: [B*MAXP] int32      starts, lengths: [B] int32   →   out: [B, H, Dv]

Grid: (B, H, MAXP), pages innermost (sequential accumulation).  Masking is
positional (``starts <= pos < lengths``), so trailing table entries may
point anywhere (the engine points them at the reserved null page 0).
``Dv != Dk`` is supported — the MLA absorbed variant attends latent pages
``[ckv ‖ kpe]`` with ``Dk = rank + rope`` and ``Dv = rank``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, starts_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, page_size: int):
    del bt_ref  # consumed by the K/V index maps
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                       # [1, Dk] row block
    k = k_ref[0, 0]                                    # [ps, Dk]
    v = v_ref[0, 0]                                    # [ps, Dv]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    posn = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                  # absolute positions
    ok = (posn >= starts_ref[b]) & (posn < lengths_ref[b])
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pi == pl.num_programs(2) - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention_pallas(
    q: jax.Array,            # [B, H, Dk]
    k: jax.Array,            # [P, KVH, ps, Dk]
    v: jax.Array,            # [P, KVH, ps, Dv]
    block_tables: jax.Array,  # [B * MAXP] int32 (flattened)
    starts: jax.Array,       # [B] int32
    lengths: jax.Array,      # [B] int32
    scale: float,
    interpret: bool = True,
) -> jax.Array:
    b, h, dk = q.shape
    _, kvh, ps, _ = k.shape
    dv = v.shape[-1]
    groups = h // kvh
    maxp = block_tables.shape[0] // b
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, dk),
                         lambda bb, hh, pp, bt, st, ln: (bb, hh, 0)),
            pl.BlockSpec((1, 1, ps, dk),
                         lambda bb, hh, pp, bt, st, ln, g=groups, mp=maxp:
                         (bt[bb * mp + pp], hh // g, 0, 0)),
            pl.BlockSpec((1, 1, ps, dv),
                         lambda bb, hh, pp, bt, st, ln, g=groups, mp=maxp:
                         (bt[bb * mp + pp], hh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dv),
                               lambda bb, hh, pp, bt, st, ln: (bb, hh, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, scale=scale, page_size=ps)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), starts.astype(jnp.int32),
      lengths.astype(jnp.int32), q, k, v)
