"""Public wrappers for paged decode attention (+ the MLA absorbed variant).

Ladder contract (docs/robustness.md): every fallback taken here is recorded
through :func:`repro.runtime.guard.note_kernel_fallback` — counted on
``kernel_log()``, one ``DegradationWarning`` per site per process.  Both
rungs compute the identical function (tests assert allclose).
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import INTERPRET_GRID_LIMIT, interpret_mode
from ...runtime.guard import note_kernel_fallback
from .kernel import paged_decode_attention_pallas
from .ref import paged_decode_attention_ref


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths,
                           starts=None, scale=None):
    """Engine-layout wrapper: q [B,H,Dk]; pages [P,ps,KVH,Dk|Dv];
    block_tables [B,MAXP]; lengths/starts [B] → [B,H,Dv]."""
    b, h, dk = q.shape
    _, ps, kvh, _ = k_pages.shape
    dv = v_pages.shape[-1]
    maxp = block_tables.shape[1]
    scale = float(dk ** -0.5) if scale is None else float(scale)
    if starts is None:
        starts = jnp.zeros_like(lengths)
    if ps % 128 or dk % 8 or dv % 8 or h % kvh:
        # off-lattice: the page is the kernel's KV tile, so the page size
        # must be a lane multiple (and head dims sublane multiples) to tile
        # the MXU.  Static shapes → fires once per route decision.
        note_kernel_fallback(
            "paged_decode", "pallas->ref",
            f"off-lattice paged shapes ps={ps}, Dk={dk}, Dv={dv}, H={h}, "
            f"KVH={kvh} (need ps%128==0, Dk%8==0, Dv%8==0, H%KVH==0); "
            "gather-einsum reference")
        return paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                                          lengths, starts, scale)
    if interpret_mode() and b * h * maxp > INTERPRET_GRID_LIMIT:
        # interpret mode unrolls the grid at trace time; beyond the shared
        # limit the gather-einsum reference compiles and runs faster (same
        # silent route decision as grouped_gemm's interpret guard).
        return paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                                          lengths, starts, scale)
    try:
        kt = jnp.swapaxes(k_pages, 1, 2)               # [P, KVH, ps, Dk]
        vt = jnp.swapaxes(v_pages, 1, 2)
        return paged_decode_attention_pallas(
            q, kt, vt, block_tables.reshape(-1), starts, lengths,
            scale=scale, interpret=interpret_mode())
    except Exception as exc:  # pragma: no cover - depends on backend
        note_kernel_fallback("paged_decode", "pallas->ref",
                             f"Pallas launch failed: {exc!r}")
        return paged_decode_attention_ref(q, k_pages, v_pages, block_tables,
                                          lengths, starts, scale)


def paged_mla_decode_attention(q_nope, q_pe, ckv_pages, kpe_pages, wk_b,
                               block_tables, lengths, scale):
    """MLA matrix-absorption variant over compressed latent pages — the
    flashinfer-style contract (``deepseek_ma.py``): per-head ``q_nope`` is
    absorbed through ``W_kb`` into latent space, then a single kvh=1 paged
    attention runs against ``[ckv ‖ kpe]`` pages with ``V = ckv``.

        q_nope: [B, H, D_nope]   q_pe: [B, H, D_pe]   wk_b: [rank, H, D_nope]
        ckv_pages: [P, ps, rank]   kpe_pages: [P, ps, D_pe]

    Returns the latent output [B, H, rank] — the caller applies ``W_vb``.
    """
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, wk_b,
                       preferred_element_type=jnp.float32).astype(q_nope.dtype)
    q_cat = jnp.concatenate([q_lat, q_pe], axis=-1)     # [B, H, rank+rope]
    k_cat = jnp.concatenate([ckv_pages, kpe_pages], axis=-1)[:, :, None, :]
    v_lat = ckv_pages[:, :, None, :]                    # [P, ps, 1, rank]
    return paged_decode_attention(q_cat, k_cat, v_lat, block_tables, lengths,
                                  starts=None, scale=scale)
