"""Pure-jnp oracle for paged single-token decode attention.

Layout contract (the engine/page-pool layout — write-friendly scatter at
``(page, offset)``):

    q:            [B, H, Dk]        one query token per sequence
    k_pages:      [P, ps, KVH, Dk]  physical KV pages (page 0 = null page)
    v_pages:      [P, ps, KVH, Dv]  Dv may differ from Dk (MLA latent)
    block_tables: [B, MAXP] int32   logical page i of seq b -> physical page
    lengths:      [B] int32         attendable positions: [starts, lengths)
    starts:       [B] int32 | None  window lower bound (None -> 0)

Out-of-range table entries simply point at the null page; masking is purely
positional, so the gather never needs bounds logic.
"""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decode_attention_ref(q, k_pages, v_pages, block_tables, lengths,
                               starts=None, scale=None):
    """Gather-then-mask einsum reference → [B, H, Dv]."""
    b, h, dk = q.shape
    _, ps, kvh, _ = k_pages.shape
    dv = v_pages.shape[-1]
    maxp = block_tables.shape[1]
    groups = h // kvh
    scale = dk ** -0.5 if scale is None else scale
    k = k_pages[block_tables].reshape(b, maxp * ps, kvh, dk)
    v = v_pages[block_tables].reshape(b, maxp * ps, kvh, dv)
    posn = jnp.arange(maxp * ps)[None, :]                # [1, T']
    valid = posn < lengths[:, None]
    if starts is not None:
        valid &= posn >= starts[:, None]
    # numerics mirror _sdpa (models/attention.py) term for term — bf16
    # operands, fp32 accumulation, probabilities cast back to the value
    # dtype — so paged and dense decode emit identical token streams.
    qg = q.reshape(b, kvh, groups, dk)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, dv).astype(q.dtype)
