from .ops import paged_decode_attention, paged_mla_decode_attention
from .ref import paged_decode_attention_ref

__all__ = ["paged_decode_attention", "paged_mla_decode_attention",
           "paged_decode_attention_ref"]
