"""Public wrappers.  ``flash_attention`` takes [B,H,S,D] layout;
``flash_attention_tpu_or_ref`` adapts the model's [B,S,H,D] layout and
falls back to the reference for non-tileable shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import interpret_mode
from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    b, h, s, d = q.shape
    t = k.shape[2]
    if s % 8 or t % 128 or d % 8:
        return flash_attention_ref(q, k, v, causal, window)
    bq, bk = min(bq, s), min(bk, t)
    while s % bq:
        bq //= 2
    while t % bk:
        bk //= 2
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  bq=bq, bk=bk, interpret=interpret_mode())


def flash_attention_tpu_or_ref(q, k, v, mask):
    """Model-layout adapter: q [B,S,H,D], k/v [B,T,KVH,D], mask [S,T] causal.

    Only exact causal masks route to the kernel; anything else uses the ref.
    """
    s, t = q.shape[1], k.shape[1]
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention(qt, kt, vt, causal=True)
    return jnp.swapaxes(out, 1, 2)
