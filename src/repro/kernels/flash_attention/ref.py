"""Pure-jnp oracle: exact (non-streaming) masked softmax attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: [B,H,S,D]; k,v: [B,KVH,T,D] → [B,H,S,D]."""
    b, h, s, d = q.shape
    kvh, t = k.shape[1], k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, kvh, groups, s, d)
    logits = jnp.einsum("bkgsd,bktd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
