from .ops import flash_attention, flash_attention_tpu_or_ref

__all__ = ["flash_attention", "flash_attention_tpu_or_ref"]
