"""Flash attention (causal / sliding-window, GQA) — prefill & train.

TPU adaptation of the IO-aware attention insight: Q/K/V tiles stream
HBM→VMEM once, online-softmax statistics (m, l) and the output accumulator
live in VMEM scratch across KV tiles.  The KV loop is the innermost
sequential grid dimension so Pallas double-buffers the next KV tile's DMA
under the current tile's MXU work — exactly the compute/memory overlap the
paper schedules at graph level (DESIGN.md §2).

    q: [B, H, S, D]   k,v: [B, KVH, T, D]  →  out: [B, H, S, D]

Grid: (B, H, S/bq, T/bk).  Causal + window masking from absolute tile
positions; fully-masked KV tiles still execute (kernel stays shape-static;
the skip-empty-tiles optimization is a §Perf item).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, bq: int, bk: int, scale: float, causal: bool, window: int):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                    # [bq, D]
    k = k_ref[0, 0]                                    # [bk, D]
    v = v_ref[0, 0]                                    # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                    # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(3) - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(
    q: jax.Array,       # [B, H, S, D]
    k: jax.Array,       # [B, KVH, T, D]
    v: jax.Array,
    causal: bool = True,
    window: int = 0,    # 0 → no window
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, s, d = q.shape
    _, kvh, t, _ = k.shape
    groups = h // kvh
    bq, bk = min(bq, s), min(bk, t)
    assert s % bq == 0 and t % bk == 0
    scale = d ** -0.5
    grid = (b, h, s // bq, t // bk)
    kernel = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                               causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qq, kk, g=groups: (bb, hh // g, kk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, qq, kk, g=groups: (bb, hh // g, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
