from .ops import rwkv6, rwkv6_tpu_or_ref

__all__ = ["rwkv6", "rwkv6_tpu_or_ref"]
