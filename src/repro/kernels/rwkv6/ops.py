"""Public wrappers for the WKV6 recurrence."""
from __future__ import annotations

import jax.numpy as jnp

from .. import interpret_mode
from .kernel import rwkv6_pallas
from .ref import rwkv6_ref


def rwkv6(r, k, v, w, u, s0, ct: int = 64):
    t = r.shape[2]
    if t % 8:
        return rwkv6_ref(r, k, v, w, u, s0)
    ct = min(ct, t)
    while t % ct:
        ct //= 2
    return rwkv6_pallas(r, k, v, w, u, s0, ct=ct, interpret=interpret_mode())


def rwkv6_tpu_or_ref(rh, kh, vh, wh, u, s0):
    """Model-layout adapter: rh/kh/vh/wh [B,T,H,K] → kernel layout [B,H,T,K].
    Returns (y [B,T,H,K], s_final [B,H,K,K])."""
    args = [jnp.swapaxes(a, 1, 2).astype(jnp.float32) for a in (rh, kh, vh, wh)]
    out, s_final = rwkv6(*args, u, s0)
    return jnp.swapaxes(out, 1, 2), s_final
