"""RWKV6 (Finch) WKV recurrence kernel — chunked linear recurrence.

    wkv_t = r_t · (diag(u)·k_tᵀv_t + S_{t-1});  S_t = diag(w_t)·S_{t-1} + k_tᵀv_t

The recurrence is HBM-bandwidth-bound (state S is [K,K] per head, inputs
stream once).  The GPU kernels (RWKV-CUDA) parallelize over (B,H) thread
blocks with S in shared memory; the TPU adaptation keeps S resident in VMEM
scratch across the sequential time-tile grid dimension so HBM traffic is
exactly one read of r/k/v/w and one write of the output — and the per-step
outer products batch into [ct,K]×[K,K] matmuls that keep the MXU busy while
the next time tile DMAs in (the Fig. 3 overlap at kernel scale).

    r,k,v,w: [B, H, T, K] fp32   u: [H, K]   s0: [B, H, K, K]
    → out [B, H, T, K], s_final [B, H, K, K]

Grid: (B, H, T/ct); time tiles innermost, state carried in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref, s_ref,
            *, ct: int):
    t_i = pl.program_id(2)

    @pl.when(t_i == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0]

    u_col = u_ref[0][:, None]                          # [K, 1]
    r = r_ref[0, 0]                                    # [ct, K]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    w = w_ref[0, 0]

    def step(i, carry):
        s = carry                                      # [K, K]
        kt = k[i][:, None]                             # [K, 1]
        vt = v[i][None, :]                             # [1, K]
        kv = kt * vt                                   # [K, K] outer product
        out = jnp.dot(r[i][None, :], u_col * kv + s,
                      preferred_element_type=jnp.float32)  # [1, K]
        o_ref[0, 0, i, :] = out[0]
        return w[i][:, None] * s + kv

    s_ref[...] = jax.lax.fori_loop(0, ct, step, s_ref[...])

    @pl.when(t_i == pl.num_programs(2) - 1)
    def _store():
        sf_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("ct", "interpret"))
def rwkv6_pallas(r, k, v, w, u, s0, ct: int = 64, interpret: bool = True):
    b, h, t, kdim = r.shape
    ct = min(ct, t)
    assert t % ct == 0
    grid = (b, h, t // ct)
    kernel = functools.partial(_kernel, ct=ct)
    io_spec = pl.BlockSpec((1, 1, ct, kdim), lambda bb, hh, tt: (bb, hh, tt, 0))
    state_spec = pl.BlockSpec((1, 1, kdim, kdim), lambda bb, hh, tt: (bb, hh, 0, 0))
    out, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, kdim), lambda bb, hh, tt: (hh, 0)),
            state_spec,
        ],
        out_specs=[io_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, kdim), jnp.float32),
            jax.ShapeDtypeStruct((b, h, kdim, kdim), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kdim, kdim), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return out, s_final
