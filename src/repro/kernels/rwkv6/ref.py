"""Pure-jnp oracle: naive sequential WKV6 recurrence."""
import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, w, u, s0):
    """r,k,v,w: [B,H,T,K] fp32; u: [H,K]; s0: [B,H,K,K].
    Returns (out [B,H,T,K], s_final)."""

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                           # [B,H,K]
        kv = kt[..., :, None] * vt[..., None, :]        # [B,H,K,K]
        out = jnp.einsum("bhk,bhkj->bhj", rt, u[None, :, :, None] * kv + s)
        s = wt[..., :, None] * s + kv
        return s, out

    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (r, k, v, w))
    s_final, outs = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(outs, 0, 2), s_final
