"""Public wrapper for fused RMSNorm."""
from __future__ import annotations

from .. import interpret_mode
from .kernel import rmsnorm_pallas
from .ref import rmsnorm_ref


def rmsnorm(x, scale, eps: float = 1e-6, bn: int = 256):
    """x: [..., d] — leading dims flattened for the kernel."""
    shape = x.shape
    n = 1
    for s in shape[:-1]:
        n *= s
    if n % 8 or shape[-1] % 128:
        return rmsnorm_ref(x, scale, eps)
    x2 = x.reshape(n, shape[-1])
    bn = min(bn, n)
    while n % bn:
        bn //= 2
    out = rmsnorm_pallas(x2, scale, bn=bn, eps=eps, interpret=interpret_mode())
    return out.reshape(shape)
