"""Fused RMSNorm — the canonical memory-bound operator of the suite.

One HBM read + one write per element, fp32 statistics in-register.  In the
Opara launch order these bandwidth-bound ops are interleaved between GEMM
waves so their DMA hides under MXU work; the kernel itself just makes sure
the op runs at line rate (no extra mean/var round trips).

    x: [N, d], scale: [d] → [N, d]

Grid: (N/bn,), full row in VMEM (d ≤ a few K → fits easily).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "eps", "interpret"))
def rmsnorm_pallas(x, scale, bn: int = 256, eps: float = 1e-6,
                   interpret: bool = True):
    n, d = x.shape
    bn = min(bn, n)
    assert n % bn == 0
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
