from .ops import rmsnorm

__all__ = ["rmsnorm"]
