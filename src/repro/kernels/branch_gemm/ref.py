"""Pure-jnp oracle for the fused branch GEMM."""
import jax.numpy as jnp


def branch_gemm_ref(x, w):
    """x: [N,M,K]; w: [N,K,F] → [N,M,F] with fp32 accumulation."""
    return jnp.einsum("nmk,nkf->nmf", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)
