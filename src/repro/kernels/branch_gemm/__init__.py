from .ops import branch_gemm

__all__ = ["branch_gemm"]
