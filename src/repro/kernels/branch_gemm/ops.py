"""Public jit'd wrapper: Pallas on TPU, interpret on CPU, ref fallback for
non-tileable shapes."""
from __future__ import annotations

import jax

from .. import interpret_mode
from .kernel import branch_gemm_pallas
from .ref import branch_gemm_ref


def _tileable(m: int, k: int, f: int) -> bool:
    return m % 8 == 0 and k % 128 == 0 and f % 128 == 0


def branch_gemm(x: jax.Array, w: jax.Array, bm: int = 128, bf: int = 128,
                bk: int = 512) -> jax.Array:
    """Fused N-branch GEMM: [N,M,K] @ [N,K,F] → [N,M,F]."""
    n, m, k = x.shape
    f = w.shape[-1]
    if not _tileable(m, k, f):
        return branch_gemm_ref(x, w)
    bm = min(bm, m)
    bf = min(bf, f)
    bk = min(bk, k)
    while m % bm:
        bm //= 2
    while f % bf:
        bf //= 2
    while k % bk:
        bk //= 2
    return branch_gemm_pallas(x, w, bm=bm, bf=bf, bk=bk,
                              interpret=interpret_mode())
