"""Public jit'd wrapper: Pallas on TPU, interpret on CPU, ref fallback for
non-tileable shapes — and for Pallas lowering failures (real or injected
via the ``kernel_compile`` fault site), since the einsum ref computes the
identical function."""
from __future__ import annotations

import warnings

import jax

from ...runtime.faults import maybe_fire
from ...runtime.guard import DegradationWarning
from .. import interpret_mode
from .kernel import branch_gemm_pallas
from .ref import branch_gemm_ref


def select_tiles(m: int, k: int, f: int, bm: int = 128, bf: int = 128,
                 bk: int = 512) -> tuple[int, int, int] | None:
    """The ONE tile-selection rule for the fused branch GEMM: ``None`` when
    ``(m, k, f)`` is not tileable (the wrapper then runs the einsum ref),
    otherwise the exact ``(bm, bf, bk)`` the kernel will launch with.
    Shared with the capturer's route estimate so the Pallas-vs-vmap
    decision counts the same grid the kernel actually runs."""
    if m % 8 or k % 128 or f % 128:
        return None
    bm, bf, bk = min(bm, m), min(bf, f), min(bk, k)
    while m % bm:
        bm //= 2
    while f % bf:
        bf //= 2
    while k % bk:
        bk //= 2
    return bm, bf, bk


def branch_gemm(x: jax.Array, w: jax.Array, bm: int = 128, bf: int = 128,
                bk: int = 512) -> jax.Array:
    """Fused N-branch GEMM: [N,M,K] @ [N,K,F] → [N,M,F]."""
    n, m, k = x.shape
    f = w.shape[-1]
    tiles = select_tiles(m, k, f, bm, bf, bk)
    if tiles is None:
        return branch_gemm_ref(x, w)
    bm, bf, bk = tiles
    try:
        maybe_fire("kernel_compile")
        return branch_gemm_pallas(x, w, bm=bm, bf=bf, bk=bk,
                                  interpret=interpret_mode())
    except Exception as exc:
        warnings.warn(f"branch_gemm: Pallas launch failed ({exc!r}); "
                      "running the einsum reference",
                      DegradationWarning, stacklevel=2)
        return branch_gemm_ref(x, w)
