"""Horizontally-fused multi-branch GEMM — the Opara wave as ONE kernel.

The paper's streams run N independent small kernels concurrently so the SM
pool stays busy.  On TPU the MXU is one big systolic array, so the same
insight becomes: stack the N independent GEMMs (same M,K,F signature —
Opara's fusion groups guarantee this) into a single ``pallas_call`` whose
grid iterates branches × tiles.  One kernel launch, zero per-branch dispatch,
MXU tiles stay 128-aligned, and the per-branch operand DMA double-buffers
under the previous branch's matmul (compute/memory overlap — paper Fig. 3,
realized by Pallas' automatic pipelining across sequential grid steps).

    x: [N, M, K]   w: [N, K, F]   out: [N, M, F]

Grid: (N, M/bm, F/bf, K/bk) — K innermost so the fp32 VMEM accumulator
carries across K tiles of one (branch, m, f) block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(3) - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bf", "bk", "interpret"))
def branch_gemm_pallas(
    x: jax.Array,
    w: jax.Array,
    bm: int = 128,
    bf: int = 128,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    n, m, k = x.shape
    n2, k2, f = w.shape
    assert (n, k) == (n2, k2), f"shape mismatch {x.shape} @ {w.shape}"
    bm, bf, bk = min(bm, m), min(bf, f), min(bk, k)
    assert m % bm == 0 and f % bf == 0 and k % bk == 0, (
        f"dims ({m},{k},{f}) must tile by ({bm},{bk},{bf})")
    grid = (n, m // bm, f // bf, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda b, i, j, kk: (b, i, kk)),
            pl.BlockSpec((1, bk, bf), lambda b, i, j, kk: (b, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bf), lambda b, i, j, kk: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
