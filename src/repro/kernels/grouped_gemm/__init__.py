from .ops import grouped_gemm

__all__ = ["grouped_gemm"]
