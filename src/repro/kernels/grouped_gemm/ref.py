"""Pure-jnp oracle for the grouped ragged-M GEMM."""
import jax.numpy as jnp


def grouped_gemm_ref(x, w, group_sizes):
    """x: [sum_M, K] rows concatenated per group; w: [N, K, F];
    ``group_sizes``: N static ints summing to sum_M → [sum_M, F] with fp32
    accumulation.  Zero-row groups contribute an empty segment."""
    f = w.shape[-1]
    outs, off = [], 0
    for i, m in enumerate(group_sizes):
        outs.append(jnp.einsum("mk,kf->mf", x[off:off + m], w[i],
                               preferred_element_type=jnp.float32))
        off += m
    if not outs:
        return jnp.zeros((0, f), x.dtype)
    return jnp.concatenate(outs, axis=0).astype(x.dtype)
