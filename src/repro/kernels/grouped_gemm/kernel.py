"""Grouped ragged-M GEMM — unequal parallel branches as ONE kernel.

The hardest Opara wave is the MoE expert fan-out where the parallel
branches have *unequal* token counts: ``branch_gemm`` requires one common
M, so ragged groups used to serialize (or be faked with uniform payloads).
Here the branches' rows are concatenated into one ``[sum_M, K]`` operand —
each group's segment zero-padded up to a multiple of the row tile ``bm`` —
and the grid walks row tiles: every tile knows its group via a prefetched
``tile_group`` table (``PrefetchScalarGridSpec``), so the weight DMA for
group ``g`` streams in while tile ``t-1``'s matmul runs.  One launch, MXU
tiles stay 128-aligned, zero per-branch dispatch — the IOS/Nimble uneven-
branch case executed the way the equal-shape wave already is.

    x: [sum_Mp, K]   w: [N, K, F]   tile_group: [T]   out: [sum_Mp, F]

Grid: (T, F/bf, K/bk) — K innermost so the fp32 VMEM accumulator carries
across K tiles of one (row-tile, f) block.  ``tile_group`` maps row tile →
group index; a zero-row group simply contributes no tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(tg_ref, x_ref, w_ref, o_ref, acc_ref):
    del tg_ref  # consumed by the index maps
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("tile_group", "bm", "bf", "bk",
                                    "interpret"))
def grouped_gemm_pallas(
    x: jax.Array,
    w: jax.Array,
    tile_group: tuple[int, ...],
    bm: int = 128,
    bf: int = 128,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """``x`` must already be padded: row tile ``t`` (rows ``[t*bm, (t+1)*bm)``)
    belongs entirely to group ``tile_group[t]``."""
    mp, k = x.shape
    n, k2, f = w.shape
    assert k == k2, f"shape mismatch {x.shape} @ {w.shape}"
    t = len(tile_group)
    assert mp == t * bm, f"padded rows {mp} != {t} tiles x bm={bm}"
    assert f % bf == 0 and k % bk == 0, (
        f"dims ({k},{f}) must tile by ({bk},{bf})")
    assert all(0 <= g < n for g in tile_group), "tile_group out of range"
    tg = jnp.asarray(tile_group, jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t, f // bf, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, tg: (i, kk)),
            pl.BlockSpec((1, bk, bf), lambda i, j, kk, tg: (tg[i], kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j, kk, tg: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bf), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, f), x.dtype),
        interpret=interpret,
    )(tg, x, w)
