"""Public wrappers for the grouped ragged-M GEMM.

``grouped_gemm_parts`` is the primary entry (the capturer's grouped step
already holds per-branch arrays): each part is zero-padded up to the row
tile and concatenated ONCE into the kernel's padded layout — no
intermediate ``[sum_M, K]`` materialization.  ``grouped_gemm`` is the flat
convenience form over rows concatenated per group.  Non-tileable (K, F) —
or interpret-mode grids too large to unroll — fall back to the einsum
reference, which is still ONE fused op inside the captured program.
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from ...runtime.faults import maybe_fire
from ...runtime.guard import DegradationWarning
from .. import INTERPRET_GRID_LIMIT, interpret_mode
from ..branch_gemm.ops import select_tiles
from .kernel import grouped_gemm_pallas
from .ref import grouped_gemm_ref


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def grouped_gemm_parts(xs: list[jax.Array], w: jax.Array,
                       bm: int = 128, bf: int = 128,
                       bk: int = 512) -> list[jax.Array]:
    """Ragged fused GEMM over per-branch parts: ``xs[i]: [M_i, K]`` against
    ``w: [N, K, F]`` → one ``[M_i, F]`` output per branch.  Row counts are
    static by construction (trace-time shapes); zero-row parts are
    allowed."""
    n, k, f = w.shape
    if len(xs) != n:
        raise ValueError(f"{len(xs)} input parts for {n} groups")
    for x in xs:
        if x.ndim != 2 or x.shape[1] != k:
            raise ValueError(f"part shape {x.shape} != (M_i, K={k})")
    group_sizes = tuple(int(x.shape[0]) for x in xs)
    total = sum(group_sizes)
    if k % 128 or f % 128 or total == 0:
        return [grouped_gemm_ref(x, w[i:i + 1], (m,))
                for i, (x, m) in enumerate(zip(xs, group_sizes))]
    # F/K tiling follows branch_gemm's ONE tile-selection rule; only the
    # row tile is ragged-specific (per-group padding picks it below)
    _, bf, bk = select_tiles(8, k, f, 8, bf, bk)
    m_max = max(group_sizes)
    bm = min(bm, _round_up(m_max, 8))
    tile_group: list[int] = []
    for i, m in enumerate(group_sizes):
        tile_group += [i] * (-(-m // bm))
    grid_points = len(tile_group) * (f // bf) * (k // bk)
    if interpret_mode() and grid_points > INTERPRET_GRID_LIMIT:
        return [grouped_gemm_ref(x, w[i:i + 1], (m,))
                for i, (x, m) in enumerate(zip(xs, group_sizes))]

    # zero-pad each part to a bm multiple and concatenate ONCE — row tiles
    # then never straddle groups (the kernel's tile→group contract)
    segs = []
    for x, m in zip(xs, group_sizes):
        pad = _round_up(m, bm) - m if m else 0
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad, k), x.dtype)], axis=0)
        if x.shape[0]:
            segs.append(x)
    xp = jnp.concatenate(segs, axis=0)
    try:
        maybe_fire("grouped_gemm_route")
        out = grouped_gemm_pallas(xp, w, tuple(tile_group), bm=bm, bf=bf,
                                  bk=bk, interpret=interpret_mode())
    except Exception as exc:
        # Pallas launch failure (real, or injected via the
        # ``grouped_gemm_route`` site): the per-part einsum reference
        # computes the identical function
        warnings.warn(f"grouped_gemm: Pallas launch failed ({exc!r}); "
                      "running the einsum reference",
                      DegradationWarning, stacklevel=2)
        return [grouped_gemm_ref(x, w[i:i + 1], (m,))
                for i, (x, m) in enumerate(zip(xs, group_sizes))]
    # strip the per-group padding rows
    outs, off = [], 0
    for m in group_sizes:
        outs.append(out[off:off + m])
        off += _round_up(m, bm)
    return outs


def grouped_gemm(x: jax.Array, w: jax.Array,
                 group_sizes: tuple[int, ...],
                 bm: int = 128, bf: int = 128, bk: int = 512) -> jax.Array:
    """Flat form: rows ``[sum_M, K]`` (group ``i`` owns the
    ``group_sizes[i]`` rows after groups ``< i``) → ``[sum_M, F]``.
    ``group_sizes`` must be static ints; zero-row groups are allowed."""
    group_sizes = tuple(int(m) for m in group_sizes)
    n, k, f = w.shape
    if len(group_sizes) != n:
        raise ValueError(f"{len(group_sizes)} group sizes for {n} groups")
    if any(m < 0 for m in group_sizes):
        raise ValueError(f"negative group size in {group_sizes}")
    total = sum(group_sizes)
    if x.shape != (total, k):
        raise ValueError(f"x {x.shape} != (sum_M={total}, K={k})")
    if total == 0:
        return jnp.zeros((0, f), x.dtype)
    parts, off = [], 0
    for m in group_sizes:
        parts.append(x[off:off + m])
        off += m
    return jnp.concatenate(grouped_gemm_parts(parts, w, bm=bm, bf=bf, bk=bk),
                           axis=0)
