"""Public wrappers for the grouped expert MLP."""
from __future__ import annotations

from .. import interpret_mode
from .kernel import moe_mlp_pallas
from .ref import moe_mlp_ref


def moe_mlp(buf, gate, up, down, bc: int = 128, bf: int = 256):
    e, c, d = buf.shape
    f = gate.shape[-1]
    if c % 8 or f % 128 or d % 128:
        return moe_mlp_ref(buf, gate, up, down)
    bc, bf = min(bc, c), min(bf, f)
    while c % bc:
        bc //= 2
    while f % bf:
        bf //= 2
    return moe_mlp_pallas(buf, gate, up, down, bc=bc, bf=bf,
                          interpret=interpret_mode())


def moe_mlp_tpu_or_ref(buf, p_experts):
    """Model adapter: p_experts = {gate, up, down} stacked [E, ...]."""
    return moe_mlp(buf, p_experts["gate"], p_experts["up"], p_experts["down"])
