"""Grouped expert GEMM with fused SwiGLU — all expert lanes as ONE kernel.

This is Opara's widest wave (up to 384 parallel expert-FFN operators in
Kimi-K2) executed as a single grouped kernel: the grid iterates
(expert, token-tile, ffn-tile) so every MXU step is a dense 128-aligned
matmul, and per-expert weight DMA pipelines under the previous tile's
compute.  SwiGLU and the down-projection accumulate in VMEM — the
memory-bound epilogue rides under the compute-bound GEMM (paper Fig. 3 at
kernel scale).

    buf:  [E, C, d]     gate/up: [E, d, f]    down: [E, f, d]
    out:  [E, C, d] = (silu(buf@gate) * (buf@up)) @ down

Grid: (E, C/bc, F/bf); the fp32 accumulator [bc, d] carries across F tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, g_ref, u_ref, d_ref, o_ref, acc_ref):
    f_i = pl.program_id(2)

    @pl.when(f_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                       # [bc, d]
    g = g_ref[0]                                       # [d, bf]
    u = u_ref[0]
    dn = d_ref[0]                                      # [bf, d]
    h = jax.nn.silu(jax.lax.dot_general(
        x, g, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32))
    h = h * jax.lax.dot_general(
        x, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        h.astype(dn.dtype), dn, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f_i == pl.num_programs(2) - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "interpret"))
def moe_mlp_pallas(buf, gate, up, down, bc: int = 128, bf: int = 256,
                   interpret: bool = True):
    e, c, d = buf.shape
    f = gate.shape[-1]
    bc, bf = min(bc, c), min(bf, f)
    assert c % bc == 0 and f % bf == 0
    grid = (e, c // bc, f // bf)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, d), lambda ee, cc, ff: (ee, cc, 0)),
            pl.BlockSpec((1, d, bf), lambda ee, cc, ff: (ee, 0, ff)),
            pl.BlockSpec((1, d, bf), lambda ee, cc, ff: (ee, 0, ff)),
            pl.BlockSpec((1, bf, d), lambda ee, cc, ff: (ee, ff, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, d), lambda ee, cc, ff: (ee, cc, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), buf.dtype),
        scratch_shapes=[pltpu.VMEM((bc, d), jnp.float32)],
        interpret=interpret,
    )(buf, gate, up, down)
