from .ops import moe_mlp, moe_mlp_tpu_or_ref

__all__ = ["moe_mlp", "moe_mlp_tpu_or_ref"]
