"""Pure-jnp oracle for the grouped expert SwiGLU MLP."""
import jax
import jax.numpy as jnp


def moe_mlp_ref(buf, gate, up, down):
    """buf: [E,C,d]; gate/up: [E,d,f]; down: [E,f,d] → [E,C,d]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate,
                               preferred_element_type=jnp.float32))
    h = h * jnp.einsum("ecd,edf->ecf", buf, up,
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("ecf,efd->ecd", h.astype(buf.dtype), down,
                     preferred_element_type=jnp.float32)
    return out.astype(buf.dtype)
