from .ops import decode_attention, decode_attention_tpu_or_ref

__all__ = ["decode_attention", "decode_attention_tpu_or_ref"]
