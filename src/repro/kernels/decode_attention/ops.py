"""Public wrappers for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import interpret_mode
from ...runtime.guard import note_kernel_fallback
from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q, k, v, valid, bk: int = 512):
    t, d = k.shape[2], q.shape[-1]
    if t % 128 or d % 8:
        # off-lattice shapes cannot tile the TPU kernel — the einsum ref is
        # the recovery rung.  This fires at trace time (shapes are static),
        # so the count is per route decision, not per decode step.
        note_kernel_fallback(
            "decode_attention", "pallas->ref",
            f"off-lattice decode shapes T={t}, D={d} "
            "(need T%128==0, D%8==0); einsum reference")
        return decode_attention_ref(q, k, v, valid)
    bk = min(bk, t)
    while t % bk:
        bk //= 2
    return decode_attention_pallas(q, k, v, valid, bk=bk,
                                   interpret=interpret_mode())


def decode_attention_tpu_or_ref(q, k_cache, v_cache, valid):
    """Model-layout adapter: q [B,H,D]; caches [B,T,KVH,D]; valid [B,T]."""
    kt = jnp.swapaxes(k_cache, 1, 2)
    vt = jnp.swapaxes(v_cache, 1, 2)
    return decode_attention(q, kt, vt, valid)
