"""Public wrappers for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import interpret_mode
from .kernel import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q, k, v, valid, bk: int = 512):
    t, d = k.shape[2], q.shape[-1]
    if t % 128 or d % 8:
        return decode_attention_ref(q, k, v, valid)
    bk = min(bk, t)
    while t % bk:
        bk //= 2
    return decode_attention_pallas(q, k, v, valid, bk=bk,
                                   interpret=interpret_mode())


def decode_attention_tpu_or_ref(q, k_cache, v_cache, valid):
    """Model-layout adapter: q [B,H,D]; caches [B,T,KVH,D]; valid [B,T]."""
    kt = jnp.swapaxes(k_cache, 1, 2)
    vt = jnp.swapaxes(v_cache, 1, 2)
    return decode_attention(q, kt, vt, valid)
