"""Pure-jnp oracle for single-token decode attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, valid):
    """q: [B,H,D]; k,v: [B,KVH,T,D]; valid: [B,T] bool → [B,H,D]."""
    b, h, d = q.shape
    kvh = k.shape[1]
    groups = h // kvh
    qg = q.reshape(b, kvh, groups, d)
    logits = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * d ** -0.5
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
