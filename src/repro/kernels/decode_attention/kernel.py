"""Split-KV flash decoding: one query token against a long KV cache.

Decode is pure HBM bandwidth (read the whole cache once); the kernel's job
is to stream KV tiles through VMEM at line rate with the online-softmax
epilogue fused (no [T]-sized logits round-trip to HBM).  Validity masking
(cache positions beyond ``pos``/outside the window) comes in as a bool mask
so ring/window policies stay outside the kernel.

    q: [B, H, D]   k,v: [B, KVH, T, D]   valid: [B, T]  →  out: [B, H, D]

Grid: (B, H, T/bk), KV tiles innermost (sequential accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                       # [1, D] row block
    k = k_ref[0, 0]                                    # [bk, D]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # [1,bk]
    valid = valid_ref[...]                             # [1, bk] int32 mask block
    s = jnp.where(valid > 0, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(2) - 1)
    def _store():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(
    q: jax.Array,       # [B, H, D]
    k: jax.Array,       # [B, KVH, T, D]
    v: jax.Array,
    valid: jax.Array,   # [B, T] int32 (1 = attendable)
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, d = q.shape
    _, kvh, t, _ = k.shape
    groups = h // kvh
    bk = min(bk, t)
    assert t % bk == 0
    grid = (b, h, t // bk)
    kernel = functools.partial(_kernel, scale=d ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bb, hh, kk: (bb, hh, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, kk, g=groups: (bb, hh // g, kk, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, kk, g=groups: (bb, hh // g, kk, 0)),
            pl.BlockSpec((1, bk), lambda bb, hh, kk: (bb, kk)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bb, hh, kk: (bb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid.astype(jnp.int32))
