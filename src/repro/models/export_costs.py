"""Shared traced-kernel cost vocabulary for operator-graph exporters.

One home for the cost constructors that model *weight-streaming* GEMMs —
used by both the hand-built paper workloads (``benchmarks/workloads.py``)
and the config-arch exporter (``models/opgraph_export.py``), so bert/t5 and
the 11 assigned architectures price identical stages identically.

DESIGN.md §2: on TPU the weights of a large layer stream into VMEM; a
stream whose transfer time exceeds the kernel floor is an explicitly
schedulable memory op (the scheduler overlaps it with compute — the paper's
compute/memory overlap, Fig. 3), while smaller weights hide behind the
preceding kernel and stay folded into the GEMM cost.
"""
from __future__ import annotations

from ..core.graph import OpCost, OpGraph, OpKind
from ..core.profiler import gemm_cost


def stream_cost(nbytes: float) -> OpCost:
    """Weight-prefetch DMA (HBM→VMEM): pure read traffic, no flops."""
    return OpCost(flops=0.0, bytes_read=float(nbytes), bytes_written=0.0,
                  vmem_bytes=float(min(nbytes, 8 * 2**20)))


def act_gemm_cost(m: int, k: int, n: int, dtype_bytes: int = 2) -> OpCost:
    """GEMM whose weight traffic is carried by a separate stream op: only
    activation bytes count against HBM (the weight sits in VMEM by the time
    the kernel fires)."""
    base = gemm_cost(m, k, n, dtype_bytes)
    return OpCost(flops=base.flops,
                  bytes_read=float(m * k * dtype_bytes),
                  bytes_written=base.bytes_written,
                  vmem_bytes=base.vmem_bytes,
                  occupancy=base.occupancy)


def streamed_ff(g: OpGraph, name: str, inp: int, root: int,
                m: int, k: int, n: int, fuse: tuple | None = None) -> int:
    """FF-projection pair: weight-stream DMA (off the critical path, rooted
    at the graph input so the scheduler may prefetch arbitrarily early) +
    activation-roofline GEMM."""
    w = g.add(f"{name}_wstream", OpKind.GATHER, [root],
              cost=stream_cost(k * n * 2))
    return g.add(name, OpKind.GEMM, [inp, w], cost=act_gemm_cost(m, k, n),
                 fuse_sig=fuse)
