"""Transformer assembly for every assigned architecture family.

Design:
  * one ``init_block``/``block_seq``/``block_step`` triple covering
    dense / MoE / hybrid(Hymba) / ssm(RWKV6) layers;
  * layer parameters are STACKED ``[L, ...]`` and executed with
    ``jax.lax.scan`` (fast compiles at 61-layer production scale);
    non-uniform stacks (DeepSeek/Kimi dense-prefix layers) become two
    sequential scans;
  * per-layer attention windows are data (``window_sizes [L]``), so hybrid
    global/window layers share one scan body;
  * prefill returns stacked KV caches; decode consumes/updates them;
  * optional remat (``jax.checkpoint``) around the scan body for training.

Encoder-decoder (Whisper) and VLM (LLaVA) wrappers live at the bottom.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..utils import shard
from .attention import (
    attn_decode,
    attn_paged_decode,
    attn_prefill,
    init_attention,
    init_cache,
    init_paged_cache,
)
from .ffn import ffn, init_ffn
from .layers import apply_norm, embed, init_embedding, init_norm, unembed
from .ssm import (
    init_mamba,
    init_rwkv_channel_mix,
    init_rwkv_time_mix,
    mamba_seq,
    mamba_state_init,
    rwkv_channel_mix,
    rwkv_state_init,
    rwkv_time_mix_seq,
)

MTP_LOSS_WEIGHT = 0.3


# ============================ block =========================================

def init_block(key, cfg: ModelConfig, layer_kind: str):
    """layer_kind: dense | moe | hybrid | rwkv.  (moe/dense differ in ffn.)"""
    ks = jax.random.split(key, 6)
    if layer_kind == "rwkv":
        return {
            "norm1": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
            "time_mix": init_rwkv_time_mix(ks[0], cfg),
            "norm2": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
            "channel_mix": init_rwkv_channel_mix(ks[1], cfg),
        }
    import dataclasses as _dc
    ffn_cfg = cfg if layer_kind != "dense_prefix" else _dc.replace(cfg, moe=None)
    p = {
        "norm1": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "attn": init_attention(ks[0], cfg),
        "norm2": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "ffn": init_ffn(ks[1], ffn_cfg),
    }
    if layer_kind == "hybrid":
        p["mamba"] = init_mamba(ks[2], cfg)
    return p


def block_seq(p, x, cfg: ModelConfig, positions, window, rng=None,
              use_kernels: bool = False, layer_kind: str = "dense"):
    """Full-sequence block (train / prefill). Returns (x', cache, aux)."""
    if layer_kind == "rwkv":
        state = rwkv_state_init(cfg, x.shape[0])
        y, tm_state = rwkv_time_mix_seq(p["time_mix"], apply_norm(p["norm1"], x, cfg.norm),
                                        (state["tm_x"], state["tm_s"]), cfg, use_kernels)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm)
        y2, cm_x = rwkv_channel_mix(p["channel_mix"], h, state["cm_x"], cfg)
        x = x + y2
        cache = {"tm_x": tm_state[0], "tm_s": tm_state[1], "cm_x": cm_x}
        return x, cache, jnp.float32(0.0)

    h = apply_norm(p["norm1"], x, cfg.norm)
    attn_out, kv = attn_prefill(p["attn"], h, cfg, positions, window, use_kernels)
    if layer_kind == "hybrid":
        m_state = mamba_state_init(cfg, x.shape[0])
        m_out, m_state = mamba_seq(p["mamba"], h, m_state, cfg, use_kernels)
        attn_out = 0.5 * (attn_out + m_out)  # Hymba: mean-fused parallel heads
    x = x + attn_out * cfg.residual_scale
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if layer_kind == "dense_prefix":
        from .ffn import mlp
        f_out, aux = mlp(p["ffn"], h2, cfg.act), {}
    else:
        f_out, aux = ffn(p["ffn"], h2, cfg, rng, use_kernels)
    x = x + f_out * cfg.residual_scale
    cache: Any = kv
    if layer_kind == "hybrid":
        cache = {"kv": kv, "mamba_conv": m_state[0], "mamba_h": m_state[1]}
    aux_loss = aux.get("aux_loss", jnp.float32(0.0)) if isinstance(aux, dict) else jnp.float32(0.0)
    return x, cache, aux_loss


def block_step(p, x, cache, pos, cfg: ModelConfig, window, layer_kind: str = "dense",
               use_kernels: bool = False):
    """Single-token decode. x: [B,1,d]."""
    if layer_kind == "rwkv":
        y, tm_state = rwkv_time_mix_seq(
            p["time_mix"], apply_norm(p["norm1"], x, cfg.norm),
            (cache["tm_x"], cache["tm_s"]), cfg)
        x = x + y
        h = apply_norm(p["norm2"], x, cfg.norm)
        y2, cm_x = rwkv_channel_mix(p["channel_mix"], h, cache["cm_x"], cfg)
        x = x + y2
        return x, {"tm_x": tm_state[0], "tm_s": tm_state[1], "cm_x": cm_x}

    h = apply_norm(p["norm1"], x, cfg.norm)
    if layer_kind == "hybrid":
        kv = cache["kv"]
        attn_out, kv = attn_decode(p["attn"], h, kv, pos, cfg, window, use_kernels)
        m_out, m_state = mamba_seq(p["mamba"], h, (cache["mamba_conv"], cache["mamba_h"]), cfg)
        attn_out = 0.5 * (attn_out + m_out)
        new_cache: Any = {"kv": kv, "mamba_conv": m_state[0], "mamba_h": m_state[1]}
    else:
        attn_out, new_cache = attn_decode(p["attn"], h, cache, pos, cfg, window, use_kernels)
    x = x + attn_out * cfg.residual_scale
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if layer_kind == "dense_prefix":
        from .ffn import mlp
        f_out = mlp(p["ffn"], h2, cfg.act)
    else:
        f_out, _ = ffn(p["ffn"], h2, cfg, None, use_kernels)
    x = x + f_out * cfg.residual_scale
    return x, new_cache


# ============================ stacks ========================================

def layer_kinds(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(kind, n_layers)] groups executed in order (dense-prefix before MoE)."""
    if cfg.family == "ssm":
        return [("rwkv", cfg.n_layers)]
    if cfg.family == "hybrid":
        return [("hybrid", cfg.n_layers)]
    if cfg.moe is not None:
        prefix = cfg_dense_prefix(cfg)
        groups = []
        if prefix:
            groups.append(("dense_prefix", prefix))
        groups.append(("moe", cfg.n_layers - prefix))
        return groups
    return [("dense", cfg.n_layers)]


def cfg_dense_prefix(cfg: ModelConfig) -> int:
    """DeepSeek-V3: first 3 layers dense; Kimi-K2: first layer dense."""
    name = cfg.name.removesuffix("-smoke")
    prefix = {"deepseek-v3-671b": 3, "kimi-k2-1t-a32b": 1}.get(name, 0)
    return min(prefix, max(cfg.n_layers - 1, 0))


def window_for_layer(cfg: ModelConfig, global_index: int) -> int:
    """0 means no window (full attention)."""
    if cfg.window is None:
        return 0
    if global_index in cfg.global_layers:
        return 0
    return cfg.window


def stack_meta(cfg: ModelConfig) -> list[tuple[str, int, tuple[int, ...]]]:
    """Static metadata per stack: (kind, n_layers, window_sizes)."""
    out = []
    base = 0
    for kind, n in layer_kinds(cfg):
        windows = tuple(window_for_layer(cfg, base + i) for i in range(n))
        out.append((kind, n, windows))
        base += n
    return out


def init_stack(key, cfg: ModelConfig):
    """Returns list of stacked param pytrees [n, ...] (pure arrays only —
    kinds/windows are static metadata from :func:`stack_meta`)."""
    stacks = []
    for gi, (kind, n, _) in enumerate(stack_meta(cfg)):
        keys = jax.random.split(jax.random.fold_in(key, gi), n)
        stacks.append(jax.vmap(lambda k: init_block(k, cfg, kind))(keys))
    return stacks


def _scan_seq(stack_params, kind, windows, x, cfg, positions, rng, use_kernels,
              remat, with_cache: bool = True):
    win_arr = jnp.array([w if w > 0 else (1 << 30) for w in windows], jnp.int32)

    def body(carry, xs):
        x, aux = carry
        p_l, win_l, key_l = xs
        x, cache, a = block_seq(p_l, x, cfg, positions, win_l, key_l,
                                use_kernels, kind)
        # training never reads the caches — dropping them here (instead of
        # trusting scan-DCE through jax.checkpoint) saves the full stacked
        # KV allocation.
        return (x, aux + a), (cache if with_cache else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    n = len(windows)
    keys = (jax.random.split(rng, n) if rng is not None
            else jnp.zeros((n,), jnp.uint32))
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.float32(0.0)), (stack_params, win_arr, keys))
    return x, aux, caches


def _scan_step(stack_params, kind, windows, x, caches, pos, cfg, use_kernels=False):
    win_arr = jnp.array([w if w > 0 else (1 << 30) for w in windows], jnp.int32)

    def body(x, xs):
        p_l, win_l, cache_l = xs
        x, new_cache = block_step(p_l, x, cache_l, pos, cfg, win_l, kind, use_kernels)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (stack_params, win_arr, caches))
    return x, new_caches


# ============================ LM facade =====================================

def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    p = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "stacks": init_stack(ks[1], cfg),
        "final_norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = init_embedding(ks[2], cfg.vocab_size, cfg.d_model, cfg.dtype)
    if cfg.meta_tokens:
        p["meta"] = (jax.random.normal(ks[3], (cfg.meta_tokens, cfg.d_model),
                                       jnp.float32) * 0.02).astype(cfg.dtype)
    if cfg.mtp_heads:
        p["mtp"] = {
            "proj": {"w": (jax.random.normal(ks[4], (2 * cfg.d_model, cfg.d_model),
                                             jnp.float32) * (2 * cfg.d_model) ** -0.5
                           ).astype(cfg.dtype)},
            "block": init_block(jax.random.fold_in(ks[4], 1), cfg,
                                "dense" if cfg.moe is None else "moe"),
            "norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        }
    if cfg.frontend is not None:
        fe = cfg.frontend
        from .layers import init_linear
        p["frontend"] = {
            "proj1": init_linear(jax.random.fold_in(ks[3], 2), fe.feat_dim,
                                 cfg.d_model, True, cfg.dtype),
            "proj2": init_linear(jax.random.fold_in(ks[3], 3), cfg.d_model,
                                 cfg.d_model, True, cfg.dtype),
        }
    return p


def _embed_inputs(params, tokens, cfg: ModelConfig, extra_embeds=None):
    """tokens [B,S] (+ optional modality embeds prepended). Returns [B,S',d]."""
    x = embed(params["embed"], tokens)
    if extra_embeds is not None:
        from .layers import gelu, linear
        fe = gelu(linear(params["frontend"]["proj1"], extra_embeds))
        fe = linear(params["frontend"]["proj2"], fe)
        x = jnp.concatenate([fe.astype(x.dtype), x], axis=1)
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(params["meta"][None], (x.shape[0],) + params["meta"].shape)
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    return shard(x, "batch", "seq", "embed")


def lm_forward(params, tokens, cfg: ModelConfig, rng=None, use_kernels=False,
               remat=False, extra_embeds=None, with_cache: bool = True,
               with_logits: bool = True):
    """Training/prefill forward → (logits [B,S',V] fp32, aux_loss, caches).
    ``with_logits=False`` returns the final hidden states instead (used by
    the chunked-CE path that fuses the head matmul into the loss)."""
    x = _embed_inputs(params, tokens, cfg, extra_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux_total = jnp.float32(0.0)
    caches = []
    for stack_params, (kind, _, windows) in zip(params["stacks"], stack_meta(cfg)):
        r = jax.random.fold_in(rng, len(caches)) if rng is not None else None
        x, aux, cache = _scan_seq(stack_params, kind, windows, x, cfg, positions,
                                  r, use_kernels, remat, with_cache)
        aux_total += aux
        caches.append(cache)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if not with_logits:
        return x, aux_total, caches
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(head, x)
    logits = shard(logits, "batch", "seq", "vocab")
    return logits, aux_total, caches


def lm_loss(params, batch, cfg: ModelConfig, rng=None, use_kernels=False, remat=False):
    """Next-token CE (+ MoE aux + MTP). batch: {tokens, labels[, extra_embeds]}."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    from ..flags import chunked_ce
    from .losses import chunked_softmax_xent, softmax_xent
    if chunked_ce():
        # §Perf O3: head matmul fused into a seq-chunked loss — the full
        # [B,S,V] fp32 logits tensor never exists.
        hidden, aux, _ = lm_forward(params, tokens, cfg, rng, use_kernels,
                                    remat, batch.get("extra_embeds"),
                                    with_cache=False, with_logits=False)
        prefix = hidden.shape[1] - labels.shape[1]
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        ce = chunked_softmax_xent(hidden[:, prefix:], head["table"], labels)
    else:
        logits, aux, _ = lm_forward(params, tokens, cfg, rng, use_kernels,
                                    remat, batch.get("extra_embeds"),
                                    with_cache=False)
        # align: logits predict the NEXT token; labels = tokens shifted by 1.
        prefix = logits.shape[1] - labels.shape[1]
        ce = softmax_xent(logits[:, prefix:], labels)
    loss = ce + 0.01 * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_heads:
        mtp_ce = _mtp_loss(params, tokens, labels, cfg)
        loss = loss + MTP_LOSS_WEIGHT * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics


def _mtp_loss(params, tokens, labels, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: one extra block predicting t+2,
    fed by concat(stopgrad-free h, embed(next token)) — simplified single head."""
    x = embed(params["embed"], tokens)
    x_next = embed(params["embed"], labels)             # emb of t+1 stream
    h = jnp.concatenate([x[:, :-1], x_next[:, :-1]], axis=-1)
    h = jnp.einsum("bsd,dk->bsk", h, params["mtp"]["proj"]["w"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kind = "dense" if cfg.moe is None else "moe"
    h, _, _ = block_seq(params["mtp"]["block"], h, cfg, positions, None, None, False, kind)
    h = apply_norm(params["mtp"]["norm"], h, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    from ..utils import shard as _shard
    from .losses import softmax_xent
    logits = _shard(unembed(head, h), "batch", "seq", "vocab")
    return softmax_xent(logits, labels[:, 1:])          # predict t+2


# -- serving ------------------------------------------------------------------

def lm_prefill(params, tokens, cfg: ModelConfig, cache_len: int | None = None,
               use_kernels=False, extra_embeds=None):
    """Prefill → (last-token logits [B,V], caches padded to cache_len)."""
    logits, _, caches = lm_forward(params, tokens, cfg, None, use_kernels,
                                   False, extra_embeds)
    if cache_len is not None and cfg.family not in ("ssm",):
        caches = [_pad_cache(c, cache_len, cfg) for c in caches]
    return logits[:, -1], caches


def _pad_cache(cache, length: int, cfg: ModelConfig):
    def pad(x):
        # KV tensors have the seq axis at position 2 ([L,B,S,...]); states
        # (mamba/rwkv) are position-free and pass through.
        return x

    if cfg.mla is not None and isinstance(cache, tuple):
        c, r = cache
        padw = [(0, 0), (0, 0), (0, length - c.shape[2]), (0, 0)]
        return (jnp.pad(c, padw), jnp.pad(r, padw))
    if isinstance(cache, tuple):
        k, v = cache
        padw = [(0, 0), (0, 0), (0, length - k.shape[2])] + [(0, 0)] * (k.ndim - 3)
        return (jnp.pad(k, padw), jnp.pad(v, padw))
    if isinstance(cache, dict) and "kv" in cache:
        return {**cache, "kv": _pad_cache(cache["kv"], length, cfg)}
    return cache


def init_decode_caches(cfg: ModelConfig, batch: int, length: int):
    """Empty caches shaped for decode (used by dry-run decode cells)."""
    caches = []
    for kind, n, _ in stack_meta(cfg):
        if kind == "rwkv":
            st = rwkv_state_init(cfg, batch)
            caches.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), st))
        else:
            kv = init_cache(cfg, batch, length)
            entry: Any = jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), kv)
            if kind == "hybrid":
                ms = mamba_state_init(cfg, batch)
                entry = {
                    "kv": entry,
                    "mamba_conv": jnp.broadcast_to(ms[0][None], (n,) + ms[0].shape),
                    "mamba_h": jnp.broadcast_to(ms[1][None], (n,) + ms[1].shape),
                }
            caches.append(entry)
    return caches


def block_step_paged(p, x, pages, block_tables, pos, cfg: ModelConfig, window,
                     layer_kind: str = "dense", use_kernels: bool = False):
    """Single-token decode against paged KV. x: [B,1,d]; pages per layer."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    attn_out, new_pages = attn_paged_decode(p["attn"], h, pages, block_tables,
                                            pos, cfg, window, use_kernels)
    x = x + attn_out * cfg.residual_scale
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    if layer_kind == "dense_prefix":
        from .ffn import mlp
        f_out = mlp(p["ffn"], h2, cfg.act)
    else:
        f_out, _ = ffn(p["ffn"], h2, cfg, None, use_kernels)
    x = x + f_out * cfg.residual_scale
    return x, new_pages


def _scan_step_paged(stack_params, kind, windows, x, caches, block_tables, pos,
                     cfg, use_kernels=False):
    win_arr = jnp.array([w if w > 0 else (1 << 30) for w in windows], jnp.int32)

    def body(x, xs):
        p_l, win_l, cache_l = xs
        x, new_cache = block_step_paged(p_l, x, cache_l, block_tables, pos,
                                        cfg, win_l, kind, use_kernels)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (stack_params, win_arr, caches))
    return x, new_caches


def init_paged_decode_caches(cfg: ModelConfig, num_pages: int, page_size: int):
    """Paged KV leaves [L, P, ps, ...] per stack.  Materialized with
    ``jnp.zeros`` (not broadcast) so ``nbytes`` honestly reports the paged
    footprint the serving bench compares against the dense slab."""
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"family {cfg.family!r} carries recurrent state; paged KV "
            "applies only to pure-attention stacks")
    caches = []
    for kind, n, _ in stack_meta(cfg):
        kv = init_paged_cache(cfg, num_pages, page_size)
        caches.append(tuple(jnp.zeros((n,) + x.shape, x.dtype) for x in kv))
    return caches


def lm_paged_decode(params, token, caches, block_tables, pos,
                    cfg: ModelConfig, use_kernels=False):
    """One decode step over paged caches. token/pos: [B] int32;
    block_tables: [B,MAXP] int32 (shared by every layer). → (logits, caches')."""
    x = embed(params["embed"], token[:, None])
    if cfg.meta_tokens:
        pos = pos + cfg.meta_tokens
    new_caches = []
    for stack_params, cache, (kind, _, windows) in zip(
            params["stacks"], caches, stack_meta(cfg)):
        x, cache = _scan_step_paged(stack_params, kind, windows, x, cache,
                                    block_tables, pos, cfg, use_kernels)
        new_caches.append(cache)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(head, x)[:, 0]
    return logits, new_caches


def lm_decode(params, token, caches, pos, cfg: ModelConfig, use_kernels=False):
    """One decode step. token: [B] int32; pos: [B] int32. → (logits, caches')."""
    x = embed(params["embed"], token[:, None])
    if cfg.meta_tokens:
        pos = pos + cfg.meta_tokens
    new_caches = []
    for stack_params, cache, (kind, _, windows) in zip(
            params["stacks"], caches, stack_meta(cfg)):
        x, cache = _scan_step(stack_params, kind, windows, x, cache, pos, cfg,
                              use_kernels)
        new_caches.append(cache)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(head, x)[:, 0]
    return logits, new_caches
