"""Attention: GQA (optionally sliding-window) and MLA (DeepSeek-style),
with prefill and single-token-decode paths and an explicit KV cache.

Kernel dispatch: when ``use_kernels=True`` (and shapes are TPU-tileable) the
prefill path calls the Pallas flash-attention kernel and the decode path the
split-KV decode kernel; otherwise the pure-jnp reference math runs (identical
semantics — tests assert allclose).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig
from ..utils import shard
from .layers import apply_rope, init_linear, linear

NEG_INF = -1e30


# -- masks --------------------------------------------------------------------

def causal_window_mask(q_pos, k_pos, window: int | None):
    """[qs, ks] boolean: causal AND within window (window=None → pure causal)."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


# -- GQA ----------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, h * hd, cfg.qkv_bias, cfg.dtype),
        "wk": init_linear(ks[1], d, kvh * hd, cfg.qkv_bias, cfg.dtype),
        "wv": init_linear(ks[2], d, kvh * hd, cfg.qkv_bias, cfg.dtype),
        "wo": init_linear(ks[3], h * hd, d, False, cfg.dtype),
    }


def _sdpa(q, k, v, mask, use_kernels: bool = False, scale: float | None = None):
    """q: [B,S,H,Dk]; k: [B,T,KVH,Dk]; v: [B,T,KVH,Dv];
    mask: [S,T] or [B,S,T] or None.  Dv may differ from Dk (MLA)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    if use_kernels and mask is not None and mask.ndim == 2 and dv == d:
        from ..kernels.flash_attention.ops import flash_attention_tpu_or_ref
        return flash_attention_tpu_or_ref(q, k, v, mask)
    groups = h // kvh
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, s, kvh, groups, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    logits *= scale
    if mask is not None:
        m = mask if mask.ndim == 2 else mask[:, None, None]
        logits = jnp.where(m, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, dv).astype(q.dtype)


# -- chunked flash-structured attention (pure jnp, production shapes) ---------

_CHUNK_THRESHOLD = 1 << 22        # s*t above which we never materialize [S,T]
# roofline hook: "single" forces one chunk (scan trip=1) so cost_analysis
# counts attention exactly (launch/roofline.py); None = production chunking.
_CHUNK_OVERRIDE: str | None = None


def _pad_axis(x, axis: int, to: int):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pad) if to > x.shape[axis] else x


def _chunk_mask(q_pos, k_pos, t, causal, window_f):
    """[qc,kc] bool from absolute positions. window_f: fp32 scalar (<=0 off)."""
    mask = k_pos[None, :] < t
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    mask &= jnp.where(window_f > 0,
                      k_pos[None, :].astype(jnp.float32)
                      > (q_pos[:, None].astype(jnp.float32) - window_f),
                      True)
    return mask


def _bcast_heads(x, g):
    """[b,t,kvh,d] → [b,t,kvh*g,d]: per-chunk KV broadcast so the attention
    einsums keep ONE head axis (h = kvh·g) that TP shards cleanly.  The g×
    duplication only ever exists for one chunk in VMEM-scale buffers."""
    if g == 1:
        return x
    return jnp.repeat(x, g, axis=2)


def _flash_fwd(q, k, v, window_f, *, causal, scale, qc, kc, t_true):
    """Returns (out [B,S2,H,Dv], lse [b,h,S2]) on padded length S2."""
    b, s2, h, dk = q.shape
    _, t2, kvh, _ = k.shape
    dv = v.shape[-1]
    g = h // kvh
    t = t_true  # padded KV rows (k_pos >= t_true) masked inside _chunk_mask

    nq, nk = s2 // qc, t2 // kc
    qs = jnp.moveaxis(q.reshape(b, nq, qc, h, dk), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, nk, kc, kvh, dk), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kc, kvh, dv), 1, 0)

    from ..flags import causal_skip

    def q_step(_, qi_blk):
        qi, qblk = qi_blk
        q_pos = qi * qc + jnp.arange(qc)

        def kv_work(carry, kj, kblk, vblk):
            m, l, acc = carry
            kb = _bcast_heads(kblk, g)                   # [b,kc,h,dk]
            vb = _bcast_heads(vblk, g)
            k_pos = kj * kc + jnp.arange(kc)
            logits = jnp.einsum("bchd,bthd->bhct", qblk, kb,
                                preferred_element_type=jnp.float32) * scale
            logits = shard(logits, "batch", "heads", None, None)
            mask = _chunk_mask(q_pos, k_pos, t, causal, window_f)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(-1))       # [b,h,qc]
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            pv = jnp.einsum("bhct,bthd->bhcd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * alpha[..., None] + pv
            return m_new, l, acc

        def kv_step(carry, kj_blk):
            kj, kblk, vblk = kj_blk
            if causal and causal_skip():
                # §Perf O5: a KV chunk entirely in the causal future (or
                # entirely outside the window) contributes nothing — skip
                # its matmuls at runtime via cond (≈ halves prefill flops).
                above = kj * kc > qi * qc + (qc - 1)
                below = jnp.logical_and(
                    window_f > 0,
                    (kj + 1) * kc - 1 < qi * qc - window_f + 1)
                skip = jnp.logical_or(above, below)
                carry = jax.lax.cond(
                    skip, lambda c: c,
                    lambda c: kv_work(c, kj, kblk, vblk), carry)
                return carry, None
            return kv_work(carry, kj, kblk, vblk), None

        m0 = jnp.full((b, h, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        a0 = jnp.zeros((b, h, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        l_safe = jnp.maximum(l, 1e-30)
        out = acc / l_safe[..., None]                    # [b,h,qc,dv]
        out = jnp.moveaxis(out, 2, 1)                    # [b,qc,h,dv]
        lse = m + jnp.log(l_safe)                        # [b,h,qc]
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s2, h, dv)
    lse = jnp.moveaxis(lses, 0, 2).reshape(b, h, s2)     # [nq,b,h,qc]→[b,h,S2]
    return out, lse


def _flash_bwd_impl(q, k, v, window_f, out, lse, dout, *, causal, scale, qc, kc,
                    t_true):
    """FlashAttention backward: recompute p per chunk from saved lse.

    Outer scan over KV chunks (yields dk,dv per chunk), inner scan over Q
    chunks (accumulates dq as a carry).  Memory: O(chunk²) per step.
    """
    b, s2, h, dk = q.shape
    _, t2, kvh, _ = k.shape
    dv_dim = v.shape[-1]
    g = h // kvh
    t = t_true
    nq, nk = s2 // qc, t2 // kc

    qs = jnp.moveaxis(q.reshape(b, nq, qc, h, dk), 1, 0)
    dos = jnp.moveaxis(dout.reshape(b, nq, qc, h, dv_dim), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, nk, kc, kvh, dk), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, nk, kc, kvh, dv_dim), 1, 0)
    # D = rowsum(dout ⊙ out) [b,h,S2]
    dsum = jnp.einsum("bshd,bshd->bsh", dout.astype(jnp.float32),
                      out.astype(jnp.float32))
    dsum = jnp.moveaxis(dsum, 1, 2)                      # [b,h,S2]
    dsums = jnp.moveaxis(dsum.reshape(b, h, nq, qc), 2, 0)   # [nq,b,h,qc]
    lses = jnp.moveaxis(lse.reshape(b, h, nq, qc), 2, 0)

    def kv_step(dq_acc, kj_blk):
        kj, kblk, vblk = kj_blk
        kb = _bcast_heads(kblk, g)                       # [b,kc,h,dk]
        vb = _bcast_heads(vblk, g)
        k_pos = kj * kc + jnp.arange(kc)

        def q_step(carry, qi_blk):
            dkj, dvj, dq_acc = carry
            qi, qblk, doblk, lse_i, dsum_i = qi_blk
            q_pos = qi * qc + jnp.arange(qc)
            logits = jnp.einsum("bchd,bthd->bhct", qblk, kb,
                                preferred_element_type=jnp.float32) * scale
            logits = shard(logits, "batch", "heads", None, None)
            mask = _chunk_mask(q_pos, k_pos, t, causal, window_f)
            logits = jnp.where(mask[None, None], logits, NEG_INF)
            p = jnp.exp(logits - lse_i[..., None])       # [b,h,qc,kc]
            dp = jnp.einsum("bchd,bthd->bhct", doblk, vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dsum_i[..., None]) * scale    # [b,h,qc,kc]
            dvj = dvj + jnp.einsum("bhct,bchd->bthd", p.astype(doblk.dtype),
                                   doblk, preferred_element_type=jnp.float32)
            dkj = dkj + jnp.einsum("bhct,bchd->bthd", ds.astype(qblk.dtype),
                                   qblk, preferred_element_type=jnp.float32)
            dq_i = jnp.einsum("bhct,bthd->bchd", ds.astype(kb.dtype), kb,
                              preferred_element_type=jnp.float32)
            dq_acc = dq_acc.at[qi].add(dq_i)
            return (dkj, dvj, dq_acc), None

        dk_h0 = jnp.zeros((b, kc, h, dk), jnp.float32)
        dv_h0 = jnp.zeros((b, kc, h, dv_dim), jnp.float32)
        (dkj, dvj, dq_acc), _ = jax.lax.scan(
            q_step, (dk_h0, dv_h0, dq_acc),
            (jnp.arange(nq), qs, dos, lses, dsums))
        # fold the broadcast heads back onto kv heads
        dkj = dkj.reshape(b, kc, kvh, g, dk).sum(3)
        dvj = dvj.reshape(b, kc, kvh, g, dv_dim).sum(3)
        return dq_acc, (dkj, dvj)

    dq0 = jnp.zeros((nq, b, qc, h, dk), jnp.float32)
    dq_acc, (dks, dvs) = jax.lax.scan(kv_step, dq0, (jnp.arange(nk), ks, vs))
    dq = jnp.moveaxis(dq_acc, 0, 1).reshape(b, s2, h, dk).astype(q.dtype)
    dk_out = jnp.moveaxis(dks, 0, 1).reshape(b, t2, kvh, dk).astype(k.dtype)
    dv_out = jnp.moveaxis(dvs, 0, 1).reshape(b, t2, kvh, dv_dim).astype(v.dtype)
    return dq, dk_out, dv_out


@functools.lru_cache(maxsize=None)
def _make_flash(causal: bool, scale: float, qc: int, kc: int, t_true: int):
    kwargs = dict(causal=causal, scale=scale, qc=qc, kc=kc, t_true=t_true)

    @jax.custom_vjp
    def flash(q, k, v, window_f):
        out, _ = _flash_fwd(q, k, v, window_f, **kwargs)
        return out

    def fwd(q, k, v, window_f):
        out, lse = _flash_fwd(q, k, v, window_f, **kwargs)
        return out, (q, k, v, window_f, out, lse)

    def bwd(res, dout):
        q, k, v, window_f, out, lse = res
        dq, dk, dv = _flash_bwd_impl(q, k, v, window_f, out, lse, dout, **kwargs)
        return dq, dk, dv, jnp.zeros_like(window_f)

    flash.defvjp(fwd, bwd)
    return flash


def chunked_attention(q, k, v, *, causal: bool = True, window=None,
                      scale: float | None = None,
                      q_chunk: int = 2048, kv_chunk: int = 2048):
    """Flash-structured attention in pure jnp with a flash custom-VJP:
    O(S·chunk) memory forward AND backward (p recomputed from saved LSE).
    The jnp twin of the Pallas flash kernel; every production prefill/train
    cell lowers through here (naive attention would claim [S,T] buffers no
    HBM holds).

    q: [B,S,H,Dk]; k: [B,T,KVH,Dk]; v: [B,T,KVH,Dv].  ``window`` may be a
    traced scalar (cast to fp32; <=0 or >=2^29 disables).
    """
    b, s, h, dk = q.shape
    _, t, kvh, _ = k.shape
    scale = dk ** -0.5 if scale is None else scale

    if _CHUNK_OVERRIDE == "single":
        q_chunk, kv_chunk = s, t
    qc = min(q_chunk, s)
    kc = min(kv_chunk, t)
    s2 = -(-s // qc) * qc
    t2 = -(-t // kc) * kc
    qp = _pad_axis(q, 1, s2)
    kp = _pad_axis(k, 1, t2)
    vp = _pad_axis(v, 1, t2)
    if window is None:
        window_f = jnp.float32(0.0)
    else:
        wf = jnp.asarray(window).astype(jnp.float32)
        window_f = jnp.where(wf >= jnp.float32(1 << 29), 0.0, wf)
    flash = _make_flash(causal, float(scale), qc, kc, t)
    return flash(qp, kp, vp, window_f)[:, :s]


def gqa_prefill(p, x, cfg: ModelConfig, positions, window=None, use_kernels=False):
    """Returns (attn_out [B,S,d_model], (k_cache, v_cache) [B,S,KVH,D])."""
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, s, h, hd)
    k = linear(p["wk"], x).reshape(b, s, kvh, hd)
    v = linear(p["wv"], x).reshape(b, s, kvh, hd)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if use_kernels:
        from ..kernels.flash_attention.ops import flash_attention_tpu_or_ref
        out = flash_attention_tpu_or_ref(q, k, v, None)
    elif s * s > _CHUNK_THRESHOLD:
        out = chunked_attention(q, k, v, causal=True, window=window)
    else:
        mask = causal_window_mask(positions[0], positions[0], window)
        out = _sdpa(q, k, v, mask)
    y = linear(p["wo"], out.reshape(b, s, h * hd))
    return shard(y, "batch", "seq", "embed"), (k, v)


def gqa_decode(p, x, cache_kv, pos, cfg: ModelConfig, window=None, use_kernels=False):
    """One-token decode. x: [B,1,d]; cache_kv: (k,v) [B,T,KVH,D]; pos: [B] int.

    Writes the new K/V at ``pos`` and attends over positions <= pos (and
    within the window).  Cache length T is static.
    """
    k_cache, v_cache = cache_kv
    b, t = k_cache.shape[0], k_cache.shape[1]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, 1, h, hd)
    k = linear(p["wk"], x).reshape(b, 1, kvh, hd)
    v = linear(p["wv"], x).reshape(b, 1, kvh, hd)
    if cfg.rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    from ..flags import cache_update_mode
    if cache_update_mode() == "scatter":
        # §Perf O1: scatter writes ONE slot per sequence (aliasable in-place
        # update) instead of the where-select that rewrites the full cache.
        rows = jnp.arange(b)
        k_cache = k_cache.at[rows, pos].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[rows, pos].set(v[:, 0].astype(v_cache.dtype))
    else:
        idx = pos[:, None, None, None]
        onehot = (jnp.arange(t)[None, :, None, None] == idx)
        k_cache = jnp.where(onehot, k.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(onehot, v.astype(v_cache.dtype), v_cache)
    from ..flags import window_slice_decode
    w_static = cfg.window                               # static per-arch bound
    if (window_slice_decode() and w_static is not None
            and w_static + 1 + cfg.meta_tokens < t):
        # §Perf O6: windowed layers read only window+1 cache slots via a
        # per-sequence dynamic slice; global layers (traced window ≥ 2^29)
        # take the full-cache branch of the cond.
        size = w_static + 1

        def windowed(_):
            start = jnp.clip(pos - w_static, 0, t - size)      # [B]
            ks = jax.vmap(lambda c, s0: jax.lax.dynamic_slice_in_dim(
                c, s0, size, axis=0))(k_cache, start)          # [B,size,KVH,D]
            vs = jax.vmap(lambda c, s0: jax.lax.dynamic_slice_in_dim(
                c, s0, size, axis=0))(v_cache, start)
            k_pos_w = start[:, None] + jnp.arange(size)[None]  # [B,size]
            ok = (k_pos_w <= pos[:, None]) & (k_pos_w > (pos[:, None] - w_static))
            return _sdpa(q, ks, vs, ok[:, None, :])

        def full(_):
            k_pos = jnp.arange(t)[None, :]
            ok = k_pos <= pos[:, None]
            ok &= k_pos > (pos[:, None] - window)
            return _sdpa(q, k_cache, v_cache, ok[:, None, :])

        is_windowed = window < jnp.int32(1 << 29)
        out = jax.lax.cond(is_windowed, windowed, full, operand=None)
    else:
        k_pos = jnp.arange(t)[None, :]                  # [1,T]
        valid = k_pos <= pos[:, None]
        if window is not None:
            valid &= k_pos > (pos[:, None] - window)
        if use_kernels:
            from ..kernels.decode_attention.ops import decode_attention_tpu_or_ref
            out = decode_attention_tpu_or_ref(q[:, 0], k_cache, v_cache, valid)
            out = out[:, None]
        else:
            out = _sdpa(q, k_cache, v_cache, valid[:, None, :])  # [b,s=1,t]
    y = linear(p["wo"], out.reshape(b, 1, h * hd))
    return y, (k_cache, v_cache)


# -- MLA (DeepSeek-V3) --------------------------------------------------------

def init_mla(key, cfg: ModelConfig):
    m = cfg.mla
    assert m is not None
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": init_linear(ks[0], d, m.q_lora_rank, False, cfg.dtype),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), cfg.dtype)},
        "wq_b": init_linear(ks[1], m.q_lora_rank, h * qk_head, False, cfg.dtype),
        "wkv_a": init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, False, cfg.dtype),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), cfg.dtype)},
        "wk_b": init_linear(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, False, cfg.dtype),
        "wv_b": init_linear(ks[4], m.kv_lora_rank, h * m.v_head_dim, False, cfg.dtype),
        "wo": init_linear(ks[5], h * m.v_head_dim, d, False, cfg.dtype),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions):
    """Shared projection math. Returns q_nope,q_rope,c_kv,k_rope."""
    from .layers import rmsnorm
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(p["q_norm"], linear(p["wq_a"], x))
    q = linear(p["wq_b"], cq).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    kv = linear(p["wkv_a"], x)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_norm"], c_kv)                  # [B,S,rank]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, q_nope, q_rope, c_kv, k_rope, cfg: ModelConfig,
                  mask=None, chunked: bool = False):
    """Latent attention via the absorbed formulation: MLA ≡ GQA with ONE
    shared latent KV head.

    q_lat = q_nope @ W_kbᵀ (per head, absorbed so the cache stays
    compressed); q_cat = [q_lat ‖ q_rope] against k_cat = [c_kv ‖ k_rope]
    with V = c_kv — a single kvh=1 attention with Dk = rank+rope, Dv = rank.
    This routes MLA through the exact same naive/chunked/flash machinery as
    GQA (and the chunked path keeps 32k×32k cells O(S·chunk)).
    """
    m = cfg.mla
    b, s, h, _ = q_nope.shape
    rank = m.kv_lora_rank
    wk_b = p["wk_b"]["w"].reshape(rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b,
                       preferred_element_type=jnp.float32).astype(q_nope.dtype)
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)       # [B,S,H,rank+rope]
    k_cat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
    v_lat = c_kv[:, :, None, :]                             # [B,T,1,rank]
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    if chunked:
        lat = chunked_attention(q_cat, k_cat, v_lat, causal=True, window=None,
                                scale=scale)
    else:
        lat = _sdpa(q_cat, k_cat, v_lat, mask, scale=scale)  # [B,S,H,rank]
    wv_b = p["wv_b"]["w"].reshape(rank, h, m.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", lat, wv_b,
                     preferred_element_type=jnp.float32).astype(c_kv.dtype)
    return linear(p["wo"], out.reshape(b, s, h * m.v_head_dim))


def mla_prefill(p, x, cfg: ModelConfig, positions, use_kernels=False):
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions)
    s = x.shape[1]
    if s * s > _CHUNK_THRESHOLD:
        y = mla_attention(p, q_nope, q_rope, c_kv, k_rope, cfg, chunked=True)
    else:
        mask = causal_window_mask(positions[0], positions[0], None)
        y = mla_attention(p, q_nope, q_rope, c_kv, k_rope, cfg, mask=mask)
    return shard(y, "batch", "seq", "embed"), (c_kv, k_rope)


def mla_decode(p, x, cache, pos, cfg: ModelConfig, use_kernels=False):
    from ..flags import cache_update_mode, kv_quant
    quant = kv_quant() and len(cache) == 3
    if quant:
        c_q, c_scale, r_cache = cache   # int8 [B,T,rank], f16 [B,T], bf16 rope
        b, t = c_q.shape[0], c_q.shape[1]
    else:
        c_cache, r_cache = cache                        # [B,T,rank], [B,T,rope]
        b, t = c_cache.shape[0], c_cache.shape[1]
    q_nope, q_rope, c_new, r_new = _mla_qkv(p, x, cfg, pos[:, None])
    rows = jnp.arange(b)
    if quant:
        # quantize the new latent token: per-token absmax scale
        scale_new = jnp.maximum(jnp.max(jnp.abs(c_new[:, 0]), axis=-1), 1e-6)
        c_new_q = jnp.clip(jnp.round(c_new[:, 0] / scale_new[:, None] * 127.0),
                           -127, 127).astype(jnp.int8)
        c_q = c_q.at[rows, pos].set(c_new_q)
        c_scale = c_scale.at[rows, pos].set((scale_new / 127.0).astype(jnp.float16))
        r_cache = r_cache.at[rows, pos].set(r_new[:, 0].astype(r_cache.dtype))
        c_cache = (c_q.astype(jnp.bfloat16)
                   * c_scale[..., None].astype(jnp.bfloat16))
        new_cache = (c_q, c_scale, r_cache)
    elif cache_update_mode() == "scatter":
        c_cache = c_cache.at[rows, pos].set(c_new[:, 0].astype(c_cache.dtype))
        r_cache = r_cache.at[rows, pos].set(r_new[:, 0].astype(r_cache.dtype))
        new_cache = (c_cache, r_cache)
    else:
        onehot2 = (jnp.arange(t)[None, :, None] == pos[:, None, None])
        c_cache = jnp.where(onehot2, c_new.astype(c_cache.dtype), c_cache)
        r_cache = jnp.where(onehot2, r_new.astype(r_cache.dtype), r_cache)
        new_cache = (c_cache, r_cache)
    valid = jnp.arange(t)[None, :] <= pos[:, None]      # [B,T]
    y = mla_attention(p, q_nope, q_rope, c_cache, r_cache, cfg,
                      mask=valid[:, None, :])           # [B,1,T] = [b,s,t]
    return y, new_cache


# -- paged decode (block-table KV) --------------------------------------------

def gqa_paged_decode(p, x, pages, block_tables, pos, cfg: ModelConfig,
                     window=None, use_kernels=False):
    """One-token decode against paged KV. x: [B,1,d]; pages: (k,v)
    [P,ps,KVH,D]; block_tables: [B,MAXP] int32; pos: [B] int.

    Writes the new K/V at ``(table[pos//ps], pos%ps)`` and attends positions
    ``[max(0, pos-window+1), pos]`` through the block table — there is no
    per-sequence dense slab.  ``window`` may be the traced sentinel
    (>= 2^29 disables): the start clamp maps it to 0.
    """
    k_pages, v_pages = pages
    ps = k_pages.shape[1]
    b = x.shape[0]
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(b, 1, h, hd)
    k = linear(p["wk"], x).reshape(b, 1, kvh, hd)
    v = linear(p["wv"], x).reshape(b, 1, kvh, hd)
    if cfg.rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    rows = jnp.arange(b)
    page = block_tables[rows, pos // ps]                # [B] physical pages
    off = pos % ps
    k_pages = k_pages.at[page, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[page, off].set(v[:, 0].astype(v_pages.dtype))
    lengths = (pos + 1).astype(jnp.int32)
    starts = None
    if window is not None:
        starts = jnp.clip(pos - window + 1, 0).astype(jnp.int32)
    if use_kernels:
        from ..kernels.paged_decode.ops import paged_decode_attention
        out = paged_decode_attention(q[:, 0], k_pages, v_pages, block_tables,
                                     lengths, starts)
    else:
        from ..kernels.paged_decode.ref import paged_decode_attention_ref
        out = paged_decode_attention_ref(q[:, 0], k_pages, v_pages,
                                         block_tables, lengths, starts)
    y = linear(p["wo"], out.reshape(b, 1, h * hd))
    return y, (k_pages, v_pages)


def mla_paged_decode(p, x, pages, block_tables, pos, cfg: ModelConfig,
                     use_kernels=False):
    """Paged MLA decode over latent pages (ckv [P,ps,rank], kpe [P,ps,rope])
    via matrix absorption — see :func:`mla_attention` for the math."""
    m = cfg.mla
    ckv_pages, kpe_pages = pages
    ps = ckv_pages.shape[1]
    b = x.shape[0]
    h, rank = cfg.n_heads, m.kv_lora_rank
    q_nope, q_rope, c_new, r_new = _mla_qkv(p, x, cfg, pos[:, None])
    rows = jnp.arange(b)
    page = block_tables[rows, pos // ps]
    off = pos % ps
    ckv_pages = ckv_pages.at[page, off].set(c_new[:, 0].astype(ckv_pages.dtype))
    kpe_pages = kpe_pages.at[page, off].set(r_new[:, 0].astype(kpe_pages.dtype))
    lengths = (pos + 1).astype(jnp.int32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    wk_b = p["wk_b"]["w"].reshape(rank, h, m.qk_nope_head_dim)
    if use_kernels:
        from ..kernels.paged_decode.ops import paged_mla_decode_attention
        lat = paged_mla_decode_attention(q_nope[:, 0], q_rope[:, 0], ckv_pages,
                                         kpe_pages, wk_b, block_tables,
                                         lengths, scale)
    else:
        from ..kernels.paged_decode.ref import paged_decode_attention_ref
        q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], wk_b,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        q_cat = jnp.concatenate([q_lat, q_rope[:, 0]], axis=-1)
        k_cat = jnp.concatenate([ckv_pages, kpe_pages], axis=-1)[:, :, None, :]
        lat = paged_decode_attention_ref(q_cat, k_cat, ckv_pages[:, :, None, :],
                                         block_tables, lengths, None, scale)
    wv_b = p["wv_b"]["w"].reshape(rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", lat, wv_b,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    y = linear(p["wo"], out.reshape(b, 1, h * m.v_head_dim))
    return y, (ckv_pages, kpe_pages)


def attn_paged_decode(p, x, pages, block_tables, pos, cfg, window=None,
                      use_kernels=False):
    if cfg.mla is not None:
        return mla_paged_decode(p, x, pages, block_tables, pos, cfg, use_kernels)
    return gqa_paged_decode(p, x, pages, block_tables, pos, cfg, window,
                            use_kernels)


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int, dtype=None):
    """Single-layer paged KV pages (page 0 reserved as the null page).

    MLA always pages the *compressed* latent cache (no int8 variant — the
    engine gates ``kv_quant`` off the paged path)."""
    dtype = dtype or cfg.dtype
    if cfg.mla is not None:
        m = cfg.mla
        return (jnp.zeros((num_pages, page_size, m.kv_lora_rank), dtype),
                jnp.zeros((num_pages, page_size, m.qk_rope_head_dim), dtype))
    return (jnp.zeros((num_pages, page_size, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((num_pages, page_size, cfg.n_kv_heads, cfg.head_dim), dtype))


# -- dispatch -----------------------------------------------------------------

def init_attention(key, cfg: ModelConfig):
    return init_mla(key, cfg) if cfg.mla is not None else init_gqa(key, cfg)


def attn_prefill(p, x, cfg, positions, window=None, use_kernels=False):
    if cfg.mla is not None:
        return mla_prefill(p, x, cfg, positions, use_kernels)
    return gqa_prefill(p, x, cfg, positions, window, use_kernels)


def attn_decode(p, x, cache, pos, cfg, window=None, use_kernels=False):
    if cfg.mla is not None:
        return mla_decode(p, x, cache, pos, cfg, use_kernels)
    return gqa_decode(p, x, cache, pos, cfg, window, use_kernels)


def init_cache(cfg: ModelConfig, batch: int, length: int, dtype=None):
    """Empty per-layer KV cache (single layer); transformer stacks [L, ...]."""
    dtype = dtype or cfg.dtype
    if cfg.mla is not None:
        m = cfg.mla
        from ..flags import kv_quant
        if kv_quant():
            # §Perf O8: int8 latent + per-token fp16 scale (+ bf16 rope keys)
            return (jnp.zeros((batch, length, m.kv_lora_rank), jnp.int8),
                    jnp.zeros((batch, length), jnp.float16),
                    jnp.zeros((batch, length, m.qk_rope_head_dim), dtype))
        return (jnp.zeros((batch, length, m.kv_lora_rank), dtype),
                jnp.zeros((batch, length, m.qk_rope_head_dim), dtype))
    return (jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype),
            jnp.zeros((batch, length, cfg.n_kv_heads, cfg.head_dim), dtype))
