"""Export a model's block structure as an Opara :class:`OpGraph`.

This is the bridge between the substrate and the paper's contribution: the
per-layer operator DAG (QKV branches, gate∥up, expert fan-out, attn∥mamba,
RWKV's 5 token-shift projections, …) is emitted with analytic costs so the
Stream Allocator / Operator Launcher schedule REAL model topologies, and the
Graph Capturer can execute them (used by benchmarks + examples with
smoke-size weights).

Payload functions close over concrete weights when ``params`` is given;
otherwise nodes are cost-only (for scheduling/simulation at production
scale, where we never allocate).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.graph import OpGraph, OpKind
from ..core.profiler import (
    attention_cost,
    elementwise_cost,
    gather_cost,
    gemm_cost,
    norm_cost,
    scan_cost,
)
from .transformer import stack_meta


def _w(params, *path):
    if params is None:
        return None
    out = params
    for p in path:
        out = out[p]
    return out


def build_lm_opgraph(cfg: ModelConfig, batch: int, seq: int,
                     params: Any = None, n_layers: int | None = None,
                     moe_branch_cap: int = 16,
                     moe_dispatch: str = "auto") -> OpGraph:
    """Operator DAG of an LM forward pass (prefill semantics).

    ``n_layers`` trims depth (graph-size control for schedulers/benchmarks);
    MoE fan-out is capped at ``moe_branch_cap`` expert branches per layer.

    ``moe_dispatch`` picks the MoE block structure: ``"uniform"`` emits the
    historical cost-only fan-out (equal-FLOP expert branches, scatter
    dispatch/combine without payloads); ``"ragged"`` emits the routed
    fan-out — real router → per-expert token gathers with *unequal* static
    capacities → grouped ragged-M expert GEMMs → weighted scatter-add
    combine — executable end to end whenever ``params`` is threaded.
    ``"auto"`` (default) uses ragged with params and uniform without, so
    cost-only scheduling benchmarks keep their historical topology.
    """
    if moe_dispatch not in ("auto", "ragged", "uniform"):
        raise ValueError(f"unknown moe_dispatch {moe_dispatch!r}")
    g = OpGraph(cfg.name)
    d, dt = cfg.d_model, 2
    b, s = batch, seq
    L = n_layers if n_layers is not None else cfg.n_layers

    def fn_or_none(f):
        return f if params is not None else None

    x = g.add("tokens", OpKind.INPUT, out_shape=(b, s))
    emb_w = _w(params, "embed", "table")
    x = g.add("embed", OpKind.GATHER, [x],
              fn=fn_or_none(lambda t: jnp.take(emb_w, t, axis=0)),
              cost=gather_cost(b * s, d), out_shape=(b, s, d))

    meta = stack_meta(cfg)
    layer_idx = 0
    for si, (kind, n, windows) in enumerate(meta):
        for li in range(min(n, max(L - layer_idx, 0))):
            tag = f"L{layer_idx}"
            pl = (jax.tree_util.tree_map(lambda a: a[li], _w(params, "stacks")[si])
                  if params is not None else None)
            if kind == "rwkv":
                x = _rwkv_layer(g, cfg, x, b, s, tag, pl)
            elif kind == "hybrid":
                x = _hybrid_layer(g, cfg, x, b, s, tag, pl,
                                  windows[li] or s)
            elif kind in ("moe",):
                x = _dense_layer(g, cfg, x, b, s, tag, pl, moe=True,
                                 moe_branch_cap=moe_branch_cap,
                                 moe_dispatch=moe_dispatch)
            else:
                x = _dense_layer(g, cfg, x, b, s, tag, pl, moe=False)
            layer_idx += 1
    fn = _w(params, "final_norm")
    x = g.add("final_norm", OpKind.NORM, [x],
              fn=fn_or_none(lambda h: _rms(fn, h)),
              cost=norm_cost(b * s * d))
    head = _w(params, "embed" if cfg.tie_embeddings else "head")
    g.add("logits", OpKind.GEMM, [x],
          fn=fn_or_none(lambda h: jnp.einsum("bsd,vd->bsv", h, head["table"])),
          cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rms(p, h, eps=1e-6):
    hf = h.astype(jnp.float32)
    v = jnp.mean(hf * hf, -1, keepdims=True)
    return (hf * jax.lax.rsqrt(v + eps) * p["scale"].astype(jnp.float32)).astype(h.dtype)


def _lin(p, h):
    return jnp.einsum("...i,io->...o", h, p["w"]) + (p.get("b", 0) if p else 0)


def _matmul(h, w):
    return jnp.einsum("...i,io->...o", h, w)


def _matmul_bias(h, w, bias):
    return jnp.einsum("...i,io->...o", h, w) + bias


def _gemm_node(g, name, inp, pl_linear, m, k, n, bias: bool = False,
               cost=None, fuse_sig=None, out_shape=None):
    """GEMM node following the capture contract: weights go in
    meta["consts"] so same-signature branches stack into one fused kernel.

    EVERY GEMM-semantics node the exporter emits goes through here — expert
    fan-out and conv-like frontend projections included — so whenever a
    weight is threaded the node automatically carries ``payload="matmul"``,
    the capturer's routing contract for the fused branch_gemm Pallas kernel
    (no hand-placed markers).  ``cost`` / ``fuse_sig`` override the
    defaults for nodes whose analytic cost is not the plain (m, k, n)
    roofline (e.g. capacity-scaled expert branches).
    """
    cost = cost if cost is not None else gemm_cost(m, k, n)
    fuse_sig = fuse_sig if fuse_sig is not None else ("gemm", k, n, bias)
    if pl_linear is None:
        return g.add(name, OpKind.GEMM, [inp], cost=cost, fuse_sig=fuse_sig,
                     out_shape=out_shape)
    if isinstance(pl_linear, dict):
        consts = (pl_linear["w"],) + ((pl_linear["b"],) if bias else ())
    else:  # a bare weight array (expert slices) — carries no bias term
        assert not bias, f"{name}: bare-array weight cannot supply a bias"
        consts = (pl_linear,)
    return g.add(name, OpKind.GEMM, [inp],
                 fn=_matmul_bias if bias else _matmul,
                 cost=cost, fuse_sig=fuse_sig, consts=consts,
                 out_shape=out_shape, payload="matmul")


def _dense_layer(g, cfg, x, b, s, tag, pl, moe: bool, moe_branch_cap: int = 16,
                 moe_dispatch: str = "auto"):
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    bias = cfg.qkv_bias
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x],
               fn=(lambda h: _rms(pl["norm1"], h)) if pl else None,
               cost=norm_cost(b * s * d))
    attn_p = pl["attn"] if pl else None
    if pl is not None and cfg.mla is not None:
        # MLA params carry low-rank factors (wq_a/wq_b/wkv_a/...), not the
        # separate wq/wk/wv the branch structure below expects — run the
        # whole latent attention (wo included) as one payload node.  The
        # node's cost must carry the folded-in projection GEMMs too, or the
        # layer's dominant FLOPs vanish from the scheduler's view.
        m = cfg.mla
        qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
        o = g.add(f"{tag}.attn", OpKind.ATTENTION, [n1],
                  fn=lambda h: _mla_payload(cfg, attn_p, h),
                  cost=_sum_costs(
                      attention_cost(b, s, s, nh, hd, kvh),
                      gemm_cost(b * s, d, m.q_lora_rank),
                      gemm_cost(b * s, m.q_lora_rank, nh * qk_head),
                      gemm_cost(b * s, d, m.kv_lora_rank + m.qk_rope_head_dim),
                      gemm_cost(b * s, nh * m.v_head_dim, d)))
    else:
        # QKV: 3 parallel GEMM branches (the canonical Opara wave)
        q = _gemm_node(g, f"{tag}.wq", n1, attn_p and attn_p["wq"], b * s, d, nh * hd, bias)
        k = _gemm_node(g, f"{tag}.wk", n1, attn_p and attn_p["wk"], b * s, d, kvh * hd, bias)
        v = _gemm_node(g, f"{tag}.wv", n1, attn_p and attn_p["wv"], b * s, d, kvh * hd, bias)
        att = g.add(f"{tag}.attn", OpKind.ATTENTION, [q, k, v],
                    fn=(lambda qq, kk, vv: _attn_payload(cfg, qq, kk, vv)) if pl else None,
                    cost=attention_cost(b, s, s, nh, hd, kvh))
        o = _gemm_node(g, f"{tag}.wo", att, attn_p and attn_p["wo"], b * s, nh * hd, d, False)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1],
               fn=(lambda h: _rms(pl["norm2"], h)) if pl else None,
               cost=norm_cost(b * s * d))
    if not moe:
        dff = cfg.d_ff
        ffn_p = pl["ffn"] if pl else None
        gate = _gemm_node(g, f"{tag}.gate", n2, ffn_p and ffn_p["gate"],
                          b * s, d, dff, False)
        up = _gemm_node(g, f"{tag}.up", n2, ffn_p and ffn_p["up"],
                        b * s, d, dff, False)
        prod = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                     fn=(lambda a, c: jax.nn.silu(a) * c) if pl else None,
                     cost=elementwise_cost(b * s * dff, n_in=2, flops_per_elem=5))
        down = _gemm_node(g, f"{tag}.down", prod, ffn_p and ffn_p["down"],
                          b * s, dff, d, False)
    elif moe_dispatch == "ragged" or (moe_dispatch == "auto" and pl is not None):
        down = _moe_ragged_block(g, cfg, n2, b, s, tag,
                                 pl["ffn"] if pl else None, moe_branch_cap)
    else:
        e = cfg.moe
        moe_p = pl["ffn"] if pl else None
        router = g.add(f"{tag}.router", OpKind.REDUCE, [n2],
                       cost=gemm_cost(b * s, d, e.n_experts))
        disp = g.add(f"{tag}.dispatch", OpKind.SCATTER, [n2, router],
                     cost=gather_cost(b * s * e.top_k, d))
        nb = min(e.n_experts, moe_branch_cap)
        tok_per_branch = b * s * e.top_k / e.n_experts * (e.n_experts / nb)
        outs = []
        for j in range(nb):
            # per-branch expert weight from the stacked [E, d, d_e] params:
            # gate|up|downᵀ concatenated to [d, 3·d_e], so the x@w payload
            # performs exactly the FLOPs the analytic cost models (one
            # [d → 3·d_e] GEMM per branch) and the branch carries the matmul
            # marker, stacking with its siblings into ONE fused branch_gemm
            # kernel at capture.  Params-threaded exports are smoke-size by
            # construction, so the concat allocation is negligible.
            ew = (jnp.concatenate(
                      [moe_p["experts"]["gate"][j],
                       moe_p["experts"]["up"][j],
                       moe_p["experts"]["down"][j].T], axis=1)
                  if moe_p is not None else None)
            eb = _gemm_node(g, f"{tag}.expert{j}", disp, ew,
                            int(tok_per_branch), d, 3 * e.d_expert,
                            fuse_sig=("egemm", d, e.d_expert))
            outs.append(eb)
        if e.n_shared:
            sp = (moe_p["shared"]
                  if moe_p is not None and "shared" in moe_p else None)
            sw = (jnp.concatenate([sp["gate"]["w"], sp["up"]["w"],
                                   sp["down"]["w"].T], axis=1)
                  if sp is not None else None)
            outs.append(_gemm_node(g, f"{tag}.shared_expert", n2, sw,
                                   b * s, d, 3 * e.d_expert * e.n_shared))
        down = g.add(f"{tag}.combine", OpKind.SCATTER, outs + [router],
                     cost=gather_cost(b * s * e.top_k, d))
    out = g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                fn=(lambda a, c: a + c) if pl else None,
                cost=elementwise_cost(b * s * d, n_in=2))
    return out


def _attn_payload(cfg, q, k, v):
    from .attention import _sdpa, causal_window_mask
    b, s = q.shape[0], q.shape[1]
    nh, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qh = q.reshape(b, s, nh, hd)
    kh = k.reshape(b, s, kvh, hd)
    vh = v.reshape(b, s, kvh, hd)
    pos = jnp.arange(s)
    mask = causal_window_mask(pos, pos, None)
    return _sdpa(qh, kh, vh, mask).reshape(b, s, nh * hd)


def _mla_payload(cfg, p, h):
    from .attention import mla_prefill
    b, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return mla_prefill(p, h, cfg, positions)[0]


def _sum_costs(*costs):
    """Combine analytic costs of ops folded into one node: traffic and
    FLOPs add; working set and occupancy are bounded by the widest phase."""
    from ..core.graph import OpCost
    occ = [c.occupancy for c in costs if c.occupancy is not None]
    return OpCost(
        flops=sum(c.flops for c in costs),
        bytes_read=sum(c.bytes_read for c in costs),
        bytes_written=sum(c.bytes_written for c in costs),
        vmem_bytes=max(c.vmem_bytes for c in costs),
        occupancy=max(occ) if occ else None)


# -- routed (ragged) MoE fan-out ---------------------------------------------
#
# The dispatch/combine payloads both recompute the routing decision from the
# router node's logits — pure, deterministic, and cheap next to the expert
# GEMMs, so the graph needs no multi-output nodes and XLA CSEs the repeated
# top-k inside the captured single program.

def _moe_capacities(n_tokens: int, e, nb: int, top_k: int) -> tuple[int, ...]:
    """Static per-expert capacities, deliberately UNEQUAL (0.5×–1.5× the
    mean routed load) so the exported fan-out is genuinely ragged and
    exercises the grouped ragged-M kernel; the total stays at roughly
    ``capacity_factor`` × routed tokens, the moe_gemm capacity-buffer
    budget."""
    base = n_tokens * top_k / nb * e.capacity_factor
    return tuple(max(1, int(round(base * (0.5 + j / max(nb - 1, 1)))))
                 for j in range(nb))


def _topk_routing(logits, nb: int, top_k: int, aux_free: bool):
    """(combine weights [N, k], expert ids [N, k]) from router logits —
    the same softmax/sigmoid selection rule as :func:`repro.models.ffn.route`
    (without the balancing bias, which is zero at init)."""
    lf = logits.reshape(-1, nb).astype(jnp.float32)
    scores = jax.nn.sigmoid(lf) if aux_free else jax.nn.softmax(lf, axis=-1)
    top_w, top_idx = jax.lax.top_k(scores, top_k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_idx


def _make_dispatch(j: int, cap: int, nb: int, top_k: int, aux_free: bool):
    """Per-expert token gather: the ``cap`` rows routed to expert ``j``
    (capacity-truncated, zero-padded when fewer arrive)."""
    def dispatch(h, logits):
        d = h.shape[-1]
        xf = h.reshape(-1, d)
        _, top_idx = _topk_routing(logits, nb, top_k, aux_free)
        expert_flat = top_idx.reshape(-1)                       # [N·k]
        tok = jnp.repeat(jnp.arange(xf.shape[0], dtype=jnp.int32), top_k)
        mine = expert_flat == j
        rank = jnp.cumsum(mine) - mine                          # rank within j
        take = mine & (rank < cap)
        slot = jnp.where(take, rank, cap)                       # cap = drop row
        buf = jnp.zeros((cap + 1, d), xf.dtype).at[slot].add(
            xf[tok] * take[:, None].astype(xf.dtype))
        return buf[:cap]
    return dispatch


def _make_glu(dff: int):
    def glu(h):
        return jax.nn.silu(h[..., :dff]) * h[..., dff:]
    return glu


def _make_combine(caps: tuple[int, ...], nb: int, top_k: int, aux_free: bool):
    """Weighted scatter-add of the per-expert outputs back to token order:
    each (token, k) pair re-derives its expert + within-expert rank exactly
    as the dispatch nodes did, reads that row of the concatenated expert
    outputs, and sums ``router_weight × row`` over k (capacity-dropped
    pairs contribute zero)."""
    offs = []
    off = 0
    for c in caps:
        offs.append(off)
        off += c

    def combine(*args):
        *eouts, h, logits = args
        d = h.shape[-1]
        xf = h.reshape(-1, d)
        n = xf.shape[0]
        top_w, top_idx = _topk_routing(logits, nb, top_k, aux_free)
        expert_flat = top_idx.reshape(-1)                       # [N·k]
        w_flat = top_w.reshape(-1)
        onehot = expert_flat[:, None] == jnp.arange(nb)[None, :]
        ranks = jnp.cumsum(onehot, axis=0) - onehot
        rank = jnp.take_along_axis(
            ranks, expert_flat[:, None], axis=1)[:, 0]
        caps_arr = jnp.asarray(caps, jnp.int32)
        offs_arr = jnp.asarray(offs, jnp.int32)
        cap_e = caps_arr[expert_flat]
        take = rank < cap_e
        row = offs_arr[expert_flat] + jnp.minimum(rank, cap_e - 1)
        allout = jnp.concatenate(eouts, axis=0)                 # [ΣC, d]
        rows = allout[row] * (w_flat * take).astype(allout.dtype)[:, None]
        y = rows.reshape(n, top_k, d).sum(axis=1)
        return y.reshape(h.shape).astype(h.dtype)
    return combine


def _moe_ragged_block(g, cfg, n2, b, s, tag, moe_p, moe_branch_cap):
    """Routed expert fan-out with REAL dispatch/combine payloads.

    router → nb parallel per-expert gathers (unequal static capacities) →
    TWO grouped ragged-M GEMM waves (gate∥up, then down — each stacks into
    ONE ``grouped_gemm`` kernel at capture because the branches share
    ``(K, F)`` but differ in M) → weighted scatter-add combine (+ the
    always-on shared expert).  Fan-out is capped at ``moe_branch_cap``
    branches; routing is then restricted to the first nb experts, so the
    exported math stays self-consistent (the differential oracle runs the
    same payloads per-op).
    """
    e = cfg.moe
    d, de = cfg.d_model, e.d_expert
    nb = min(e.n_experts, moe_branch_cap)
    top_k = min(e.top_k, nb)
    caps = _moe_capacities(b * s, e, nb, top_k)
    rw = (jnp.asarray(moe_p["router"]["w"], jnp.float32)[:, :nb]
          if moe_p is not None else None)
    router = g.add(
        f"{tag}.router", OpKind.REDUCE, [n2],
        fn=(lambda h: jnp.einsum("...d,de->...e", h.astype(jnp.float32), rw))
        if moe_p is not None else None,
        cost=gemm_cost(b * s, d, e.n_experts),
        out_shape=(b, s, nb), out_dtype=jnp.float32)
    outs = []
    for j in range(nb):
        disp = g.add(
            f"{tag}.dispatch{j}", OpKind.GATHER, [n2, router],
            fn=(_make_dispatch(j, caps[j], nb, top_k, e.router_aux_free)
                if moe_p is not None else None),
            cost=gather_cost(caps[j], d), out_shape=(caps[j], d))
        ew = (jnp.concatenate([moe_p["experts"]["gate"][j],
                               moe_p["experts"]["up"][j]], axis=1)
              if moe_p is not None else None)
        h = _gemm_node(g, f"{tag}.expert{j}_in", disp, ew,
                       caps[j], d, 2 * de,
                       fuse_sig=("egemm_in", d, 2 * de),
                       out_shape=(caps[j], 2 * de))
        glu = g.add(f"{tag}.expert{j}_glu", OpKind.ELEMENTWISE, [h],
                    fn=_make_glu(de) if moe_p is not None else None,
                    cost=elementwise_cost(caps[j] * de, n_in=1,
                                          flops_per_elem=5),
                    out_shape=(caps[j], de))
        outs.append(_gemm_node(
            g, f"{tag}.expert{j}_down", glu,
            moe_p["experts"]["down"][j] if moe_p is not None else None,
            caps[j], de, d, fuse_sig=("egemm_down", de, d),
            out_shape=(caps[j], d)))
    comb = g.add(
        f"{tag}.combine", OpKind.SCATTER, outs + [n2, router],
        fn=(_make_combine(caps, nb, top_k, e.router_aux_free)
            if moe_p is not None else None),
        cost=gather_cost(b * s * e.top_k, d))
    if not e.n_shared:
        return comb
    dsh = de * e.n_shared
    sp = (moe_p["shared"]
          if moe_p is not None and "shared" in moe_p else None)
    sw = (jnp.concatenate([sp["gate"]["w"], sp["up"]["w"]], axis=1)
          if sp is not None else None)
    sh = _gemm_node(g, f"{tag}.shared_in", n2, sw, b * s, d, 2 * dsh,
                    fuse_sig=("sgemm_in", d, 2 * dsh))
    shg = g.add(f"{tag}.shared_glu", OpKind.ELEMENTWISE, [sh],
                fn=_make_glu(dsh) if sp is not None else None,
                cost=elementwise_cost(b * s * dsh, n_in=1, flops_per_elem=5))
    shd = _gemm_node(g, f"{tag}.shared_down", shg,
                     sp["down"]["w"] if sp is not None else None,
                     b * s, dsh, d, fuse_sig=("sgemm_down", dsh, d))
    return g.add(f"{tag}.moe_out", OpKind.ELEMENTWISE, [comb, shd],
                 fn=(lambda a, c: a + c) if moe_p is not None else None,
                 cost=elementwise_cost(b * s * d, n_in=2))


def _hybrid_layer(g, cfg, x, b, s, tag, pl, window):
    """Hymba: attention and mamba heads in PARALLEL — the paper's Fig. 3
    compute∥memory overlap case (attn = MXU-bound, SSM scan = HBM-bound)."""
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    di = cfg.ssm.expand * d
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x], cost=norm_cost(b * s * d))
    q = _gemm_node(g, f"{tag}.wq", n1, None, b * s, d, nh * hd)
    k = _gemm_node(g, f"{tag}.wk", n1, None, b * s, d, kvh * hd)
    v = _gemm_node(g, f"{tag}.wv", n1, None, b * s, d, kvh * hd)
    att = g.add(f"{tag}.attn", OpKind.ATTENTION, [q, k, v],
                cost=attention_cost(b, s, min(s, window), nh, hd, kvh))
    # parallel mamba branch
    inp = _gemm_node(g, f"{tag}.mamba_in", n1, None, b * s, d, 2 * di)
    conv = g.add(f"{tag}.mamba_conv", OpKind.ELEMENTWISE, [inp],
                 cost=elementwise_cost(b * s * di, n_in=1, flops_per_elem=8))
    scan = g.add(f"{tag}.mamba_scan", OpKind.SCAN, [conv],
                 cost=scan_cost(b, s, di, cfg.ssm.state_dim))
    mo = _gemm_node(g, f"{tag}.mamba_out", scan, None, b * s, di, d)
    o = _gemm_node(g, f"{tag}.wo", att, None, b * s, nh * hd, d)
    mix = g.add(f"{tag}.head_mix", OpKind.ELEMENTWISE, [o, mo],
                cost=elementwise_cost(b * s * d, n_in=2))
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, mix],
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
    gate = _gemm_node(g, f"{tag}.gate", n2, None, b * s, d, cfg.d_ff)
    up = _gemm_node(g, f"{tag}.up", n2, None, b * s, d, cfg.d_ff)
    glu = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                cost=elementwise_cost(b * s * cfg.d_ff, n_in=2))
    down = _gemm_node(g, f"{tag}.down", glu, None, b * s, cfg.d_ff, d)
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                 cost=elementwise_cost(b * s * d, n_in=2))


def build_encdec_opgraph(cfg: ModelConfig, batch: int, dec_seq: int,
                         n_layers: int | None = None) -> OpGraph:
    """Whisper/T5-style encoder-decoder DAG: the encoder chain and the
    decoder's cross-attention KV projections are parallel branches until the
    first cross-attend — the operator-diversity case the paper highlights
    for T5 (Fig. 7a)."""
    g = OpGraph(cfg.name)
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = batch
    fe = cfg.frontend
    L = n_layers if n_layers is not None else cfg.n_layers
    Ld = n_layers if n_layers is not None else (cfg.n_dec_layers or cfg.n_layers)
    es = fe.n_tokens if fe else 1500

    frames = g.add("frames", OpKind.INPUT, out_shape=(b, es, fe.feat_dim if fe else d))
    # conv-style audio frontend lowered as an im2col GEMM — routed through
    # _gemm_node so the matmul payload marker appears the moment weights are
    # threaded (no hand-placed markers, ROADMAP item)
    enc = _gemm_node(g, "frontend_proj", frames, None,
                     b * es, fe.feat_dim if fe else d, d)
    for l in range(L):
        n1 = g.add(f"e{l}.norm1", OpKind.NORM, [enc], cost=norm_cost(b * es * d))
        q = _gemm_node(g, f"e{l}.wq", n1, None, b * es, d, nh * hd)
        k = _gemm_node(g, f"e{l}.wk", n1, None, b * es, d, kvh * hd)
        v = _gemm_node(g, f"e{l}.wv", n1, None, b * es, d, kvh * hd)
        att = g.add(f"e{l}.attn", OpKind.ATTENTION, [q, k, v],
                    cost=attention_cost(b, es, es, nh, hd, kvh))
        o = _gemm_node(g, f"e{l}.wo", att, None, b * es, nh * hd, d)
        r1 = g.add(f"e{l}.res1", OpKind.ELEMENTWISE, [enc, o],
                   cost=elementwise_cost(b * es * d, n_in=2))
        n2 = g.add(f"e{l}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * es * d))
        up = _gemm_node(g, f"e{l}.up", n2, None, b * es, d, cfg.d_ff)
        dn = _gemm_node(g, f"e{l}.down", up, None, b * es, cfg.d_ff, d)
        enc = g.add(f"e{l}.res2", OpKind.ELEMENTWISE, [r1, dn],
                    cost=elementwise_cost(b * es * d, n_in=2))

    tokens = g.add("tokens", OpKind.INPUT, out_shape=(b, dec_seq))
    dec = g.add("dec_embed", OpKind.GATHER, [tokens], cost=gather_cost(b * dec_seq, d))
    s = dec_seq
    for l in range(Ld):
        n1 = g.add(f"d{l}.norm1", OpKind.NORM, [dec], cost=norm_cost(b * s * d))
        q = _gemm_node(g, f"d{l}.wq", n1, None, b * s, d, nh * hd)
        k = _gemm_node(g, f"d{l}.wk", n1, None, b * s, d, kvh * hd)
        v = _gemm_node(g, f"d{l}.wv", n1, None, b * s, d, kvh * hd)
        att = g.add(f"d{l}.self", OpKind.ATTENTION, [q, k, v],
                    cost=attention_cost(b, s, s, nh, hd, kvh))
        # cross-attn K/V from the encoder: parallel with decoder self-attn
        ck = _gemm_node(g, f"d{l}.cross_k", enc, None, b * es, d, kvh * hd)
        cv = _gemm_node(g, f"d{l}.cross_v", enc, None, b * es, d, kvh * hd)
        cq = _gemm_node(g, f"d{l}.cross_q", att, None, b * s, d, nh * hd)
        xat = g.add(f"d{l}.cross", OpKind.ATTENTION, [cq, ck, cv],
                    cost=attention_cost(b, s, es, nh, hd, kvh))
        o = _gemm_node(g, f"d{l}.wo", xat, None, b * s, nh * hd, d)
        r1 = g.add(f"d{l}.res1", OpKind.ELEMENTWISE, [dec, o],
                   cost=elementwise_cost(b * s * d, n_in=2))
        n2 = g.add(f"d{l}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
        up = _gemm_node(g, f"d{l}.up", n2, None, b * s, d, cfg.d_ff)
        dn = _gemm_node(g, f"d{l}.down", up, None, b * s, cfg.d_ff, d)
        dec = g.add(f"d{l}.res2", OpKind.ELEMENTWISE, [r1, dn],
                    cost=elementwise_cost(b * s * d, n_in=2))
    g.add("logits", OpKind.GEMM, [dec], cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rwkv_layer(g, cfg, x, b, s, tag, pl):
    """RWKV6: five parallel token-shift projections feeding the WKV scan."""
    d = cfg.d_model
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x], cost=norm_cost(b * s * d))
    projs = [_gemm_node(g, f"{tag}.w{nm}", n1, None, b * s, d, d)
             for nm in ("r", "k", "v", "g")]
    wdec = _gemm_node(g, f"{tag}.w_lora", n1, None, b * s, d, 64)
    scan = g.add(f"{tag}.wkv_scan", OpKind.SCAN, projs[:3] + [wdec],
                 cost=scan_cost(b, s, d, hs))
    gated = g.add(f"{tag}.gate_mul", OpKind.ELEMENTWISE, [scan, projs[3]],
                  cost=elementwise_cost(b * s * d, n_in=2))
    o = _gemm_node(g, f"{tag}.wo", gated, None, b * s, d, d)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
    ck = _gemm_node(g, f"{tag}.cm_k", n2, None, b * s, d, cfg.d_ff)
    cv = _gemm_node(g, f"{tag}.cm_v", ck, None, b * s, cfg.d_ff, d)
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, cv],
                 cost=elementwise_cost(b * s * d, n_in=2))
