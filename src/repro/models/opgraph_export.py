"""Export a model's block structure as an Opara :class:`OpGraph`.

This is the bridge between the substrate and the paper's contribution: the
per-layer operator DAG (QKV branches, gate∥up, expert fan-out, attn∥mamba,
RWKV's 5 token-shift projections, …) is emitted with analytic costs so the
Stream Allocator / Operator Launcher schedule REAL model topologies, and the
Graph Capturer can execute them (used by benchmarks + examples with
smoke-size weights).

Payload functions close over concrete weights when ``params`` is given;
otherwise nodes are cost-only (for scheduling/simulation at production
scale, where we never allocate).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.graph import OpGraph, OpKind
from ..core.profiler import (
    attention_cost,
    elementwise_cost,
    gather_cost,
    gemm_cost,
    norm_cost,
    scan_cost,
)
from .transformer import stack_meta


def _w(params, *path):
    if params is None:
        return None
    out = params
    for p in path:
        out = out[p]
    return out


def build_lm_opgraph(cfg: ModelConfig, batch: int, seq: int,
                     params: Any = None, n_layers: int | None = None,
                     moe_branch_cap: int = 16) -> OpGraph:
    """Operator DAG of an LM forward pass (prefill semantics).

    ``n_layers`` trims depth (graph-size control for schedulers/benchmarks);
    MoE fan-out is capped at ``moe_branch_cap`` expert branches per layer
    (each branch node carries 1/cap of the routed FLOPs).
    """
    g = OpGraph(cfg.name)
    d, dt = cfg.d_model, 2
    b, s = batch, seq
    L = n_layers if n_layers is not None else cfg.n_layers

    def fn_or_none(f):
        return f if params is not None else None

    x = g.add("tokens", OpKind.INPUT, out_shape=(b, s))
    emb_w = _w(params, "embed", "table")
    x = g.add("embed", OpKind.GATHER, [x],
              fn=fn_or_none(lambda t: jnp.take(emb_w, t, axis=0)),
              cost=gather_cost(b * s, d), out_shape=(b, s, d))

    meta = stack_meta(cfg)
    layer_idx = 0
    for si, (kind, n, windows) in enumerate(meta):
        for li in range(min(n, max(L - layer_idx, 0))):
            tag = f"L{layer_idx}"
            pl = (jax.tree_util.tree_map(lambda a: a[li], _w(params, "stacks")[si])
                  if params is not None else None)
            if kind == "rwkv":
                x = _rwkv_layer(g, cfg, x, b, s, tag, pl)
            elif kind == "hybrid":
                x = _hybrid_layer(g, cfg, x, b, s, tag, pl,
                                  windows[li] or s)
            elif kind in ("moe",):
                x = _dense_layer(g, cfg, x, b, s, tag, pl, moe=True,
                                 moe_branch_cap=moe_branch_cap)
            else:
                x = _dense_layer(g, cfg, x, b, s, tag, pl, moe=False)
            layer_idx += 1
    fn = _w(params, "final_norm")
    x = g.add("final_norm", OpKind.NORM, [x],
              fn=fn_or_none(lambda h: _rms(fn, h)),
              cost=norm_cost(b * s * d))
    head = _w(params, "embed" if cfg.tie_embeddings else "head")
    g.add("logits", OpKind.GEMM, [x],
          fn=fn_or_none(lambda h: jnp.einsum("bsd,vd->bsv", h, head["table"])),
          cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rms(p, h, eps=1e-6):
    hf = h.astype(jnp.float32)
    v = jnp.mean(hf * hf, -1, keepdims=True)
    return (hf * jax.lax.rsqrt(v + eps) * p["scale"].astype(jnp.float32)).astype(h.dtype)


def _lin(p, h):
    return jnp.einsum("...i,io->...o", h, p["w"]) + (p.get("b", 0) if p else 0)


def _matmul(h, w):
    return jnp.einsum("...i,io->...o", h, w)


def _matmul_bias(h, w, bias):
    return jnp.einsum("...i,io->...o", h, w) + bias


def _gemm_node(g, name, inp, pl_linear, m, k, n, bias: bool = False,
               cost=None, fuse_sig=None):
    """GEMM node following the capture contract: weights go in
    meta["consts"] so same-signature branches stack into one fused kernel.

    EVERY GEMM-semantics node the exporter emits goes through here — expert
    fan-out and conv-like frontend projections included — so whenever a
    weight is threaded the node automatically carries ``payload="matmul"``,
    the capturer's routing contract for the fused branch_gemm Pallas kernel
    (no hand-placed markers).  ``cost`` / ``fuse_sig`` override the
    defaults for nodes whose analytic cost is not the plain (m, k, n)
    roofline (e.g. capacity-scaled expert branches).
    """
    cost = cost if cost is not None else gemm_cost(m, k, n)
    fuse_sig = fuse_sig if fuse_sig is not None else ("gemm", k, n, bias)
    if pl_linear is None:
        return g.add(name, OpKind.GEMM, [inp], cost=cost, fuse_sig=fuse_sig)
    if isinstance(pl_linear, dict):
        consts = (pl_linear["w"],) + ((pl_linear["b"],) if bias else ())
    else:  # a bare weight array (expert slices) — carries no bias term
        assert not bias, f"{name}: bare-array weight cannot supply a bias"
        consts = (pl_linear,)
    return g.add(name, OpKind.GEMM, [inp],
                 fn=_matmul_bias if bias else _matmul,
                 cost=cost, fuse_sig=fuse_sig, consts=consts,
                 payload="matmul")


def _dense_layer(g, cfg, x, b, s, tag, pl, moe: bool, moe_branch_cap: int = 16):
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    bias = cfg.qkv_bias
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x],
               fn=(lambda h: _rms(pl["norm1"], h)) if pl else None,
               cost=norm_cost(b * s * d))
    # QKV: 3 parallel GEMM branches (the canonical Opara wave)
    attn_p = pl["attn"] if pl else None
    q = _gemm_node(g, f"{tag}.wq", n1, attn_p and attn_p["wq"], b * s, d, nh * hd, bias)
    k = _gemm_node(g, f"{tag}.wk", n1, attn_p and attn_p["wk"], b * s, d, kvh * hd, bias)
    v = _gemm_node(g, f"{tag}.wv", n1, attn_p and attn_p["wv"], b * s, d, kvh * hd, bias)
    att = g.add(f"{tag}.attn", OpKind.ATTENTION, [q, k, v],
                fn=(lambda qq, kk, vv: _attn_payload(cfg, qq, kk, vv)) if pl else None,
                cost=attention_cost(b, s, s, nh, hd, kvh))
    o = _gemm_node(g, f"{tag}.wo", att, attn_p and attn_p["wo"], b * s, nh * hd, d, False)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1],
               fn=(lambda h: _rms(pl["norm2"], h)) if pl else None,
               cost=norm_cost(b * s * d))
    if not moe:
        dff = cfg.d_ff
        ffn_p = pl["ffn"] if pl else None
        gate = _gemm_node(g, f"{tag}.gate", n2, ffn_p and ffn_p["gate"],
                          b * s, d, dff, False)
        up = _gemm_node(g, f"{tag}.up", n2, ffn_p and ffn_p["up"],
                        b * s, d, dff, False)
        prod = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                     fn=(lambda a, c: jax.nn.silu(a) * c) if pl else None,
                     cost=elementwise_cost(b * s * dff, n_in=2, flops_per_elem=5))
        down = _gemm_node(g, f"{tag}.down", prod, ffn_p and ffn_p["down"],
                          b * s, dff, d, False)
    else:
        e = cfg.moe
        moe_p = pl["ffn"] if pl else None
        router = g.add(f"{tag}.router", OpKind.REDUCE, [n2],
                       cost=gemm_cost(b * s, d, e.n_experts))
        disp = g.add(f"{tag}.dispatch", OpKind.SCATTER, [n2, router],
                     cost=gather_cost(b * s * e.top_k, d))
        nb = min(e.n_experts, moe_branch_cap)
        tok_per_branch = b * s * e.top_k / e.n_experts * (e.n_experts / nb)
        outs = []
        for j in range(nb):
            # per-branch expert weight from the stacked [E, d, d_e] params:
            # gate|up|downᵀ concatenated to [d, 3·d_e], so the x@w payload
            # performs exactly the FLOPs the analytic cost models (one
            # [d → 3·d_e] GEMM per branch) and the branch carries the matmul
            # marker, stacking with its siblings into ONE fused branch_gemm
            # kernel at capture.  Params-threaded exports are smoke-size by
            # construction, so the concat allocation is negligible.
            ew = (jnp.concatenate(
                      [moe_p["experts"]["gate"][j],
                       moe_p["experts"]["up"][j],
                       moe_p["experts"]["down"][j].T], axis=1)
                  if moe_p is not None else None)
            eb = _gemm_node(g, f"{tag}.expert{j}", disp, ew,
                            int(tok_per_branch), d, 3 * e.d_expert,
                            fuse_sig=("egemm", d, e.d_expert))
            outs.append(eb)
        if e.n_shared:
            sp = (moe_p["shared"]
                  if moe_p is not None and "shared" in moe_p else None)
            sw = (jnp.concatenate([sp["gate"]["w"], sp["up"]["w"],
                                   sp["down"]["w"].T], axis=1)
                  if sp is not None else None)
            outs.append(_gemm_node(g, f"{tag}.shared_expert", n2, sw,
                                   b * s, d, 3 * e.d_expert * e.n_shared))
        down = g.add(f"{tag}.combine", OpKind.SCATTER, outs + [router],
                     cost=gather_cost(b * s * e.top_k, d))
    out = g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                fn=(lambda a, c: a + c) if pl else None,
                cost=elementwise_cost(b * s * d, n_in=2))
    return out


def _attn_payload(cfg, q, k, v):
    from .attention import _sdpa, causal_window_mask
    b, s = q.shape[0], q.shape[1]
    nh, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qh = q.reshape(b, s, nh, hd)
    kh = k.reshape(b, s, kvh, hd)
    vh = v.reshape(b, s, kvh, hd)
    pos = jnp.arange(s)
    mask = causal_window_mask(pos, pos, None)
    return _sdpa(qh, kh, vh, mask).reshape(b, s, nh * hd)


def _hybrid_layer(g, cfg, x, b, s, tag, pl, window):
    """Hymba: attention and mamba heads in PARALLEL — the paper's Fig. 3
    compute∥memory overlap case (attn = MXU-bound, SSM scan = HBM-bound)."""
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    di = cfg.ssm.expand * d
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x], cost=norm_cost(b * s * d))
    q = _gemm_node(g, f"{tag}.wq", n1, None, b * s, d, nh * hd)
    k = _gemm_node(g, f"{tag}.wk", n1, None, b * s, d, kvh * hd)
    v = _gemm_node(g, f"{tag}.wv", n1, None, b * s, d, kvh * hd)
    att = g.add(f"{tag}.attn", OpKind.ATTENTION, [q, k, v],
                cost=attention_cost(b, s, min(s, window), nh, hd, kvh))
    # parallel mamba branch
    inp = _gemm_node(g, f"{tag}.mamba_in", n1, None, b * s, d, 2 * di)
    conv = g.add(f"{tag}.mamba_conv", OpKind.ELEMENTWISE, [inp],
                 cost=elementwise_cost(b * s * di, n_in=1, flops_per_elem=8))
    scan = g.add(f"{tag}.mamba_scan", OpKind.SCAN, [conv],
                 cost=scan_cost(b, s, di, cfg.ssm.state_dim))
    mo = _gemm_node(g, f"{tag}.mamba_out", scan, None, b * s, di, d)
    o = _gemm_node(g, f"{tag}.wo", att, None, b * s, nh * hd, d)
    mix = g.add(f"{tag}.head_mix", OpKind.ELEMENTWISE, [o, mo],
                cost=elementwise_cost(b * s * d, n_in=2))
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, mix],
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
    gate = _gemm_node(g, f"{tag}.gate", n2, None, b * s, d, cfg.d_ff)
    up = _gemm_node(g, f"{tag}.up", n2, None, b * s, d, cfg.d_ff)
    glu = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                cost=elementwise_cost(b * s * cfg.d_ff, n_in=2))
    down = _gemm_node(g, f"{tag}.down", glu, None, b * s, cfg.d_ff, d)
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                 cost=elementwise_cost(b * s * d, n_in=2))


def build_encdec_opgraph(cfg: ModelConfig, batch: int, dec_seq: int,
                         n_layers: int | None = None) -> OpGraph:
    """Whisper/T5-style encoder-decoder DAG: the encoder chain and the
    decoder's cross-attention KV projections are parallel branches until the
    first cross-attend — the operator-diversity case the paper highlights
    for T5 (Fig. 7a)."""
    g = OpGraph(cfg.name)
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = batch
    fe = cfg.frontend
    L = n_layers if n_layers is not None else cfg.n_layers
    Ld = n_layers if n_layers is not None else (cfg.n_dec_layers or cfg.n_layers)
    es = fe.n_tokens if fe else 1500

    frames = g.add("frames", OpKind.INPUT, out_shape=(b, es, fe.feat_dim if fe else d))
    # conv-style audio frontend lowered as an im2col GEMM — routed through
    # _gemm_node so the matmul payload marker appears the moment weights are
    # threaded (no hand-placed markers, ROADMAP item)
    enc = _gemm_node(g, "frontend_proj", frames, None,
                     b * es, fe.feat_dim if fe else d, d)
    for l in range(L):
        n1 = g.add(f"e{l}.norm1", OpKind.NORM, [enc], cost=norm_cost(b * es * d))
        q = _gemm_node(g, f"e{l}.wq", n1, None, b * es, d, nh * hd)
        k = _gemm_node(g, f"e{l}.wk", n1, None, b * es, d, kvh * hd)
        v = _gemm_node(g, f"e{l}.wv", n1, None, b * es, d, kvh * hd)
        att = g.add(f"e{l}.attn", OpKind.ATTENTION, [q, k, v],
                    cost=attention_cost(b, es, es, nh, hd, kvh))
        o = _gemm_node(g, f"e{l}.wo", att, None, b * es, nh * hd, d)
        r1 = g.add(f"e{l}.res1", OpKind.ELEMENTWISE, [enc, o],
                   cost=elementwise_cost(b * es * d, n_in=2))
        n2 = g.add(f"e{l}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * es * d))
        up = _gemm_node(g, f"e{l}.up", n2, None, b * es, d, cfg.d_ff)
        dn = _gemm_node(g, f"e{l}.down", up, None, b * es, cfg.d_ff, d)
        enc = g.add(f"e{l}.res2", OpKind.ELEMENTWISE, [r1, dn],
                    cost=elementwise_cost(b * es * d, n_in=2))

    tokens = g.add("tokens", OpKind.INPUT, out_shape=(b, dec_seq))
    dec = g.add("dec_embed", OpKind.GATHER, [tokens], cost=gather_cost(b * dec_seq, d))
    s = dec_seq
    for l in range(Ld):
        n1 = g.add(f"d{l}.norm1", OpKind.NORM, [dec], cost=norm_cost(b * s * d))
        q = _gemm_node(g, f"d{l}.wq", n1, None, b * s, d, nh * hd)
        k = _gemm_node(g, f"d{l}.wk", n1, None, b * s, d, kvh * hd)
        v = _gemm_node(g, f"d{l}.wv", n1, None, b * s, d, kvh * hd)
        att = g.add(f"d{l}.self", OpKind.ATTENTION, [q, k, v],
                    cost=attention_cost(b, s, s, nh, hd, kvh))
        # cross-attn K/V from the encoder: parallel with decoder self-attn
        ck = _gemm_node(g, f"d{l}.cross_k", enc, None, b * es, d, kvh * hd)
        cv = _gemm_node(g, f"d{l}.cross_v", enc, None, b * es, d, kvh * hd)
        cq = _gemm_node(g, f"d{l}.cross_q", att, None, b * s, d, nh * hd)
        xat = g.add(f"d{l}.cross", OpKind.ATTENTION, [cq, ck, cv],
                    cost=attention_cost(b, s, es, nh, hd, kvh))
        o = _gemm_node(g, f"d{l}.wo", xat, None, b * s, nh * hd, d)
        r1 = g.add(f"d{l}.res1", OpKind.ELEMENTWISE, [dec, o],
                   cost=elementwise_cost(b * s * d, n_in=2))
        n2 = g.add(f"d{l}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
        up = _gemm_node(g, f"d{l}.up", n2, None, b * s, d, cfg.d_ff)
        dn = _gemm_node(g, f"d{l}.down", up, None, b * s, cfg.d_ff, d)
        dec = g.add(f"d{l}.res2", OpKind.ELEMENTWISE, [r1, dn],
                    cost=elementwise_cost(b * s * d, n_in=2))
    g.add("logits", OpKind.GEMM, [dec], cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rwkv_layer(g, cfg, x, b, s, tag, pl):
    """RWKV6: five parallel token-shift projections feeding the WKV scan."""
    d = cfg.d_model
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x], cost=norm_cost(b * s * d))
    projs = [_gemm_node(g, f"{tag}.w{nm}", n1, None, b * s, d, d)
             for nm in ("r", "k", "v", "g")]
    wdec = _gemm_node(g, f"{tag}.w_lora", n1, None, b * s, d, 64)
    scan = g.add(f"{tag}.wkv_scan", OpKind.SCAN, projs[:3] + [wdec],
                 cost=scan_cost(b, s, d, hs))
    gated = g.add(f"{tag}.gate_mul", OpKind.ELEMENTWISE, [scan, projs[3]],
                  cost=elementwise_cost(b * s * d, n_in=2))
    o = _gemm_node(g, f"{tag}.wo", gated, None, b * s, d, d)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
    ck = _gemm_node(g, f"{tag}.cm_k", n2, None, b * s, d, cfg.d_ff)
    cv = _gemm_node(g, f"{tag}.cm_v", ck, None, b * s, cfg.d_ff, d)
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, cv],
                 cost=elementwise_cost(b * s * d, n_in=2))
