"""Export a model's block structure as an Opara :class:`OpGraph`.

This is the bridge between the substrate and the paper's contribution: the
per-layer operator DAG (QKV branches, gate∥up, expert fan-out, attn∥mamba,
RWKV's 5 token-shift projections, …) is emitted with analytic costs so the
Stream Allocator / Operator Launcher schedule REAL model topologies, and the
Graph Capturer can execute them (used by benchmarks + examples with
smoke-size weights).

Payload functions close over concrete weights when ``params`` is given;
otherwise nodes are cost-only (for scheduling/simulation at production
scale, where we never allocate).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.graph import OpGraph, OpKind
from ..core.profiler import (
    attention_cost,
    elementwise_cost,
    gather_cost,
    gemm_cost,
    norm_cost,
    scan_cost,
)
from .transformer import stack_meta


def _w(params, *path):
    if params is None:
        return None
    out = params
    for p in path:
        out = out[p]
    return out


def build_lm_opgraph(cfg: ModelConfig, batch: int, seq: int,
                     params: Any = None, n_layers: int | None = None,
                     moe_branch_cap: int = 16) -> OpGraph:
    """Operator DAG of an LM forward pass (prefill semantics).

    ``n_layers`` trims depth (graph-size control for schedulers/benchmarks);
    MoE fan-out is capped at ``moe_branch_cap`` expert branches per layer
    (each branch node carries 1/cap of the routed FLOPs).
    """
    g = OpGraph(cfg.name)
    d, dt = cfg.d_model, 2
    b, s = batch, seq
    L = n_layers if n_layers is not None else cfg.n_layers

    def fn_or_none(f):
        return f if params is not None else None

    x = g.add("tokens", OpKind.INPUT, out_shape=(b, s))
    emb_w = _w(params, "embed", "table")
    x = g.add("embed", OpKind.GATHER, [x],
              fn=fn_or_none(lambda t: jnp.take(emb_w, t, axis=0)),
              cost=gather_cost(b * s, d), out_shape=(b, s, d))

    meta = stack_meta(cfg)
    layer_idx = 0
    for si, (kind, n, windows) in enumerate(meta):
        for li in range(min(n, max(L - layer_idx, 0))):
            tag = f"L{layer_idx}"
            pl = (jax.tree_util.tree_map(lambda a: a[li], _w(params, "stacks")[si])
                  if params is not None else None)
            if kind == "rwkv":
                x = _rwkv_layer(g, cfg, x, b, s, tag, pl)
            elif kind == "hybrid":
                x = _hybrid_layer(g, cfg, x, b, s, tag, pl,
                                  windows[li] or s)
            elif kind in ("moe",):
                x = _dense_layer(g, cfg, x, b, s, tag, pl, moe=True,
                                 moe_branch_cap=moe_branch_cap)
            else:
                x = _dense_layer(g, cfg, x, b, s, tag, pl, moe=False)
            layer_idx += 1
    fn = _w(params, "final_norm")
    x = g.add("final_norm", OpKind.NORM, [x],
              fn=fn_or_none(lambda h: _rms(fn, h)),
              cost=norm_cost(b * s * d))
    head = _w(params, "embed" if cfg.tie_embeddings else "head")
    g.add("logits", OpKind.GEMM, [x],
          fn=fn_or_none(lambda h: jnp.einsum("bsd,vd->bsv", h, head["table"])),
          cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rms(p, h, eps=1e-6):
    hf = h.astype(jnp.float32)
    v = jnp.mean(hf * hf, -1, keepdims=True)
    return (hf * jax.lax.rsqrt(v + eps) * p["scale"].astype(jnp.float32)).astype(h.dtype)


def _lin(p, h):
    return jnp.einsum("...i,io->...o", h, p["w"]) + (p.get("b", 0) if p else 0)


def _matmul(h, w):
    return jnp.einsum("...i,io->...o", h, w)


def _matmul_bias(h, w, bias):
    return jnp.einsum("...i,io->...o", h, w) + bias


def _gemm_node(g, name, inp, pl_linear, m, k, n, bias: bool):
    """GEMM node following the capture contract: weights go in
    meta["consts"] so same-signature branches stack into one fused kernel."""
    if pl_linear is None:
        return g.add(name, OpKind.GEMM, [inp], cost=gemm_cost(m, k, n),
                     fuse_sig=("gemm", k, n, bias))
    consts = (pl_linear["w"],) + ((pl_linear["b"],) if bias else ())
    # payload="matmul" declares x @ w (+ b) semantics — the capturer's
    # routing contract for the fused branch_gemm Pallas kernel.
    return g.add(name, OpKind.GEMM, [inp],
                 fn=_matmul_bias if bias else _matmul,
                 cost=gemm_cost(m, k, n),
                 fuse_sig=("gemm", k, n, bias), consts=consts,
                 payload="matmul")


def _dense_layer(g, cfg, x, b, s, tag, pl, moe: bool, moe_branch_cap: int = 16):
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    bias = cfg.qkv_bias
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x],
               fn=(lambda h: _rms(pl["norm1"], h)) if pl else None,
               cost=norm_cost(b * s * d))
    # QKV: 3 parallel GEMM branches (the canonical Opara wave)
    attn_p = pl["attn"] if pl else None
    q = _gemm_node(g, f"{tag}.wq", n1, attn_p and attn_p["wq"], b * s, d, nh * hd, bias)
    k = _gemm_node(g, f"{tag}.wk", n1, attn_p and attn_p["wk"], b * s, d, kvh * hd, bias)
    v = _gemm_node(g, f"{tag}.wv", n1, attn_p and attn_p["wv"], b * s, d, kvh * hd, bias)
    att = g.add(f"{tag}.attn", OpKind.ATTENTION, [q, k, v],
                fn=(lambda qq, kk, vv: _attn_payload(cfg, qq, kk, vv)) if pl else None,
                cost=attention_cost(b, s, s, nh, hd, kvh))
    o = _gemm_node(g, f"{tag}.wo", att, attn_p and attn_p["wo"], b * s, nh * hd, d, False)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1],
               fn=(lambda h: _rms(pl["norm2"], h)) if pl else None,
               cost=norm_cost(b * s * d))
    if not moe:
        dff = cfg.d_ff
        ffn_p = pl["ffn"] if pl else None
        gate = _gemm_node(g, f"{tag}.gate", n2, ffn_p and ffn_p["gate"],
                          b * s, d, dff, False)
        up = _gemm_node(g, f"{tag}.up", n2, ffn_p and ffn_p["up"],
                        b * s, d, dff, False)
        prod = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                     fn=(lambda a, c: jax.nn.silu(a) * c) if pl else None,
                     cost=elementwise_cost(b * s * dff, n_in=2, flops_per_elem=5))
        down = _gemm_node(g, f"{tag}.down", prod, ffn_p and ffn_p["down"],
                          b * s, dff, d, False)
    else:
        e = cfg.moe
        router = g.add(f"{tag}.router", OpKind.REDUCE, [n2],
                       cost=gemm_cost(b * s, d, e.n_experts))
        disp = g.add(f"{tag}.dispatch", OpKind.SCATTER, [n2, router],
                     cost=gather_cost(b * s * e.top_k, d))
        nb = min(e.n_experts, moe_branch_cap)
        tok_per_branch = b * s * e.top_k / e.n_experts * (e.n_experts / nb)
        outs = []
        for j in range(nb):
            eb = g.add(f"{tag}.expert{j}", OpKind.GEMM, [disp],
                       cost=gemm_cost(int(tok_per_branch), d, 3 * e.d_expert),
                       fuse_sig=("egemm", d, e.d_expert))
            outs.append(eb)
        if e.n_shared:
            outs.append(g.add(f"{tag}.shared_expert", OpKind.GEMM, [n2],
                              cost=gemm_cost(b * s, d, 3 * e.d_expert * e.n_shared)))
        down = g.add(f"{tag}.combine", OpKind.SCATTER, outs + [router],
                     cost=gather_cost(b * s * e.top_k, d))
    out = g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                fn=(lambda a, c: a + c) if pl else None,
                cost=elementwise_cost(b * s * d, n_in=2))
    return out


def _attn_payload(cfg, q, k, v):
    from .attention import _sdpa, causal_window_mask
    b, s = q.shape[0], q.shape[1]
    nh, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qh = q.reshape(b, s, nh, hd)
    kh = k.reshape(b, s, kvh, hd)
    vh = v.reshape(b, s, kvh, hd)
    pos = jnp.arange(s)
    mask = causal_window_mask(pos, pos, None)
    return _sdpa(qh, kh, vh, mask).reshape(b, s, nh * hd)


def _hybrid_layer(g, cfg, x, b, s, tag, pl, window):
    """Hymba: attention and mamba heads in PARALLEL — the paper's Fig. 3
    compute∥memory overlap case (attn = MXU-bound, SSM scan = HBM-bound)."""
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    di = cfg.ssm.expand * d
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x], cost=norm_cost(b * s * d))
    q = g.add(f"{tag}.wq", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, nh * hd),
              fuse_sig=("gemm", d, nh * hd))
    k = g.add(f"{tag}.wk", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, kvh * hd),
              fuse_sig=("gemm", d, kvh * hd))
    v = g.add(f"{tag}.wv", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, kvh * hd),
              fuse_sig=("gemm", d, kvh * hd))
    att = g.add(f"{tag}.attn", OpKind.ATTENTION, [q, k, v],
                cost=attention_cost(b, s, min(s, window), nh, hd, kvh))
    # parallel mamba branch
    inp = g.add(f"{tag}.mamba_in", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, 2 * di))
    conv = g.add(f"{tag}.mamba_conv", OpKind.ELEMENTWISE, [inp],
                 cost=elementwise_cost(b * s * di, n_in=1, flops_per_elem=8))
    scan = g.add(f"{tag}.mamba_scan", OpKind.SCAN, [conv],
                 cost=scan_cost(b, s, di, cfg.ssm.state_dim))
    mo = g.add(f"{tag}.mamba_out", OpKind.GEMM, [scan], cost=gemm_cost(b * s, di, d))
    o = g.add(f"{tag}.wo", OpKind.GEMM, [att], cost=gemm_cost(b * s, nh * hd, d))
    mix = g.add(f"{tag}.head_mix", OpKind.ELEMENTWISE, [o, mo],
                cost=elementwise_cost(b * s * d, n_in=2))
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, mix],
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
    gate = g.add(f"{tag}.gate", OpKind.GEMM, [n2], cost=gemm_cost(b * s, d, cfg.d_ff),
                 fuse_sig=("gemm", d, cfg.d_ff))
    up = g.add(f"{tag}.up", OpKind.GEMM, [n2], cost=gemm_cost(b * s, d, cfg.d_ff),
               fuse_sig=("gemm", d, cfg.d_ff))
    glu = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                cost=elementwise_cost(b * s * cfg.d_ff, n_in=2))
    down = g.add(f"{tag}.down", OpKind.GEMM, [glu], cost=gemm_cost(b * s, cfg.d_ff, d))
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                 cost=elementwise_cost(b * s * d, n_in=2))


def build_encdec_opgraph(cfg: ModelConfig, batch: int, dec_seq: int,
                         n_layers: int | None = None) -> OpGraph:
    """Whisper/T5-style encoder-decoder DAG: the encoder chain and the
    decoder's cross-attention KV projections are parallel branches until the
    first cross-attend — the operator-diversity case the paper highlights
    for T5 (Fig. 7a)."""
    g = OpGraph(cfg.name)
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b = batch
    fe = cfg.frontend
    L = n_layers if n_layers is not None else cfg.n_layers
    Ld = n_layers if n_layers is not None else (cfg.n_dec_layers or cfg.n_layers)
    es = fe.n_tokens if fe else 1500

    frames = g.add("frames", OpKind.INPUT, out_shape=(b, es, fe.feat_dim if fe else d))
    enc = g.add("frontend_proj", OpKind.GEMM, [frames],
                cost=gemm_cost(b * es, fe.feat_dim if fe else d, d))
    for l in range(L):
        n1 = g.add(f"e{l}.norm1", OpKind.NORM, [enc], cost=norm_cost(b * es * d))
        q = g.add(f"e{l}.wq", OpKind.GEMM, [n1], cost=gemm_cost(b * es, d, nh * hd),
                  fuse_sig=("gemm", d, nh * hd))
        k = g.add(f"e{l}.wk", OpKind.GEMM, [n1], cost=gemm_cost(b * es, d, kvh * hd),
                  fuse_sig=("gemm", d, kvh * hd))
        v = g.add(f"e{l}.wv", OpKind.GEMM, [n1], cost=gemm_cost(b * es, d, kvh * hd),
                  fuse_sig=("gemm", d, kvh * hd))
        att = g.add(f"e{l}.attn", OpKind.ATTENTION, [q, k, v],
                    cost=attention_cost(b, es, es, nh, hd, kvh))
        o = g.add(f"e{l}.wo", OpKind.GEMM, [att], cost=gemm_cost(b * es, nh * hd, d))
        r1 = g.add(f"e{l}.res1", OpKind.ELEMENTWISE, [enc, o],
                   cost=elementwise_cost(b * es * d, n_in=2))
        n2 = g.add(f"e{l}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * es * d))
        up = g.add(f"e{l}.up", OpKind.GEMM, [n2], cost=gemm_cost(b * es, d, cfg.d_ff))
        dn = g.add(f"e{l}.down", OpKind.GEMM, [up], cost=gemm_cost(b * es, cfg.d_ff, d))
        enc = g.add(f"e{l}.res2", OpKind.ELEMENTWISE, [r1, dn],
                    cost=elementwise_cost(b * es * d, n_in=2))

    tokens = g.add("tokens", OpKind.INPUT, out_shape=(b, dec_seq))
    dec = g.add("dec_embed", OpKind.GATHER, [tokens], cost=gather_cost(b * dec_seq, d))
    s = dec_seq
    for l in range(Ld):
        n1 = g.add(f"d{l}.norm1", OpKind.NORM, [dec], cost=norm_cost(b * s * d))
        q = g.add(f"d{l}.wq", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, nh * hd),
                  fuse_sig=("gemm", d, nh * hd))
        k = g.add(f"d{l}.wk", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, kvh * hd),
                  fuse_sig=("gemm", d, kvh * hd))
        v = g.add(f"d{l}.wv", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, kvh * hd),
                  fuse_sig=("gemm", d, kvh * hd))
        att = g.add(f"d{l}.self", OpKind.ATTENTION, [q, k, v],
                    cost=attention_cost(b, s, s, nh, hd, kvh))
        # cross-attn K/V from the encoder: parallel with decoder self-attn
        ck = g.add(f"d{l}.cross_k", OpKind.GEMM, [enc],
                   cost=gemm_cost(b * es, d, kvh * hd), fuse_sig=("gemm", d, kvh * hd))
        cv = g.add(f"d{l}.cross_v", OpKind.GEMM, [enc],
                   cost=gemm_cost(b * es, d, kvh * hd), fuse_sig=("gemm", d, kvh * hd))
        cq = g.add(f"d{l}.cross_q", OpKind.GEMM, [att],
                   cost=gemm_cost(b * s, d, nh * hd))
        xat = g.add(f"d{l}.cross", OpKind.ATTENTION, [cq, ck, cv],
                    cost=attention_cost(b, s, es, nh, hd, kvh))
        o = g.add(f"d{l}.wo", OpKind.GEMM, [xat], cost=gemm_cost(b * s, nh * hd, d))
        r1 = g.add(f"d{l}.res1", OpKind.ELEMENTWISE, [dec, o],
                   cost=elementwise_cost(b * s * d, n_in=2))
        n2 = g.add(f"d{l}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
        up = g.add(f"d{l}.up", OpKind.GEMM, [n2], cost=gemm_cost(b * s, d, cfg.d_ff))
        dn = g.add(f"d{l}.down", OpKind.GEMM, [up], cost=gemm_cost(b * s, cfg.d_ff, d))
        dec = g.add(f"d{l}.res2", OpKind.ELEMENTWISE, [r1, dn],
                    cost=elementwise_cost(b * s * d, n_in=2))
    g.add("logits", OpKind.GEMM, [dec], cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rwkv_layer(g, cfg, x, b, s, tag, pl):
    """RWKV6: five parallel token-shift projections feeding the WKV scan."""
    d = cfg.d_model
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    n1 = g.add(f"{tag}.norm1", OpKind.NORM, [x], cost=norm_cost(b * s * d))
    projs = []
    for nm in ("r", "k", "v", "g"):
        projs.append(g.add(f"{tag}.w{nm}", OpKind.GEMM, [n1],
                           cost=gemm_cost(b * s, d, d), fuse_sig=("gemm", d, d)))
    wdec = g.add(f"{tag}.w_lora", OpKind.GEMM, [n1], cost=gemm_cost(b * s, d, 64))
    scan = g.add(f"{tag}.wkv_scan", OpKind.SCAN, projs[:3] + [wdec],
                 cost=scan_cost(b, s, d, hs))
    gated = g.add(f"{tag}.gate_mul", OpKind.ELEMENTWISE, [scan, projs[3]],
                  cost=elementwise_cost(b * s * d, n_in=2))
    o = g.add(f"{tag}.wo", OpKind.GEMM, [gated], cost=gemm_cost(b * s, d, d))
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = g.add(f"{tag}.norm2", OpKind.NORM, [r1], cost=norm_cost(b * s * d))
    ck = g.add(f"{tag}.cm_k", OpKind.GEMM, [n2], cost=gemm_cost(b * s, d, cfg.d_ff))
    cv = g.add(f"{tag}.cm_v", OpKind.GEMM, [ck], cost=gemm_cost(b * s, cfg.d_ff, d))
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, cv],
                 cost=elementwise_cost(b * s * d, n_in=2))
