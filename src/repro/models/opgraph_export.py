"""Export a model's block structure as an Opara :class:`OpGraph`.

This is the bridge between the substrate and the paper's contribution: the
per-layer operator DAG (QKV branches, gate∥up, expert fan-out, attn∥mamba,
RWKV's 5 token-shift projections, …) is emitted with analytic costs so the
Stream Allocator / Operator Launcher schedule REAL model topologies, and the
Graph Capturer can execute them (used by benchmarks + examples with
smoke-size weights).

Every arch family exports at *traced-kernel* granularity (the bert/t5
treatment from ``benchmarks/workloads.py``): attention is decomposed into
head-split transpose copies → score GEMM → scale+mask → softmax → context
GEMM → head-merge, and large FF weights become explicit weight-stream DMA
ops on the cost-only path — so the memory-intensive stages the paper
overlaps with compute (Fig. 3) are individually schedulable instead of
hidden inside monolithic attention nodes.  See docs/scheduling.md
("Export granularity") for the per-arch stage table.

Payload functions close over concrete weights when ``params`` is given;
otherwise nodes are cost-only (for scheduling/simulation at production
scale, where we never allocate).  Payload-backed exports keep a SINGLE
graph input (weights ride in ``meta["consts"]``), so the differential
harness can replay them op-by-op.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.graph import OpGraph, OpKind
from ..core.profiler import (
    elementwise_cost,
    gather_cost,
    gemm_cost,
    norm_cost,
    scan_cost,
)
from .attention import NEG_INF, causal_window_mask
from .export_costs import act_gemm_cost, stream_cost
from .layers import apply_norm, apply_rope, gelu
from .ssm import mamba_scan_ref, wkv_scan_ref
from .transformer import stack_meta


def _w(params, *path):
    if params is None:
        return None
    out = params
    for p in path:
        out = out[p]
    return out


def build_lm_opgraph(cfg: ModelConfig, batch: int, seq: int,
                     params: Any = None, n_layers: int | None = None,
                     moe_branch_cap: int = 16,
                     moe_dispatch: str = "auto",
                     moe_cap_scale: float = 1.0) -> OpGraph:
    """Operator DAG of an LM forward pass (prefill semantics).

    ``n_layers`` trims depth (graph-size control for schedulers/benchmarks);
    MoE fan-out is capped at ``moe_branch_cap`` expert branches per layer.

    ``moe_dispatch`` picks the MoE block structure: ``"uniform"`` emits the
    historical cost-only fan-out (equal-FLOP expert branches, scatter
    dispatch/combine without payloads); ``"ragged"`` emits the routed
    fan-out — real router → per-expert token gathers with *unequal* static
    capacities → grouped ragged-M expert GEMMs → weighted scatter-add
    combine — executable end to end whenever ``params`` is threaded.
    ``"auto"`` (default) uses ragged with params and uniform without, so
    cost-only scheduling benchmarks keep their historical topology.

    ``moe_cap_scale`` scales the static per-expert capacities of the ragged
    fan-out; values < 1 force genuine capacity overflow (routed pairs whose
    within-expert rank exceeds capacity contribute zero), the production
    sort-dispatch semantics the differential harness pins.
    """
    if moe_dispatch not in ("auto", "ragged", "uniform"):
        raise ValueError(f"unknown moe_dispatch {moe_dispatch!r}")
    g = OpGraph(cfg.name)
    d = cfg.d_model
    b, s = batch, seq
    L = n_layers if n_layers is not None else cfg.n_layers

    def fn_or_none(f):
        return f if params is not None else None

    root = g.add("tokens", OpKind.INPUT, out_shape=(b, s))
    emb_w = _w(params, "embed", "table")
    x = g.add("embed", OpKind.GATHER, [root],
              fn=fn_or_none(lambda t: jnp.take(emb_w, t, axis=0)),
              cost=gather_cost(b * s, d), out_shape=(b, s, d))

    meta = stack_meta(cfg)
    layer_idx = 0
    for si, (kind, n, windows) in enumerate(meta):
        for li in range(min(n, max(L - layer_idx, 0))):
            tag = f"L{layer_idx}"
            pl = (jax.tree_util.tree_map(lambda a: a[li], _w(params, "stacks")[si])
                  if params is not None else None)
            if kind == "rwkv":
                x = _rwkv_layer(g, cfg, x, b, s, tag, pl, root)
            elif kind == "hybrid":
                x = _hybrid_layer(g, cfg, x, b, s, tag, pl,
                                  windows[li] or None, root)
            elif kind in ("moe",):
                x = _dense_layer(g, cfg, x, b, s, tag, pl, root, moe=True,
                                 moe_branch_cap=moe_branch_cap,
                                 moe_dispatch=moe_dispatch,
                                 moe_cap_scale=moe_cap_scale)
            else:
                x = _dense_layer(g, cfg, x, b, s, tag, pl, root, moe=False)
            layer_idx += 1
    x = _norm_node(g, "final_norm", x, _w(params, "final_norm"), cfg.norm,
                   b * s * d)
    head = _w(params, "embed" if cfg.tie_embeddings else "head")
    g.add("logits", OpKind.GEMM, [x],
          fn=fn_or_none(lambda h: jnp.einsum("bsd,vd->bsv", h, head["table"])),
          cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


def _rms(p, h, eps=1e-6):
    hf = h.astype(jnp.float32)
    v = jnp.mean(hf * hf, -1, keepdims=True)
    return (hf * jax.lax.rsqrt(v + eps) * p["scale"].astype(jnp.float32)).astype(h.dtype)


def _norm_node(g, name, inp, p, kind, numel, out_shape=None):
    """Pre/post-norm node.  ``out_shape`` should be declared wherever the
    graph mixes sequence lengths (encoder vs decoder): capture's stacking
    check can only veto a mixed-shape fusion group it can SEE (see
    ``capture._can_stack``)."""
    return g.add(name, OpKind.NORM, [inp],
                 fn=(lambda h: apply_norm(p, h, kind)) if p is not None else None,
                 cost=norm_cost(numel), out_shape=out_shape)


def _matmul(h, w):
    return jnp.einsum("...i,io->...o", h, w)


def _matmul_bias(h, w, bias):
    return jnp.einsum("...i,io->...o", h, w) + bias


def _gemm_node(g, name, inp, pl_linear, m, k, n, bias: bool = False,
               cost=None, fuse_sig=None, out_shape=None):
    """GEMM node following the capture contract: weights go in
    meta["consts"] so same-signature branches stack into one fused kernel.

    EVERY GEMM-semantics node the exporter emits goes through here — expert
    fan-out and conv-like frontend projections included — so whenever a
    weight is threaded the node automatically carries ``payload="matmul"``,
    the capturer's routing contract for the fused branch_gemm Pallas kernel
    (no hand-placed markers).  ``cost`` / ``fuse_sig`` override the
    defaults for nodes whose analytic cost is not the plain (m, k, n)
    roofline (e.g. capacity-scaled expert branches).
    """
    cost = cost if cost is not None else gemm_cost(m, k, n)
    fuse_sig = fuse_sig if fuse_sig is not None else ("gemm", k, n, bias)
    if pl_linear is None:
        return g.add(name, OpKind.GEMM, [inp], cost=cost, fuse_sig=fuse_sig,
                     out_shape=out_shape)
    if isinstance(pl_linear, dict):
        consts = (pl_linear["w"],) + ((pl_linear["b"],) if bias else ())
    else:  # a bare weight array (expert slices) — carries no bias term
        assert not bias, f"{name}: bare-array weight cannot supply a bias"
        consts = (pl_linear,)
    return g.add(name, OpKind.GEMM, [inp],
                 fn=_matmul_bias if bias else _matmul,
                 cost=cost, fuse_sig=fuse_sig, consts=consts,
                 out_shape=out_shape, payload="matmul")


def _ffn_gemm(g, name, inp, root, pl_linear, m, k, n, bias: bool = False,
              fuse_sig=None, out_shape=None):
    """Large FF projection.  Cost-only exports split it into a
    weight-stream DMA (GATHER rooted at the graph input, prefetchable
    arbitrarily early) + an activation-roofline GEMM — the paper's
    compute/memory-overlap pair.  Payload-backed exports keep the single
    matmul-marked node (one graph input; the weight rides in ``consts``),
    mirroring the ``moe_dispatch="auto"`` topology-split precedent.
    """
    if pl_linear is not None:
        return _gemm_node(g, name, inp, pl_linear, m, k, n, bias,
                          fuse_sig=fuse_sig, out_shape=out_shape)
    w = g.add(f"{name}_wstream", OpKind.GATHER, [root],
              cost=stream_cost(k * n * 2))
    return g.add(name, OpKind.GEMM, [inp, w], cost=act_gemm_cost(m, k, n),
                 fuse_sig=fuse_sig if fuse_sig is not None
                 else ("gemm", k, n, bias),
                 out_shape=out_shape)


# -- decomposed attention core -----------------------------------------------
#
# Numerics mirror attention._sdpa exactly on head-major tensors: fp32
# logits/softmax, probabilities cast to V's dtype for the context matmul.
# Stage payloads are module-level / lru-cached so identical stages across
# layers share one fn object and stack into fused kernels at capture.

@functools.lru_cache(maxsize=None)
def _make_split_heads(heads: int):
    def split_heads(x):
        b, s, dd = x.shape
        return x.reshape(b, s, heads, dd // heads).transpose(0, 2, 1, 3)
    return split_heads


def _scores_payload(q, k):
    """q: [B,H,S,Dk] head-major; k: [B,KVH,T,Dk] → logits [B,H,S,T] fp32."""
    b, nh, s, hd = q.shape
    kvh, t = k.shape[1], k.shape[2]
    qg = q.reshape(b, kvh, nh // kvh, s, hd)
    return jnp.einsum("bkgsd,bktd->bkgst", qg, k,
                      preferred_element_type=jnp.float32).reshape(b, nh, s, t)


@functools.lru_cache(maxsize=None)
def _make_scale_mask(scale: float, window: int | None, causal: bool):
    def scale_mask(x):
        s, t = x.shape[-2], x.shape[-1]
        x = x * scale
        if causal:
            m = causal_window_mask(jnp.arange(s), jnp.arange(t), window)
            x = jnp.where(m, x, NEG_INF)
        return x
    return scale_mask


def _softmax_payload(x):
    return jax.nn.softmax(x, axis=-1)


def _ctx_payload(p, v):
    """p: [B,H,S,T] fp32 probs; v: [B,KVH,T,Dv] → ctx [B,H,S,Dv]."""
    b, nh, s, t = p.shape
    kvh, dv = v.shape[1], v.shape[-1]
    pg = p.reshape(b, kvh, nh // kvh, s, t).astype(v.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", pg, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, nh, s, dv).astype(v.dtype)


def _merge_heads(x):
    b, nh, s, dv = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, nh * dv)


def _attn_core(g, pre, qt, kt, vt, b, s, t, nh, kvh, hd, dv,
               scale, causal, window, with_fn):
    """scores → scale+mask → softmax → ctx → head-merge, from head-major
    Q/K/V nodes.  The scores/ctx pair carries exactly the 4·b·h·s·t·d
    attention FLOPs (2·m·k·n each); mask/softmax are the memory-bound
    stages the scheduler overlaps with neighboring GEMMs."""
    def F(f):
        return f if with_fn else None
    sc = g.add(f"{pre}scores", OpKind.GEMM, [qt, kt], fn=F(_scores_payload),
               cost=gemm_cost(b * nh * s, hd, t),
               fuse_sig=("qk", s, t, hd), out_shape=(b, nh, s, t))
    sm = g.add(f"{pre}scale_mask", OpKind.ELEMENTWISE, [sc],
               fn=F(_make_scale_mask(scale, window, causal)),
               cost=elementwise_cost(b * nh * s * t, 4, flops_per_elem=2),
               fuse_sig=("mask", s, t, scale, window, causal),
               out_shape=(b, nh, s, t))
    sx = g.add(f"{pre}softmax", OpKind.REDUCE, [sm], fn=F(_softmax_payload),
               cost=elementwise_cost(b * nh * s * t, 4, flops_per_elem=5),
               fuse_sig=("smax", s, t), out_shape=(b, nh, s, t))
    cx = g.add(f"{pre}ctx", OpKind.GEMM, [sx, vt], fn=F(_ctx_payload),
               cost=gemm_cost(b * nh * s, t, dv),
               fuse_sig=("pv", s, t, dv), out_shape=(b, nh, s, dv))
    return g.add(f"{pre}ctxt", OpKind.ELEMENTWISE, [cx], fn=F(_merge_heads),
                 cost=elementwise_cost(b * s * nh * dv),
                 fuse_sig=("mrg", s, nh, dv), out_shape=(b, s, nh * dv))


def _attn_stages(g, pre, q, k, v, b, s, t, nh, kvh, hd,
                 scale=None, causal=True, window=None, with_fn=False):
    """Full decomposed attention from flat [B,S,H·D] projection outputs:
    three head-split transpose copies (the memory-intensive stage bert/t5
    already export), then :func:`_attn_core`."""
    scale = hd ** -0.5 if scale is None else float(scale)

    def F(f):
        return f if with_fn else None
    qt = g.add(f"{pre}qt", OpKind.ELEMENTWISE, [q],
               fn=F(_make_split_heads(nh)),
               cost=elementwise_cost(b * s * nh * hd),
               fuse_sig=("tps", s, nh, hd), out_shape=(b, nh, s, hd))
    kt = g.add(f"{pre}kt", OpKind.ELEMENTWISE, [k],
               fn=F(_make_split_heads(kvh)),
               cost=elementwise_cost(b * t * kvh * hd),
               fuse_sig=("tps", t, kvh, hd), out_shape=(b, kvh, t, hd))
    vt = g.add(f"{pre}vt", OpKind.ELEMENTWISE, [v],
               fn=F(_make_split_heads(kvh)),
               cost=elementwise_cost(b * t * kvh * hd),
               fuse_sig=("tps", t, kvh, hd), out_shape=(b, kvh, t, hd))
    return _attn_core(g, pre, qt, kt, vt, b, s, t, nh, kvh, hd, hd,
                      scale, causal, window, with_fn)


# -- MLA (DeepSeek-style latent attention), decomposed ------------------------

@functools.lru_cache(maxsize=None)
def _make_mla_q_lat(nh: int, nope: int, rope: int, theta: float):
    """Absorbed query: rope the rope-part, fold W_kb into q_nope
    (mla_attention's q_lat einsum), emit head-major [B,H,S,rank+rope]."""
    def q_lat(qflat, wk_b):
        b, s, _ = qflat.shape
        q = qflat.reshape(b, s, nh, nope + rope)
        q_nope, q_rope = jnp.split(q, [nope], axis=-1)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q_rope = apply_rope(q_rope, positions, theta)
        wk = wk_b.reshape(-1, nh, nope)
        lat = jnp.einsum("bshd,rhd->bshr", q_nope, wk,
                         preferred_element_type=jnp.float32).astype(qflat.dtype)
        return jnp.concatenate([lat, q_rope], axis=-1).transpose(0, 2, 1, 3)
    return q_lat


@functools.lru_cache(maxsize=None)
def _make_mla_kv_prep(rank: int, theta: float):
    """Latent KV: rmsnorm the compressed part, rope the shared k_rope,
    concatenate — ONE latent head, head-major [B,1,S,rank+rope]."""
    def kv_prep(kv, scale):
        b, s, _ = kv.shape
        c_kv, k_rope = jnp.split(kv, [rank], axis=-1)
        c_kv = _rms({"scale": scale}, c_kv)
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        k_rope = apply_rope(k_rope[:, :, None, :], positions, theta)[:, :, 0]
        return jnp.concatenate([c_kv, k_rope], axis=-1)[:, None]
    return kv_prep


@functools.lru_cache(maxsize=None)
def _make_latent_v(rank: int):
    def latent_v(kcat):
        return kcat[..., :rank]
    return latent_v


@functools.lru_cache(maxsize=None)
def _make_mla_out(nh: int, rank: int, v_head: int):
    def mla_out(lat_flat, wv_b):
        b, s, _ = lat_flat.shape
        lat = lat_flat.reshape(b, s, nh, rank)
        wv = wv_b.reshape(rank, nh, v_head)
        out = jnp.einsum("bshr,rhd->bshd", lat, wv,
                         preferred_element_type=jnp.float32).astype(lat_flat.dtype)
        return out.reshape(b, s, nh * v_head)
    return mla_out


def _mla_block(g, cfg, n1, b, s, tag, attn_p):
    """MLA at traced-kernel granularity (absorbed formulation, kvh = 1):
    low-rank Q/KV projections → latent score/context GEMMs with the
    mask+softmax stage explicit → per-head value up-projection → wo.
    Works cost-only and payload-backed alike; per-stage nodes carry their
    own vmem/occupancy instead of the old folded max-of-phases bound."""
    m, d, nh = cfg.mla, cfg.d_model, cfg.n_heads
    nope, rope, rank = m.qk_nope_head_dim, m.qk_rope_head_dim, m.kv_lora_rank
    qk_head = nope + rope
    with_fn = attn_p is not None
    cq = _gemm_node(g, f"{tag}.wq_a", n1, attn_p and attn_p["wq_a"],
                    b * s, d, m.q_lora_rank)
    qn = g.add(f"{tag}.q_norm", OpKind.NORM, [cq],
               fn=(lambda h: _rms(attn_p["q_norm"], h)) if with_fn else None,
               cost=norm_cost(b * s * m.q_lora_rank))
    qb = _gemm_node(g, f"{tag}.wq_b", qn, attn_p and attn_p["wq_b"],
                    b * s, m.q_lora_rank, nh * qk_head)
    q_lat = g.add(f"{tag}.q_lat", OpKind.GEMM, [qb],
                  fn=_make_mla_q_lat(nh, nope, rope, cfg.rope_theta)
                  if with_fn else None,
                  cost=gemm_cost(b * s * nh, nope, rank),
                  fuse_sig=("qlat", s, nh, nope, rank),
                  out_shape=(b, nh, s, rank + rope),
                  **({"consts": (attn_p["wk_b"]["w"],)} if with_fn else {}))
    kva = _gemm_node(g, f"{tag}.wkv_a", n1, attn_p and attn_p["wkv_a"],
                     b * s, d, rank + rope)
    kvp = g.add(f"{tag}.kv_prep", OpKind.NORM, [kva],
                fn=_make_mla_kv_prep(rank, cfg.rope_theta)
                if with_fn else None,
                cost=norm_cost(b * s * (rank + rope)),
                fuse_sig=("mlakv", s, rank, rope),
                out_shape=(b, 1, s, rank + rope),
                **({"consts": (attn_p["kv_norm"]["scale"],)} if with_fn else {}))
    vlat = g.add(f"{tag}.v_lat", OpKind.ELEMENTWISE, [kvp],
                 fn=_make_latent_v(rank) if with_fn else None,
                 cost=elementwise_cost(b * s * rank),
                 fuse_sig=("vlat", s, rank), out_shape=(b, 1, s, rank))
    mrg = _attn_core(g, f"{tag}.", q_lat, kvp, vlat, b, s, s, nh, 1,
                     rank + rope, rank, scale=qk_head ** -0.5, causal=True,
                     window=None, with_fn=with_fn)
    aout = g.add(f"{tag}.attn_out", OpKind.GEMM, [mrg],
                 fn=_make_mla_out(nh, rank, m.v_head_dim)
                 if with_fn else None,
                 cost=gemm_cost(b * s * nh, rank, m.v_head_dim),
                 fuse_sig=("mlaout", s, nh, rank, m.v_head_dim),
                 **({"consts": (attn_p["wv_b"]["w"],)} if with_fn else {}))
    return _gemm_node(g, f"{tag}.wo", aout, attn_p and attn_p["wo"],
                      b * s, nh * m.v_head_dim, d)


def _dense_layer(g, cfg, x, b, s, tag, pl, root, moe: bool,
                 moe_branch_cap: int = 16, moe_dispatch: str = "auto",
                 moe_cap_scale: float = 1.0):
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    bias = cfg.qkv_bias
    n1 = _norm_node(g, f"{tag}.norm1", x, pl and pl["norm1"], cfg.norm,
                    b * s * d)
    attn_p = pl["attn"] if pl else None
    if cfg.mla is not None:
        o = _mla_block(g, cfg, n1, b, s, tag, attn_p)
    else:
        # QKV: 3 parallel GEMM branches (the canonical Opara wave) feeding
        # the decomposed attention stages
        q = _gemm_node(g, f"{tag}.wq", n1, attn_p and attn_p["wq"], b * s, d, nh * hd, bias)
        k = _gemm_node(g, f"{tag}.wk", n1, attn_p and attn_p["wk"], b * s, d, kvh * hd, bias)
        v = _gemm_node(g, f"{tag}.wv", n1, attn_p and attn_p["wv"], b * s, d, kvh * hd, bias)
        mrg = _attn_stages(g, f"{tag}.", q, k, v, b, s, s, nh, kvh, hd,
                           causal=True, window=None, with_fn=pl is not None)
        o = _gemm_node(g, f"{tag}.wo", mrg, attn_p and attn_p["wo"], b * s, nh * hd, d, False)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = _norm_node(g, f"{tag}.norm2", r1, pl and pl["norm2"], cfg.norm,
                    b * s * d)
    if not moe:
        dff = cfg.d_ff
        ffn_p = pl["ffn"] if pl else None
        gate = _ffn_gemm(g, f"{tag}.gate", n2, root, ffn_p and ffn_p["gate"],
                         b * s, d, dff)
        up = _ffn_gemm(g, f"{tag}.up", n2, root, ffn_p and ffn_p["up"],
                       b * s, d, dff)
        prod = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                     fn=(lambda a, c: jax.nn.silu(a) * c) if pl else None,
                     cost=elementwise_cost(b * s * dff, n_in=2, flops_per_elem=5))
        down = _ffn_gemm(g, f"{tag}.down", prod, root, ffn_p and ffn_p["down"],
                         b * s, dff, d)
    elif moe_dispatch == "ragged" or (moe_dispatch == "auto" and pl is not None):
        down = _moe_ragged_block(g, cfg, n2, b, s, tag,
                                 pl["ffn"] if pl else None, moe_branch_cap,
                                 moe_cap_scale)
    else:
        e = cfg.moe
        moe_p = pl["ffn"] if pl else None
        router = g.add(f"{tag}.router", OpKind.REDUCE, [n2],
                       cost=gemm_cost(b * s, d, e.n_experts))
        disp = g.add(f"{tag}.dispatch", OpKind.SCATTER, [n2, router],
                     cost=gather_cost(b * s * e.top_k, d))
        nb = min(e.n_experts, moe_branch_cap)
        tok_per_branch = b * s * e.top_k / e.n_experts * (e.n_experts / nb)
        outs = []
        for j in range(nb):
            # per-branch expert weight from the stacked [E, d, d_e] params:
            # gate|up|downᵀ concatenated to [d, 3·d_e], so the x@w payload
            # performs exactly the FLOPs the analytic cost models (one
            # [d → 3·d_e] GEMM per branch) and the branch carries the matmul
            # marker, stacking with its siblings into ONE fused branch_gemm
            # kernel at capture.  Params-threaded exports are smoke-size by
            # construction, so the concat allocation is negligible.
            ew = (jnp.concatenate(
                      [moe_p["experts"]["gate"][j],
                       moe_p["experts"]["up"][j],
                       moe_p["experts"]["down"][j].T], axis=1)
                  if moe_p is not None else None)
            eb = _gemm_node(g, f"{tag}.expert{j}", disp, ew,
                            int(tok_per_branch), d, 3 * e.d_expert,
                            fuse_sig=("egemm", d, e.d_expert))
            outs.append(eb)
        if e.n_shared:
            sp = (moe_p["shared"]
                  if moe_p is not None and "shared" in moe_p else None)
            sw = (jnp.concatenate([sp["gate"]["w"], sp["up"]["w"],
                                   sp["down"]["w"].T], axis=1)
                  if sp is not None else None)
            outs.append(_gemm_node(g, f"{tag}.shared_expert", n2, sw,
                                   b * s, d, 3 * e.d_expert * e.n_shared))
        down = g.add(f"{tag}.combine", OpKind.SCATTER, outs + [router],
                     cost=gather_cost(b * s * e.top_k, d))
    out = g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                fn=(lambda a, c: a + c) if pl else None,
                cost=elementwise_cost(b * s * d, n_in=2))
    return out


def _sum_costs(*costs):
    """Combine analytic costs of ops folded into one node: traffic and
    FLOPs add; working set and occupancy are bounded by the widest phase.

    The exporter no longer folds attention phases (every stage node carries
    its OWN vmem/occupancy now); this stays as the documented folding rule —
    tests pin that a folded cost equals the field-wise sum/max of the
    decomposed per-stage costs it replaced."""
    from ..core.graph import OpCost
    occ = [c.occupancy for c in costs if c.occupancy is not None]
    return OpCost(
        flops=sum(c.flops for c in costs),
        bytes_read=sum(c.bytes_read for c in costs),
        bytes_written=sum(c.bytes_written for c in costs),
        vmem_bytes=max(c.vmem_bytes for c in costs),
        occupancy=max(occ) if occ else None)


# -- routed (ragged) MoE fan-out ---------------------------------------------
#
# The dispatch/combine payloads both recompute the routing decision from the
# router node's logits — pure, deterministic, and cheap next to the expert
# GEMMs, so the graph needs no multi-output nodes and XLA CSEs the repeated
# top-k inside the captured single program.

def _moe_capacities(n_tokens: int, e, nb: int, top_k: int) -> tuple[int, ...]:
    """Static per-expert capacities, deliberately UNEQUAL (0.5×–1.5× the
    mean routed load) so the exported fan-out is genuinely ragged and
    exercises the grouped ragged-M kernel; the total stays at roughly
    ``capacity_factor`` × routed tokens, the moe_gemm capacity-buffer
    budget."""
    base = n_tokens * top_k / nb * e.capacity_factor
    return tuple(max(1, int(round(base * (0.5 + j / max(nb - 1, 1)))))
                 for j in range(nb))


def _topk_routing(logits, nb: int, top_k: int, aux_free: bool):
    """(combine weights [N, k], expert ids [N, k]) from router logits —
    the same softmax/sigmoid selection rule as :func:`repro.models.ffn.route`
    (without the balancing bias, which is zero at init)."""
    lf = logits.reshape(-1, nb).astype(jnp.float32)
    scores = jax.nn.sigmoid(lf) if aux_free else jax.nn.softmax(lf, axis=-1)
    top_w, top_idx = jax.lax.top_k(scores, top_k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_idx


def _make_dispatch(j: int, cap: int, nb: int, top_k: int, aux_free: bool):
    """Per-expert token gather: the ``cap`` rows routed to expert ``j``
    (capacity-truncated, zero-padded when fewer arrive).  The cumsum rank
    equals the within-expert rank of a stable sort by expert id — identical
    overflow semantics to the production sort dispatch
    (:func:`repro.models.ffn.moe_ffn_sort`)."""
    def dispatch(h, logits):
        d = h.shape[-1]
        xf = h.reshape(-1, d)
        _, top_idx = _topk_routing(logits, nb, top_k, aux_free)
        expert_flat = top_idx.reshape(-1)                       # [N·k]
        tok = jnp.repeat(jnp.arange(xf.shape[0], dtype=jnp.int32), top_k)
        mine = expert_flat == j
        rank = jnp.cumsum(mine) - mine                          # rank within j
        take = mine & (rank < cap)
        slot = jnp.where(take, rank, cap)                       # cap = drop row
        buf = jnp.zeros((cap + 1, d), xf.dtype).at[slot].add(
            xf[tok] * take[:, None].astype(xf.dtype))
        return buf[:cap]
    return dispatch


def _make_glu(dff: int):
    def glu(h):
        return jax.nn.silu(h[..., :dff]) * h[..., dff:]
    return glu


def _make_combine(caps: tuple[int, ...], nb: int, top_k: int, aux_free: bool):
    """Weighted scatter-add of the per-expert outputs back to token order:
    each (token, k) pair re-derives its expert + within-expert rank exactly
    as the dispatch nodes did, reads that row of the concatenated expert
    outputs, and sums ``router_weight × row`` over k (capacity-dropped
    pairs contribute zero)."""
    offs = []
    off = 0
    for c in caps:
        offs.append(off)
        off += c

    def combine(*args):
        *eouts, h, logits = args
        d = h.shape[-1]
        xf = h.reshape(-1, d)
        n = xf.shape[0]
        top_w, top_idx = _topk_routing(logits, nb, top_k, aux_free)
        expert_flat = top_idx.reshape(-1)                       # [N·k]
        w_flat = top_w.reshape(-1)
        onehot = expert_flat[:, None] == jnp.arange(nb)[None, :]
        ranks = jnp.cumsum(onehot, axis=0) - onehot
        rank = jnp.take_along_axis(
            ranks, expert_flat[:, None], axis=1)[:, 0]
        caps_arr = jnp.asarray(caps, jnp.int32)
        offs_arr = jnp.asarray(offs, jnp.int32)
        cap_e = caps_arr[expert_flat]
        take = rank < cap_e
        row = offs_arr[expert_flat] + jnp.minimum(rank, cap_e - 1)
        allout = jnp.concatenate(eouts, axis=0)                 # [ΣC, d]
        rows = allout[row] * (w_flat * take).astype(allout.dtype)[:, None]
        y = rows.reshape(n, top_k, d).sum(axis=1)
        return y.reshape(h.shape).astype(h.dtype)
    return combine


def _moe_ragged_block(g, cfg, n2, b, s, tag, moe_p, moe_branch_cap,
                      cap_scale: float = 1.0):
    """Routed expert fan-out with REAL dispatch/combine payloads.

    router → nb parallel per-expert gathers (unequal static capacities) →
    TWO grouped ragged-M GEMM waves (gate∥up, then down — each stacks into
    ONE ``grouped_gemm`` kernel at capture because the branches share
    ``(K, F)`` but differ in M) → weighted scatter-add combine (+ the
    always-on shared expert).  Fan-out is capped at ``moe_branch_cap``
    branches; routing is then restricted to the first nb experts, so the
    exported math stays self-consistent (the differential oracle runs the
    same payloads per-op).  ``cap_scale`` < 1 shrinks the static capacities
    to force genuine overflow re-routing."""
    e = cfg.moe
    d, de = cfg.d_model, e.d_expert
    nb = min(e.n_experts, moe_branch_cap)
    top_k = min(e.top_k, nb)
    caps = tuple(max(1, int(round(c * cap_scale)))
                 for c in _moe_capacities(b * s, e, nb, top_k))
    rw = (jnp.asarray(moe_p["router"]["w"], jnp.float32)[:, :nb]
          if moe_p is not None else None)
    router = g.add(
        f"{tag}.router", OpKind.REDUCE, [n2],
        fn=(lambda h: jnp.einsum("...d,de->...e", h.astype(jnp.float32), rw))
        if moe_p is not None else None,
        cost=gemm_cost(b * s, d, e.n_experts),
        out_shape=(b, s, nb), out_dtype=jnp.float32)
    outs = []
    for j in range(nb):
        disp = g.add(
            f"{tag}.dispatch{j}", OpKind.GATHER, [n2, router],
            fn=(_make_dispatch(j, caps[j], nb, top_k, e.router_aux_free)
                if moe_p is not None else None),
            cost=gather_cost(caps[j], d), out_shape=(caps[j], d))
        ew = (jnp.concatenate([moe_p["experts"]["gate"][j],
                               moe_p["experts"]["up"][j]], axis=1)
              if moe_p is not None else None)
        h = _gemm_node(g, f"{tag}.expert{j}_in", disp, ew,
                       caps[j], d, 2 * de,
                       fuse_sig=("egemm_in", d, 2 * de),
                       out_shape=(caps[j], 2 * de))
        glu = g.add(f"{tag}.expert{j}_glu", OpKind.ELEMENTWISE, [h],
                    fn=_make_glu(de) if moe_p is not None else None,
                    cost=elementwise_cost(caps[j] * de, n_in=1,
                                          flops_per_elem=5),
                    out_shape=(caps[j], de))
        outs.append(_gemm_node(
            g, f"{tag}.expert{j}_down", glu,
            moe_p["experts"]["down"][j] if moe_p is not None else None,
            caps[j], de, d, fuse_sig=("egemm_down", de, d),
            out_shape=(caps[j], d)))
    comb = g.add(
        f"{tag}.combine", OpKind.SCATTER, outs + [n2, router],
        fn=(_make_combine(caps, nb, top_k, e.router_aux_free)
            if moe_p is not None else None),
        cost=gather_cost(b * s * e.top_k, d))
    if not e.n_shared:
        return comb
    dsh = de * e.n_shared
    sp = (moe_p["shared"]
          if moe_p is not None and "shared" in moe_p else None)
    sw = (jnp.concatenate([sp["gate"]["w"], sp["up"]["w"]], axis=1)
          if sp is not None else None)
    sh = _gemm_node(g, f"{tag}.shared_in", n2, sw, b * s, d, 2 * dsh,
                    fuse_sig=("sgemm_in", d, 2 * dsh))
    shg = g.add(f"{tag}.shared_glu", OpKind.ELEMENTWISE, [sh],
                fn=_make_glu(dsh) if sp is not None else None,
                cost=elementwise_cost(b * s * dsh, n_in=1, flops_per_elem=5))
    shd = _gemm_node(g, f"{tag}.shared_down", shg,
                     sp["down"]["w"] if sp is not None else None,
                     b * s, dsh, d, fuse_sig=("sgemm_down", dsh, d))
    return g.add(f"{tag}.moe_out", OpKind.ELEMENTWISE, [comb, shd],
                 fn=(lambda a, c: a + c) if moe_p is not None else None,
                 cost=elementwise_cost(b * s * d, n_in=2))


# -- Hymba (parallel attention ∥ mamba) ---------------------------------------

def _mamba_conv_payload(xz, w):
    """Split in_proj output, causal depthwise conv + silu on the x half
    (zero prefill conv state, exactly ssm._mamba_conv_seq), carry z along."""
    di = xz.shape[-1] // 2
    xi, z = xz[..., :di], xz[..., di:]
    k = w.shape[0]
    xp = jnp.concatenate(
        [jnp.zeros((xi.shape[0], k - 1, di), xi.dtype), xi], axis=1)
    out = sum(xp[:, i: i + xi.shape[1]] * w[i][None, None].astype(xi.dtype)
              for i in range(k))
    return jnp.concatenate([jax.nn.silu(out), z], axis=-1)


def _mamba_xproj_payload(xz, w):
    """B/C/dt projection of the conved x half; emits [x ‖ z ‖ bcd] so the
    scan stage needs a single input edge."""
    di = xz.shape[-1] // 2
    bcd = jnp.einsum("...i,io->...o", xz[..., :di], w)
    return jnp.concatenate([xz, bcd], axis=-1)


def _mamba_scan_payload(packed, a_log, d_skip):
    """Discretize + selective scan + skip + silu(z) gate
    (exactly ssm.mamba_seq's tail on a zero initial state)."""
    di, n = a_log.shape
    xi = packed[..., :di]
    z = packed[..., di:2 * di]
    bcd = packed[..., 2 * di:]
    bmat, cmat, dt_raw = jnp.split(bcd, [n, 2 * n], axis=-1)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32)) + 1e-4
    a = -jnp.exp(a_log)
    h0 = jnp.zeros((xi.shape[0], di, n), jnp.float32)
    _, ys = mamba_scan_ref(delta, xi.astype(jnp.float32),
                           bmat.astype(jnp.float32),
                           cmat.astype(jnp.float32), a, h0)
    y = ys + xi.astype(jnp.float32) * d_skip[None, None]
    return y.astype(packed.dtype) * jax.nn.silu(z)


def _head_mix(a, c):
    return 0.5 * (a + c)


def _hybrid_layer(g, cfg, x, b, s, tag, pl, window, root):
    """Hymba: attention and mamba heads in PARALLEL — the paper's Fig. 3
    compute∥memory overlap case (attn = MXU-bound, SSM scan = HBM-bound).
    Both branches now carry real payloads; the sliding window enters as a
    mask (costs use the full s×t logits the naive payload materializes)."""
    d, hd, nh, kvh = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ssm = cfg.ssm
    di = ssm.expand * d
    with_fn = pl is not None
    attn_p = pl["attn"] if pl else None
    mp = pl["mamba"] if pl else None
    n1 = _norm_node(g, f"{tag}.norm1", x, pl and pl["norm1"], cfg.norm,
                    b * s * d)
    q = _gemm_node(g, f"{tag}.wq", n1, attn_p and attn_p["wq"],
                   b * s, d, nh * hd, cfg.qkv_bias)
    k = _gemm_node(g, f"{tag}.wk", n1, attn_p and attn_p["wk"],
                   b * s, d, kvh * hd, cfg.qkv_bias)
    v = _gemm_node(g, f"{tag}.wv", n1, attn_p and attn_p["wv"],
                   b * s, d, kvh * hd, cfg.qkv_bias)
    mrg = _attn_stages(g, f"{tag}.", q, k, v, b, s, s, nh, kvh, hd,
                       causal=True, window=window, with_fn=with_fn)
    o = _gemm_node(g, f"{tag}.wo", mrg, attn_p and attn_p["wo"],
                   b * s, nh * hd, d)
    # parallel mamba branch (memory-bound scan against the MXU wave above)
    inp = _gemm_node(g, f"{tag}.mamba_in", n1, mp and mp["in_proj"],
                     b * s, d, 2 * di)
    conv = g.add(f"{tag}.mamba_conv", OpKind.ELEMENTWISE, [inp],
                 fn=_mamba_conv_payload if with_fn else None,
                 cost=elementwise_cost(b * s * di, n_in=1, flops_per_elem=8),
                 fuse_sig=("mconv", s, di),
                 **({"consts": (mp["conv_w"],)} if with_fn else {}))
    xproj = g.add(f"{tag}.mamba_xproj", OpKind.GEMM, [conv],
                  fn=_mamba_xproj_payload if with_fn else None,
                  cost=gemm_cost(b * s, di, 2 * ssm.state_dim + 1),
                  fuse_sig=("mxproj", s, di, ssm.state_dim),
                  **({"consts": (mp["x_proj"]["w"],)} if with_fn else {}))
    scan = g.add(f"{tag}.mamba_scan", OpKind.SCAN, [xproj],
                 fn=_mamba_scan_payload if with_fn else None,
                 cost=scan_cost(b, s, di, ssm.state_dim),
                 fuse_sig=("mscan", s, di, ssm.state_dim),
                 **({"consts": (mp["a_log"], mp["d_skip"])} if with_fn else {}))
    mo = _gemm_node(g, f"{tag}.mamba_out", scan, mp and mp["out_proj"],
                    b * s, di, d)
    mix = g.add(f"{tag}.head_mix", OpKind.ELEMENTWISE, [o, mo],
                fn=_head_mix if with_fn else None,
                cost=elementwise_cost(b * s * d, n_in=2))
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, mix],
               fn=(lambda a, c: a + c) if with_fn else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = _norm_node(g, f"{tag}.norm2", r1, pl and pl["norm2"], cfg.norm,
                    b * s * d)
    ffn_p = pl["ffn"] if pl else None
    gate = _ffn_gemm(g, f"{tag}.gate", n2, root, ffn_p and ffn_p["gate"],
                     b * s, d, cfg.d_ff)
    up = _ffn_gemm(g, f"{tag}.up", n2, root, ffn_p and ffn_p["up"],
                   b * s, d, cfg.d_ff)
    glu = g.add(f"{tag}.glu", OpKind.ELEMENTWISE, [gate, up],
                fn=(lambda a, c: jax.nn.silu(a) * c) if with_fn else None,
                cost=elementwise_cost(b * s * cfg.d_ff, n_in=2, flops_per_elem=5))
    down = _ffn_gemm(g, f"{tag}.down", glu, root, ffn_p and ffn_p["down"],
                     b * s, cfg.d_ff, d)
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, down],
                 fn=(lambda a, c: a + c) if with_fn else None,
                 cost=elementwise_cost(b * s * d, n_in=2))


# -- encoder-decoder (Whisper) ------------------------------------------------

def _encdec_attn(g, pre, src_q, src_kv, ap, cfg, b, s, t, causal):
    """Projection markers + decomposed stages for one (self or cross)
    attention; ``src_q``/``src_kv`` may differ (cross-attention reads the
    encoder output for K/V — the parallel branch the paper highlights for
    T5, Fig. 7a)."""
    d, nh, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _gemm_node(g, f"{pre}wq", src_q, ap and ap["wq"],
                   b * s, d, nh * hd, cfg.qkv_bias)
    k = _gemm_node(g, f"{pre}wk", src_kv, ap and ap["wk"],
                   b * t, d, kvh * hd, cfg.qkv_bias)
    v = _gemm_node(g, f"{pre}wv", src_kv, ap and ap["wv"],
                   b * t, d, kvh * hd, cfg.qkv_bias)
    mrg = _attn_stages(g, pre, q, k, v, b, s, t, nh, kvh, hd,
                       causal=causal, with_fn=ap is not None)
    return _gemm_node(g, f"{pre}wo", mrg, ap and ap["wo"],
                      b * s, nh * hd, d)


def _encdec_ffn(g, pre, r_in, n2_src, root, ffn_p, cfg, b, t):
    """norm2 → FF (gelu: up→act→down; swiglu: gate∥up→glu→down) → res.

    Shapes are declared on the activation node: encoder and decoder FF
    stages share fuse signatures but differ in sequence length, and capture
    must SEE that to keep them out of one stacked kernel."""
    d, dff = cfg.d_model, cfg.d_ff
    m = b * t
    with_fn = ffn_p is not None
    if cfg.act == "swiglu":
        gate = _ffn_gemm(g, f"{pre}gate", n2_src, root,
                         ffn_p and ffn_p["gate"], m, d, dff)
        up = _ffn_gemm(g, f"{pre}up", n2_src, root,
                       ffn_p and ffn_p["up"], m, d, dff)
        act = g.add(f"{pre}glu", OpKind.ELEMENTWISE, [gate, up],
                    fn=(lambda a, c: jax.nn.silu(a) * c) if with_fn else None,
                    cost=elementwise_cost(m * dff, n_in=2, flops_per_elem=5),
                    out_shape=(b, t, dff))
    else:
        up = _ffn_gemm(g, f"{pre}up", n2_src, root,
                       ffn_p and ffn_p["up"], m, d, dff)
        act = g.add(f"{pre}act", OpKind.ELEMENTWISE, [up],
                    fn=(lambda h: gelu(h)) if with_fn else None,
                    cost=elementwise_cost(m * dff, n_in=1, flops_per_elem=8),
                    out_shape=(b, t, dff))
    dn = _ffn_gemm(g, f"{pre}down", act, root, ffn_p and ffn_p["down"],
                   m, dff, d)
    return g.add(f"{pre}res2", OpKind.ELEMENTWISE, [r_in, dn],
                 fn=(lambda a, c: a + c) if with_fn else None,
                 cost=elementwise_cost(m * d, n_in=2))


def _enc_layer(g, cfg, enc, b, es, l, pl, root):
    d = cfg.d_model
    n1 = _norm_node(g, f"e{l}.norm1", enc, pl and pl["norm1"], cfg.norm,
                    b * es * d, out_shape=(b, es, d))
    o = _encdec_attn(g, f"e{l}.", n1, n1, pl and pl["attn"], cfg,
                     b, es, es, causal=False)
    r1 = g.add(f"e{l}.res1", OpKind.ELEMENTWISE, [enc, o],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * es * d, n_in=2))
    n2 = _norm_node(g, f"e{l}.norm2", r1, pl and pl["norm2"], cfg.norm,
                    b * es * d, out_shape=(b, es, d))
    return _encdec_ffn(g, f"e{l}.", r1, n2, root, pl and pl["ffn"], cfg,
                       b, es)


def _dec_layer(g, cfg, dec, enc_out, b, s, es, l, pl, root):
    """Mirrors encdec.decoder_block_seq: self-attn → cross-attn (K/V from
    the encoder, a branch parallel to the self-attention chain) → FFN."""
    d = cfg.d_model
    n1 = _norm_node(g, f"d{l}.norm1", dec, pl and pl["norm1"], cfg.norm,
                    b * s * d, out_shape=(b, s, d))
    o = _encdec_attn(g, f"d{l}.", n1, n1, pl and pl["self_attn"], cfg,
                     b, s, s, causal=True)
    r1 = g.add(f"d{l}.res1", OpKind.ELEMENTWISE, [dec, o],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    nx = _norm_node(g, f"d{l}.norm_x", r1, pl and pl["norm_x"], cfg.norm,
                    b * s * d, out_shape=(b, s, d))
    co = _encdec_attn(g, f"d{l}.cross_", nx, enc_out,
                      pl and pl["cross_attn"], cfg, b, s, es, causal=False)
    rx = g.add(f"d{l}.res_x", OpKind.ELEMENTWISE, [r1, co],
               fn=(lambda a, c: a + c) if pl else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = _norm_node(g, f"d{l}.norm2", rx, pl and pl["norm2"], cfg.norm,
                    b * s * d, out_shape=(b, s, d))
    return _encdec_ffn(g, f"d{l}.", rx, n2, root, pl and pl["ffn"], cfg,
                       b, s)


def build_encdec_opgraph(cfg: ModelConfig, batch: int, dec_seq: int,
                         n_layers: int | None = None,
                         params: Any = None) -> OpGraph:
    """Whisper/T5-style encoder-decoder DAG at traced-kernel granularity:
    the encoder chain and the decoder's cross-attention K/V projections are
    parallel branches until the first cross-attend — the operator-diversity
    case the paper highlights for T5 (Fig. 7a).  ``params`` (an
    ``init_encdec`` tree) threads real payloads through every node,
    mirroring ``encdec.encode``/``decode_seq`` prefill math."""
    g = OpGraph(cfg.name)
    d = cfg.d_model
    b = batch
    fe = cfg.frontend
    L = n_layers if n_layers is not None else cfg.n_layers
    Ld = n_layers if n_layers is not None else (cfg.n_dec_layers or cfg.n_layers)
    es = fe.n_tokens if fe else 1500
    feat = fe.feat_dim if fe else d
    with_fn = params is not None

    frames = g.add("frames", OpKind.INPUT, out_shape=(b, es, feat))
    # conv-style audio frontend lowered as an im2col GEMM — routed through
    # _gemm_node so the matmul payload marker appears the moment weights are
    # threaded (no hand-placed markers, ROADMAP item)
    enc = _gemm_node(g, "frontend_proj", frames,
                     params and params["frontend_proj"],
                     b * es, feat, d, bias=True)
    pe = _w(params, "enc_pos")
    enc = g.add("enc_pos", OpKind.ELEMENTWISE, [enc],
                fn=(lambda h: h + pe[None, : h.shape[1]].astype(h.dtype))
                if with_fn else None,
                cost=elementwise_cost(b * es * d))
    for l in range(L):
        pl = (jax.tree_util.tree_map(lambda a: a[l], params["enc_blocks"])
              if with_fn else None)
        enc = _enc_layer(g, cfg, enc, b, es, l, pl, frames)
    enc = _norm_node(g, "enc_norm", enc, _w(params, "enc_norm"), cfg.norm,
                     b * es * d, out_shape=(b, es, d))

    tokens = g.add("tokens", OpKind.INPUT, out_shape=(b, dec_seq))
    et = _w(params, "embed", "table")
    dec = g.add("dec_embed", OpKind.GATHER, [tokens],
                fn=(lambda t: jnp.take(et, t, axis=0)) if with_fn else None,
                cost=gather_cost(b * dec_seq, d))
    dp = _w(params, "dec_pos")
    s = dec_seq
    dec = g.add("dec_pos", OpKind.ELEMENTWISE, [dec],
                fn=(lambda h: h + dp[None, : h.shape[1]].astype(h.dtype))
                if with_fn else None,
                cost=elementwise_cost(b * s * d))
    for l in range(Ld):
        pl = (jax.tree_util.tree_map(lambda a: a[l], params["dec_blocks"])
              if with_fn else None)
        dec = _dec_layer(g, cfg, dec, enc, b, s, es, l, pl, tokens)
    dec = _norm_node(g, "dec_norm", dec, _w(params, "dec_norm"), cfg.norm,
                     b * s * d)
    g.add("logits", OpKind.GEMM, [dec],
          fn=(lambda h: jnp.einsum("bsd,vd->bsv", h, et)) if with_fn else None,
          cost=gemm_cost(b * s, d, cfg.vocab_size))
    g.validate()
    return g


# -- RWKV6 --------------------------------------------------------------------

RWKV_LORA = 32  # data-dependent decay LoRA rank (matches ssm.init_rwkv_time_mix)


def _shift_mix(x, mu):
    """Token-shift interpolation with the zero prefill state
    (ssm._token_shift at x_prev = 0)."""
    xs = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    return x + (xs - x) * mu.astype(x.dtype)


def _rwkv_decay_payload(a, wb, w_base):
    """w_t = exp(-exp(base + lora_b(tanh(lora_a(x))))) — fp32 decay."""
    w_log = w_base + jnp.einsum("...i,io->...o",
                                jnp.tanh(a), wb).astype(jnp.float32)
    return jnp.exp(-jnp.exp(w_log))


def _wkv_scan_payload(r, k, v, w, u):
    h, hs = u.shape
    b, t, d = r.shape
    rh = r.reshape(b, t, h, hs).astype(jnp.float32)
    kh = k.reshape(b, t, h, hs).astype(jnp.float32)
    vh = v.reshape(b, t, h, hs).astype(jnp.float32)
    wh = w.reshape(b, t, h, hs)
    s0 = jnp.zeros((b, h, hs, hs), jnp.float32)
    _, y = wkv_scan_ref(rh, kh, vh, wh, u, s0)
    return y.reshape(b, t, d).astype(r.dtype)


@functools.lru_cache(maxsize=None)
def _make_rwkv_groupnorm(hs: int):
    """Per-head group-norm (ln_x) in fp32, exactly rwkv_time_mix_seq's."""
    def groupnorm(y, scale, bias):
        b, t, d = y.shape
        yf = y.astype(jnp.float32).reshape(b, t, d // hs, hs)
        mu = yf.mean(-1, keepdims=True)
        var = yf.var(-1, keepdims=True)
        yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
        return (yf.reshape(b, t, d) * scale.astype(jnp.float32)
                + bias.astype(jnp.float32)).astype(y.dtype)
    return groupnorm


def _silu_gate(y, go):
    return y * jax.nn.silu(go)


def _relu_sq(x):
    return jnp.square(jax.nn.relu(x))


def _rwkv_layer(g, cfg, x, b, s, tag, pl, root):
    """RWKV6: five parallel token-shift mixes feeding the r/k/v/g/decay
    projections, the WKV scan (fused — the recurrence is one memory-bound
    sweep, see docs/scheduling.md), group-norm, silu-gate, and the
    squared-relu channel mix."""
    d, dff = cfg.d_model, cfg.d_ff
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    with_fn = pl is not None
    tm = pl["time_mix"] if pl else None
    cm = pl["channel_mix"] if pl else None
    n1 = _norm_node(g, f"{tag}.norm1", x, pl and pl["norm1"], cfg.norm,
                    b * s * d)
    mixes = {}
    for i, nm in enumerate(("r", "k", "v", "g", "w")):
        mixes[nm] = g.add(f"{tag}.mix_{nm}", OpKind.ELEMENTWISE, [n1],
                          fn=_shift_mix if with_fn else None,
                          cost=elementwise_cost(b * s * d, n_in=1,
                                                flops_per_elem=3),
                          fuse_sig=("tshift", s, d),
                          **({"consts": (tm["mu"][i],)} if with_fn else {}))
    pr = {nm: _gemm_node(g, f"{tag}.w{nm}", mixes[nm], tm and tm["w" + nm],
                         b * s, d, d)
          for nm in ("r", "k", "v", "g")}
    la = _gemm_node(g, f"{tag}.w_lora", mixes["w"], tm and tm["w_lora_a"],
                    b * s, d, RWKV_LORA)
    wdec = g.add(f"{tag}.w_decay", OpKind.GEMM, [la],
                 fn=_rwkv_decay_payload if with_fn else None,
                 cost=gemm_cost(b * s, RWKV_LORA, d),
                 fuse_sig=("wdecay", s, d),
                 **({"consts": (tm["w_lora_b"]["w"], tm["w_base"])}
                    if with_fn else {}))
    scan = g.add(f"{tag}.wkv_scan", OpKind.SCAN,
                 [pr["r"], pr["k"], pr["v"], wdec],
                 fn=_wkv_scan_payload if with_fn else None,
                 cost=scan_cost(b, s, d, hs), fuse_sig=("wkv", s, d, hs),
                 **({"consts": (tm["u"],)} if with_fn else {}))
    gn = g.add(f"{tag}.ln_x", OpKind.NORM, [scan],
               fn=_make_rwkv_groupnorm(hs) if with_fn else None,
               cost=norm_cost(b * s * d), fuse_sig=("rwkvgn", s, d, hs),
               **({"consts": (tm["ln_x"]["scale"], tm["ln_x"]["bias"])}
                  if with_fn else {}))
    gated = g.add(f"{tag}.gate_mul", OpKind.ELEMENTWISE, [gn, pr["g"]],
                  fn=_silu_gate if with_fn else None,
                  cost=elementwise_cost(b * s * d, n_in=2, flops_per_elem=5))
    o = _gemm_node(g, f"{tag}.wo", gated, tm and tm["wo"], b * s, d, d)
    r1 = g.add(f"{tag}.res1", OpKind.ELEMENTWISE, [x, o],
               fn=(lambda a, c: a + c) if with_fn else None,
               cost=elementwise_cost(b * s * d, n_in=2))
    n2 = _norm_node(g, f"{tag}.norm2", r1, pl and pl["norm2"], cfg.norm,
                    b * s * d)
    cmix = g.add(f"{tag}.cm_mix", OpKind.ELEMENTWISE, [n2],
                 fn=_shift_mix if with_fn else None,
                 cost=elementwise_cost(b * s * d, n_in=1, flops_per_elem=3),
                 fuse_sig=("tshift", s, d),
                 **({"consts": (cm["mu"][0],)} if with_fn else {}))
    ck = _ffn_gemm(g, f"{tag}.cm_k", cmix, root, cm and cm["wk"],
                   b * s, d, dff)
    act = g.add(f"{tag}.cm_act", OpKind.ELEMENTWISE, [ck],
                fn=_relu_sq if with_fn else None,
                cost=elementwise_cost(b * s * dff, n_in=1, flops_per_elem=2))
    cv = _ffn_gemm(g, f"{tag}.cm_v", act, root, cm and cm["wv"],
                   b * s, dff, d)
    return g.add(f"{tag}.res2", OpKind.ELEMENTWISE, [r1, cv],
                 fn=(lambda a, c: a + c) if with_fn else None,
                 cost=elementwise_cost(b * s * d, n_in=2))
