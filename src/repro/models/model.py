"""Model facade: one object per architecture exposing

    init(rng)                 → params
    loss(params, batch, rng)  → (loss, metrics)          [train shapes]
    prefill(params, inputs)   → (last_logits, caches)    [prefill shapes]
    decode(params, ...)       → (logits, caches)         [decode shapes]
    input_specs(cell)         → ShapeDtypeStruct pytree for the dry-run
    decode_state_specs(cell)  → cache ShapeDtypeStructs (no allocation)

Every function is pure and jit/pjit-friendly; the launcher owns meshes,
shardings and optimizer state.
"""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeCell
from . import encdec as ed
from . import transformer as tf


class Model:
    def __init__(self, cfg: ModelConfig, use_kernels: bool = False):
        self.cfg = cfg
        self.use_kernels = use_kernels

    # -- params ---------------------------------------------------------------
    def init(self, rng) -> Any:
        if self.cfg.family == "encdec":
            return ed.init_encdec(rng, self.cfg)
        return tf.init_lm(rng, self.cfg)

    def init_shapes(self) -> Any:
        """ShapeDtypeStruct pytree of params — no allocation (dry-run)."""
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- steps ------------------------------------------------------------------
    def loss(self, params, batch: Mapping[str, Any], rng=None, remat: bool = False):
        if self.cfg.family == "encdec":
            return ed.encdec_loss(params, batch, self.cfg, rng, self.use_kernels, remat)
        return tf.lm_loss(params, batch, self.cfg, rng, self.use_kernels, remat)

    def prefill(self, params, inputs: Mapping[str, Any], cache_len: int | None = None):
        if self.cfg.family == "encdec":
            return ed.encdec_prefill(params, inputs["frames"], inputs["tokens"],
                                     self.cfg, cache_len or inputs["tokens"].shape[1],
                                     self.use_kernels)
        return tf.lm_prefill(params, inputs["tokens"], self.cfg, cache_len,
                             self.use_kernels, inputs.get("extra_embeds"))

    def decode(self, params, token, caches, pos):
        if self.cfg.family == "encdec":
            return ed.encdec_decode(params, token, caches, pos, self.cfg, self.use_kernels)
        return tf.lm_decode(params, token, caches, pos, self.cfg, self.use_kernels)

    # -- paged decode ------------------------------------------------------------
    def supports_paged(self) -> bool:
        """Paged KV applies to pure-attention decoder stacks only (recurrent
        state — ssm/hybrid — and encdec cross-attention stay dense)."""
        return self.cfg.family in ("dense", "moe", "vlm")

    def init_paged_caches(self, num_pages: int, page_size: int):
        return tf.init_paged_decode_caches(self.cfg, num_pages, page_size)

    def paged_decode(self, params, token, caches, block_tables, pos):
        return tf.lm_paged_decode(params, token, caches, block_tables, pos,
                                  self.cfg, self.use_kernels)

    # -- dry-run input specs -----------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of this cell."""
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        if cell.step == "train":
            if cfg.family == "encdec":
                fe = cfg.frontend
                return {
                    "frames": jax.ShapeDtypeStruct((b, fe.n_tokens, fe.feat_dim), cfg.dtype),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                    "labels": jax.ShapeDtypeStruct((b, s), i32),
                }
            out = {
                "tokens": jax.ShapeDtypeStruct((b, self._text_len(s)), i32),
                "labels": jax.ShapeDtypeStruct((b, self._text_len(s)), i32),
            }
            if cfg.family == "vlm":
                fe = cfg.frontend
                out["extra_embeds"] = jax.ShapeDtypeStruct(
                    (b, fe.n_tokens, fe.feat_dim), cfg.dtype)
            return out
        if cell.step == "prefill":
            if cfg.family == "encdec":
                fe = cfg.frontend
                return {
                    "frames": jax.ShapeDtypeStruct((b, fe.n_tokens, fe.feat_dim), cfg.dtype),
                    "tokens": jax.ShapeDtypeStruct((b, s), i32),
                }
            out = {"tokens": jax.ShapeDtypeStruct((b, self._text_len(s)), i32)}
            if cfg.family == "vlm":
                fe = cfg.frontend
                out["extra_embeds"] = jax.ShapeDtypeStruct(
                    (b, fe.n_tokens, fe.feat_dim), cfg.dtype)
            return out
        # decode: one new token against a seq_len-long cache
        return {
            "token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }

    def _text_len(self, s: int) -> int:
        """VLM text token count: total seq budget minus image patches."""
        if self.cfg.family == "vlm" and self.cfg.frontend is not None:
            return max(s - self.cfg.frontend.n_tokens, 16)
        return s

    def decode_state_specs(self, cell: ShapeCell) -> Any:
        """Cache ShapeDtypeStructs for decode cells (no allocation)."""
        cfg = self.cfg
        b = cell.global_batch
        length = cell.seq_len + cfg.meta_tokens

        def build():
            if cfg.family == "encdec":
                fe = cfg.frontend
                n_dec = cfg.n_dec_layers or cfg.n_layers
                kvh, hd = cfg.n_kv_heads, cfg.head_dim
                kv = lambda t: (jnp.zeros((n_dec, b, t, kvh, hd), cfg.dtype),
                                jnp.zeros((n_dec, b, t, kvh, hd), cfg.dtype))
                return (kv(length), kv(fe.n_tokens))
            return tf.init_decode_caches(cfg, b, length)

        return jax.eval_shape(build)


def make_model(cfg: ModelConfig, use_kernels: bool = False) -> Model:
    return Model(cfg, use_kernels)
