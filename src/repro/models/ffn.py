"""Feed-forward: dense SwiGLU / GELU MLP and Mixture-of-Experts.

MoE uses static-capacity dense dispatch (TPU-friendly: one-hot einsum
scatter/gather, no data-dependent shapes) with:
  * softmax top-k routing + optional DeepSeek-V3 aux-loss-free bias balancing,
  * shared (always-on) experts,
  * expert sharding over the ``expert`` logical axis (EP),
  * optional Pallas grouped-GEMM kernel for the expert compute.

The per-expert FFN branches are exactly the "parallelizable operators" Opara
schedules; the capacity-dense formulation IS the wave-fused execution of all
expert lanes in one grouped kernel (DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..utils import shard
from .layers import gelu, init_linear, linear


# -- dense MLP ----------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, act: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": init_linear(ks[0], d, d_ff, False, dtype),
            "up": init_linear(ks[1], d, d_ff, False, dtype),
            "down": init_linear(ks[2], d_ff, d, False, dtype),
        }
    return {
        "up": init_linear(ks[0], d, d_ff, False, dtype),
        "down": init_linear(ks[1], d_ff, d, False, dtype),
    }


def mlp(p, x, act: str = "swiglu"):
    if "gate" in p:
        h = jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x)
    else:
        h = gelu(linear(p["up"], x))
    if h.ndim == 3:
        h = shard(h, "batch", "seq", "mlp")
    else:  # flattened tokens (MoE shared-expert path)
        h = shard(h, "batch", "mlp")
    return linear(p["down"], h)


# -- MoE ----------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    e = cfg.moe
    assert e is not None
    d, dtype = cfg.d_model, cfg.dtype
    ks = jax.random.split(key, 5)
    p = {
        "router": {
            "w": (jax.random.normal(ks[0], (d, e.n_experts), jnp.float32) * d ** -0.5),
            "bias": jnp.zeros((e.n_experts,), jnp.float32),  # aux-free balancing
        },
        # stacked expert weights [E, ...] — sharded over the expert axis
        "experts": {
            "gate": (jax.random.normal(ks[1], (e.n_experts, d, e.d_expert), jnp.float32)
                     * d ** -0.5).astype(dtype),
            "up": (jax.random.normal(ks[2], (e.n_experts, d, e.d_expert), jnp.float32)
                   * d ** -0.5).astype(dtype),
            "down": (jax.random.normal(ks[3], (e.n_experts, e.d_expert, d), jnp.float32)
                     * e.d_expert ** -0.5).astype(dtype),
        },
    }
    if e.n_shared:
        p["shared"] = init_mlp(ks[4], d, e.d_expert * e.n_shared, "swiglu", dtype)
    return p


def route(p_router, x, e, rng=None):
    """Top-k routing. Returns (weights [N,k], experts [N,k], aux metrics).

    DeepSeek-V3 aux-loss-free: selection uses logits + per-expert bias; the
    combine weights use the un-biased scores.  The bias is updated outside
    the step (optimizer hook) toward load balance.
    """
    n = x.shape[0]
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), p_router["w"])
    scores = jax.nn.sigmoid(logits) if e.router_aux_free else jax.nn.softmax(logits, -1)
    select = scores + p_router["bias"][None, :] if e.router_aux_free else scores
    if rng is not None and e.router_noise > 0:
        select = select + jax.random.normal(rng, select.shape) * e.router_noise
    _, top_idx = jax.lax.top_k(select, e.top_k)                  # [N,k]
    top_w = jnp.take_along_axis(scores, top_idx, axis=-1)        # [N,k]
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)
    # load-balance metrics (Switch-style): fraction per expert
    counts = jnp.zeros((e.n_experts,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    load = counts / jnp.maximum(counts.sum(), 1.0)
    importance = scores.mean(0)
    aux = {"load": load, "aux_loss": e.n_experts * jnp.sum(load * importance)}
    return top_w, top_idx, aux


def _capacity(n: int, e) -> int:
    return min(int(max(1, round(n * e.top_k / e.n_experts * e.capacity_factor))), n)


def _expert_mlp(p_experts, buf, use_kernels: bool):
    """Grouped expert GEMM: buf [E,C,d] → [E,C,d].  ONE fused kernel over the
    expert axis — the horizontally-fused Opara wave (DESIGN.md §2)."""
    if use_kernels:
        from ..kernels.moe_gemm.ops import moe_mlp_tpu_or_ref
        return moe_mlp_tpu_or_ref(buf, p_experts)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p_experts["gate"],
                               preferred_element_type=jnp.float32).astype(buf.dtype))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p_experts["up"],
                       preferred_element_type=jnp.float32).astype(buf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p_experts["down"],
                      preferred_element_type=jnp.float32).astype(buf.dtype)


def moe_ffn_dense(p, x, cfg: ModelConfig, rng=None, use_kernels: bool = False):
    """One-hot capacity-dense dispatch (GShard-style einsum).  O(N·E·C)
    dispatch tensors — only viable for small expert counts; used by smoke
    configs and as the semantics oracle for the sort-based path.
    """
    e = cfg.moe
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    top_w, top_idx, aux = route(p["router"], xf, e, rng)

    cap = _capacity(n, e)
    onehot = jax.nn.one_hot(top_idx, e.n_experts, dtype=jnp.int32)   # [N,k,E]
    flatoh = onehot.reshape(n * e.top_k, e.n_experts)
    pos_in_e = (jnp.cumsum(flatoh, axis=0) - flatoh).reshape(n, e.top_k, e.n_experts)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                        # [N,k]
    keep = pos < cap
    w = top_w * keep

    disp = (onehot * keep[..., None]).astype(xf.dtype)               # [N,k,E]
    poh = jax.nn.one_hot(pos, cap, dtype=xf.dtype)                   # [N,k,C]
    comb = jnp.einsum("nke,nkc->nec", disp, poh)                     # [N,E,C]
    buf = jnp.einsum("nec,nd->ecd", comb, xf)                        # [E,C,d]
    buf = shard(buf, "expert", None, None)
    out_buf = shard(_expert_mlp(p["experts"], buf, use_kernels), "expert", None, None)
    comb_w = jnp.einsum("nke,nkc,nk->nec", disp, poh, w.astype(xf.dtype))
    y = jnp.einsum("nec,ecd->nd", comb_w, out_buf)

    if e.n_shared:
        y = y + mlp(p["shared"], xf, "swiglu")
    return y.reshape(b, s, d), aux


def moe_ffn_sort(p, x, cfg: ModelConfig, rng=None, use_kernels: bool = False):
    """Sort-based capacity dispatch (production path, large E).

    No [N,E,·] one-hot tensors: (token,k) pairs are argsorted by expert id,
    ranked within their expert group, and scatter-added into the [E,C,d]
    buffer (overflow rows drop to a dummy slot).  Combine is the transpose
    gather.  Memory: O(N·k·d) expanded activations — the true MoE dispatch
    cost — sharded over data (tokens) and expert (buffers) axes so GSPMD
    lowers the exchange to an all-to-all.
    """
    e = cfg.moe
    b, s, d = x.shape
    n = b * s
    xf = x.reshape(n, d)
    top_w, top_idx, aux = route(p["router"], xf, e, rng)

    cap = _capacity(n, e)
    nk = n * e.top_k
    expert_flat = top_idx.reshape(nk)                                # [NK]
    tok_flat = jnp.repeat(jnp.arange(n, dtype=jnp.int32), e.top_k)   # [NK]
    w_flat = top_w.reshape(nk)

    # rank within expert group via stable argsort (no [NK,E] one-hot)
    order = jnp.argsort(expert_flat, stable=True)                    # [NK]
    sorted_e = expert_flat[order]
    counts = jnp.zeros((e.n_experts,), jnp.int32).at[expert_flat].add(1)
    starts = jnp.cumsum(counts) - counts                             # [E]
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted)      # [NK]

    keep = pos < cap
    slot = jnp.where(keep, expert_flat * cap + pos, e.n_experts * cap)
    gathered = xf[tok_flat] * keep[:, None].astype(xf.dtype)         # [NK,d]
    buf = jnp.zeros((e.n_experts * cap + 1, d), xf.dtype).at[slot].add(gathered)
    buf = buf[: e.n_experts * cap].reshape(e.n_experts, cap, d)
    buf = shard(buf, "expert", None, None)

    out_buf = shard(_expert_mlp(p["experts"], buf, use_kernels), "expert", None, None)

    out_rows = out_buf.reshape(e.n_experts * cap, d)[jnp.minimum(slot, e.n_experts * cap - 1)]
    out_rows = out_rows * (w_flat * keep)[:, None].astype(xf.dtype)  # [NK,d]
    y = out_rows.reshape(n, e.top_k, d).sum(axis=1)

    if e.n_shared:
        y = y + mlp(p["shared"], xf, "swiglu")
    return y.reshape(b, s, d), aux


def moe_ffn(p, x, cfg: ModelConfig, rng=None, use_kernels: bool = False):
    e = cfg.moe
    if e.n_experts > 32:
        return moe_ffn_sort(p, x, cfg, rng, use_kernels)
    return moe_ffn_dense(p, x, cfg, rng, use_kernels)


def init_ffn(key, cfg: ModelConfig):
    if cfg.moe is not None:
        return init_moe(key, cfg)
    return init_mlp(key, cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype)


def ffn(p, x, cfg: ModelConfig, rng=None, use_kernels=False):
    if cfg.moe is not None:
        return moe_ffn(p, x, cfg, rng, use_kernels)
    return mlp(p, x, cfg.act), {}
