"""Loss helpers that stay sharded.

``take_along_axis`` on a vocab-sharded logits tensor makes GSPMD all-gather
the full [B,S,V] fp32 logits (tens of GB at production scale).  The one-hot
contraction below keeps every operand sharded over the vocab axis; the only
cross-shard traffic is the scalar-per-token reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy.  logits: [B,S,V] fp32 (may be vocab-sharded);
    labels: [B,S] int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return (lse - gold).mean()


def chunked_softmax_xent(x: jax.Array, head_table: jax.Array,
                         labels: jax.Array, s_chunk: int = 512) -> jax.Array:
    """§Perf O3: cross-entropy without ever materializing the full [B,S,V]
    fp32 logits — the head matmul + lse + gold fuse inside a scan over
    sequence chunks (the MaxText-style memory-term optimization).

    x: [B,S,d] final hidden states; head_table: [V,d]; labels: [B,S].
    """
    b, s, d = x.shape
    sc = min(s_chunk, s)
    while s % sc:
        sc //= 2
    n = s // sc
    xs = jnp.moveaxis(x.reshape(b, n, sc, d), 1, 0)          # [n,B,sc,d]
    ls = jnp.moveaxis(labels.reshape(b, n, sc), 1, 0)        # [n,B,sc]

    def chunk(total, inp):
        xc, lc = inp
        logits = jnp.einsum("bsd,vd->bsv", xc, head_table,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(lc, logits.shape[-1], dtype=logits.dtype)
        gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
        return total + (lse - gold).sum(), None

    total, _ = jax.lax.scan(chunk, jnp.float32(0.0), (xs, ls))
    return total / (b * s)
