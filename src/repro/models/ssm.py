"""State-space / linear-recurrence blocks: RWKV6 (Finch) and a Mamba-style
selective SSM head (used by Hymba's parallel attn∥SSM layers).

Both expose a *sequence* form (training / prefill: process T tokens, return
final state) and a *step* form (decode: one token, carry state).  The
sequence form's inner recurrence is the memory-bound hot loop — the Pallas
``rwkv6`` kernel implements the chunked WKV recurrence; the pure-jnp path
here is the oracle.

RWKV6 time-mix (per head, head_size K):
    wkv_t = diag(u)·(k_tᵀ v_t) + S_{t-1}
    S_t   = diag(w_t)·S_{t-1} + k_tᵀ v_t          (w_t data-dependent decay)
    out_t = r_t · wkv_t
Mamba selective scan (state N):
    h_t = exp(Δ_t A)·h_{t-1} + Δ_t B_t x_t ;  y_t = C_t h_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..utils import shard
from .layers import init_linear, linear


# =============================== RWKV6 =======================================

def init_rwkv_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    n_heads = d // hs
    ks = jax.random.split(key, 10)
    dt = cfg.dtype
    lora = 32  # data-dependent decay LoRA rank (Finch §3)
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(dt),  # token-shift mixes (r,k,v,g,w)
        "wr": init_linear(ks[1], d, d, False, dt),
        "wk": init_linear(ks[2], d, d, False, dt),
        "wv": init_linear(ks[3], d, d, False, dt),
        "wg": init_linear(ks[4], d, d, False, dt),
        "wo": init_linear(ks[5], d, d, False, dt),
        # decay: w_t = exp(-exp(base + lora(x)))
        "w_base": jnp.zeros((d,), jnp.float32) - 6.0,
        "w_lora_a": init_linear(ks[6], d, lora, False, dt),
        "w_lora_b": init_linear(ks[7], lora, d, False, dt),
        "u": (jax.random.normal(ks[8], (n_heads, hs), jnp.float32) * 0.1).astype(jnp.float32),
        "ln_x": {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)},
    }


def _token_shift(x, x_prev):
    """x: [B,T,d]; returns x shifted right by one, first slot = x_prev [B,d]."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _rwkv_proj(p, x, x_prev):
    """The 5 parallel token-shift projections (r,k,v,g,w) — the branchy
    sub-DAG Opara fuses into one wave (DESIGN.md §5)."""
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    mix = [x + (xs - x) * mu[i] for i in range(5)]
    r = linear(p["wr"], mix[0])
    k = linear(p["wk"], mix[1])
    v = linear(p["wv"], mix[2])
    g = jax.nn.silu(linear(p["wg"], mix[3]))
    w_log = p["w_base"] + linear(p["w_lora_b"],
                                 jnp.tanh(linear(p["w_lora_a"], mix[4]))).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))                        # decay in (0,1)
    return r, k, v, g, w


def wkv_scan_ref(rh, kh, vh, wh, u, s0):
    """The pure-jnp WKV recurrence on head-split fp32 tensors [B,T,H,K].

    Shared by :func:`rwkv_time_mix_seq` and the opgraph exporter's scan
    payload (``models/opgraph_export``), so the exported graph executes the
    exact production math.  Returns (s_final [B,H,K,K], y [B,T,H,K])."""
    def step(S, rkvw):
        rt, kt, vt, wt = rkvw                        # [B,H,K] each
        kv = kt[..., :, None] * vt[..., None, :]     # [B,H,K,K]
        out = jnp.einsum("bhk,bhkj->bhj", rt, u[None, :, :, None] * kv + S)
        S = wt[..., :, None] * S + kv
        return S, out
    xs_t = (jnp.moveaxis(rh, 1, 0), jnp.moveaxis(kh, 1, 0),
            jnp.moveaxis(vh, 1, 0), jnp.moveaxis(wh, 1, 0))
    s_final, outs = jax.lax.scan(step, s0, xs_t)
    return s_final, jnp.moveaxis(outs, 0, 1)         # [B,T,H,K]


def rwkv_time_mix_seq(p, x, state, cfg: ModelConfig, use_kernels: bool = False):
    """x: [B,T,d]; state: (x_prev [B,d], S [B,H,K,K] fp32).  Returns (y, state')."""
    b, t, d = x.shape
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    h = d // hs
    x_prev, s0 = state
    r, k, v, g, w = _rwkv_proj(p, x, x_prev)
    rh = r.reshape(b, t, h, hs).astype(jnp.float32)
    kh = k.reshape(b, t, h, hs).astype(jnp.float32)
    vh = v.reshape(b, t, h, hs).astype(jnp.float32)
    wh = w.reshape(b, t, h, hs)
    u = p["u"]

    if use_kernels:
        from ..kernels.rwkv6.ops import rwkv6_tpu_or_ref
        y, s_final = rwkv6_tpu_or_ref(rh, kh, vh, wh, u, s0)
    else:
        s_final, y = wkv_scan_ref(rh, kh, vh, wh, u, s0)

    y = y.reshape(b, t, d).astype(x.dtype)
    # group-norm over heads (ln_x in RWKV), then gate and output proj
    yf = y.astype(jnp.float32).reshape(b, t, h, hs)
    mu_ = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu_) * jax.lax.rsqrt(var + 1e-5)
    y = (yf.reshape(b, t, d) * p["ln_x"]["scale"].astype(jnp.float32)
         + p["ln_x"]["bias"].astype(jnp.float32)).astype(x.dtype)
    y = linear(p["wo"], y * g)
    return shard(y, "batch", "seq", "embed"), (x[:, -1], s_final)


def rwkv_time_mix_step(p, x, state, cfg: ModelConfig):
    """Decode: x [B,1,d]."""
    y, st = rwkv_time_mix_seq(p, x, state, cfg, use_kernels=False)
    return y, st


def init_rwkv_channel_mix(key, cfg: ModelConfig):
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.dtype
    return {
        "mu": (jax.random.uniform(ks[0], (2, d), jnp.float32)).astype(dt),
        "wk": init_linear(ks[1], d, dff, False, dt),
        "wv": init_linear(ks[2], dff, d, False, dt),
    }


def rwkv_channel_mix(p, x, x_prev, cfg: ModelConfig):
    xs = _token_shift(x, x_prev)
    mu = p["mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    k = jnp.square(jax.nn.relu(linear(p["wk"], xk)))
    return linear(p["wv"], k), x[:, -1]


def rwkv_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    hs = cfg.ssm.head_dim if cfg.ssm else 64
    h = d // hs
    return {
        "tm_x": jnp.zeros((batch, d), cfg.dtype),
        "tm_s": jnp.zeros((batch, h, hs, hs), jnp.float32),
        "cm_x": jnp.zeros((batch, d), cfg.dtype),
    }


# =============================== Mamba head ==================================

def init_mamba(key, cfg: ModelConfig):
    """Selective SSM head for Hymba (runs in parallel with attention)."""
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    ks = jax.random.split(key, 6)
    dt = cfg.dtype
    return {
        "in_proj": init_linear(ks[0], d, 2 * di, False, dt),     # x, z
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, di), jnp.float32) * 0.2).astype(dt),
        "x_proj": init_linear(ks[2], di, s.state_dim * 2 + 1, False, dt),  # B, C, dt
        "a_log": jnp.log(jnp.tile(jnp.arange(1, s.state_dim + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": init_linear(ks[3], di, d, False, dt),
    }


def _mamba_conv_seq(w, x, conv_state):
    """Causal depthwise conv over time. x: [B,T,di]; conv_state: [B,K-1,di]."""
    k = w.shape[0]
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)    # [B,T+K-1,di]
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out), xp[:, -(k - 1):]


def mamba_scan_ref(delta, xi_f32, bmat, cmat, a, h0):
    """The discretized selective scan on fp32 tensors.

    delta [B,T,1], xi [B,T,di], B/C [B,T,N], a [di,N], h0 [B,di,N].
    Shared by :func:`mamba_seq` and the opgraph exporter's scan payload.
    Returns (h_final, y [B,T,di])."""
    # discretize inside the scan body (never materialize [B,T,di,N]):
    # h_t = exp(delta_t·a) h_{t-1} + (delta_t·x_t)⊗B_t ;  y_t = C_t·h_t
    def step(h, inp):
        delta_t, x_t, b_t, c_t = inp                    # [B,1],[B,di],[B,N],[B,N]
        da_t = jnp.exp(delta_t[..., None] * a[None])    # [B,di,N]
        h = da_t * h + (delta_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(delta, 1, 0), jnp.moveaxis(xi_f32, 1, 0),
          jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return h_final, jnp.moveaxis(ys, 0, 1)


def mamba_seq(p, x, state, cfg: ModelConfig, use_kernels: bool = False):
    """x: [B,T,d]; state: (conv_state [B,K-1,di], h [B,di,N] fp32)."""
    s = cfg.ssm
    b, t, d = x.shape
    di = s.expand * d
    conv_state, h0 = state
    xz = linear(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _mamba_conv_seq(p["conv_w"], xi, conv_state)
    bcd = linear(p["x_proj"], xi)
    bmat, cmat, dt_raw = jnp.split(bcd, [s.state_dim, 2 * s.state_dim], axis=-1)
    delta = jax.nn.softplus(dt_raw.astype(jnp.float32)) + 1e-4         # [B,T,1]
    a = -jnp.exp(p["a_log"])                                           # [di,N]
    h_final, ys = mamba_scan_ref(delta, xi.astype(jnp.float32),
                                 bmat.astype(jnp.float32),
                                 cmat.astype(jnp.float32), a, h0)
    y = ys + xi.astype(jnp.float32) * p["d_skip"][None, None]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = linear(p["out_proj"], y)
    return shard(out, "batch", "seq", "embed"), (conv_state, h_final)


def mamba_state_init(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return (jnp.zeros((batch, s.conv_dim - 1, di), cfg.dtype),
            jnp.zeros((batch, di, s.state_dim), jnp.float32))
