"""Model substrate: layers, attention, FFN/MoE, SSM, assemblies, facade."""
from .model import Model, make_model

__all__ = ["Model", "make_model"]
