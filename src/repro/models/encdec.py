"""Encoder-decoder (Whisper-style) assembly.

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, T_frames, feat_dim]; a linear
projector maps them to d_model (standing in for the 2×conv1d stem).

Encoder: bidirectional self-attention, LayerNorm, GELU FFN (Whisper uses
pre-LN transformer).  Decoder: causal self-attn + cross-attn over encoder
output.  Cross-attention K/V are precomputed once at prefill — a parallel
operator branch Opara overlaps with decoder self-attention projections.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..utils import shard
from .attention import (
    attn_decode,
    gqa_prefill,
    init_cache,
    init_gqa,
    _sdpa,
)
from .ffn import init_mlp, mlp
from .layers import apply_norm, embed, init_embedding, init_linear, init_norm, linear, unembed


def init_encoder_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "norm1": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "attn": init_gqa(ks[0], cfg),
        "norm2": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "ffn": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype),
    }


def encoder_block(p, x, cfg: ModelConfig):
    """Bidirectional self-attention block."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    b, s, _ = x.shape
    hd, nh, kvh = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = linear(p["attn"]["wq"], h).reshape(b, s, nh, hd)
    k = linear(p["attn"]["wk"], h).reshape(b, s, kvh, hd)
    v = linear(p["attn"]["wv"], h).reshape(b, s, kvh, hd)
    out = _sdpa(q, k, v, None)
    x = x + linear(p["attn"]["wo"], out.reshape(b, s, nh * hd))
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    return x + mlp(p["ffn"], h2, cfg.act)


def init_decoder_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "self_attn": init_gqa(ks[0], cfg),
        "norm_x": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "cross_attn": init_gqa(ks[1], cfg),
        "norm2": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "ffn": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype),
    }


def _cross_kv(p_cross, enc_out, cfg: ModelConfig):
    b, t, _ = enc_out.shape
    kvh, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear(p_cross["wk"], enc_out).reshape(b, t, kvh, hd)
    v = linear(p_cross["wv"], enc_out).reshape(b, t, kvh, hd)
    return k, v


def _cross_attend(p_cross, x, ckv, cfg: ModelConfig):
    b, s, _ = x.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    q = linear(p_cross["wq"], x).reshape(b, s, nh, hd)
    out = _sdpa(q, ckv[0], ckv[1], None)
    return linear(p_cross["wo"], out.reshape(b, s, nh * hd))


def decoder_block_seq(p, x, enc_out, cfg: ModelConfig, positions, use_kernels=False):
    """Returns (x', (self_kv, cross_kv))."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    attn_out, self_kv = gqa_prefill(p["self_attn"], h, cfg, positions, None, use_kernels)
    x = x + attn_out
    hx = apply_norm(p["norm_x"], x, cfg.norm)
    ckv = _cross_kv(p["cross_attn"], enc_out, cfg)
    x = x + _cross_attend(p["cross_attn"], hx, ckv, cfg)
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    return x + mlp(p["ffn"], h2, cfg.act), (self_kv, ckv)


def decoder_block_step(p, x, cache, pos, cfg: ModelConfig, use_kernels=False):
    self_kv, ckv = cache
    h = apply_norm(p["norm1"], x, cfg.norm)
    attn_out, self_kv = attn_decode(p["self_attn"], h, self_kv, pos, cfg, None, use_kernels)
    x = x + attn_out
    hx = apply_norm(p["norm_x"], x, cfg.norm)
    x = x + _cross_attend(p["cross_attn"], hx, ckv, cfg)
    h2 = apply_norm(p["norm2"], x, cfg.norm)
    return x + mlp(p["ffn"], h2, cfg.act), (self_kv, ckv)


# ============================ full model ====================================

def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    fe = cfg.frontend
    n_dec = cfg.n_dec_layers or cfg.n_layers
    return {
        "frontend_proj": init_linear(ks[0], fe.feat_dim, cfg.d_model, True, cfg.dtype),
        "enc_pos": (jax.random.normal(ks[1], (fe.n_tokens, cfg.d_model), jnp.float32)
                    * 0.01).astype(cfg.dtype),
        "enc_blocks": jax.vmap(lambda k: init_encoder_block(k, cfg))(
            jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "embed": init_embedding(ks[3], cfg.vocab_size, cfg.d_model, cfg.dtype),
        "dec_pos": (jax.random.normal(ks[4], (cfg.max_seq_len, cfg.d_model), jnp.float32)
                    * 0.01).astype(cfg.dtype),
        "dec_blocks": jax.vmap(lambda k: init_decoder_block(k, cfg))(
            jax.random.split(ks[5], n_dec)),
        "dec_norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
    }


def encode(params, frames, cfg: ModelConfig, remat: bool = False):
    """frames: [B, T_frames, feat_dim] (precomputed stub embeddings)."""
    x = linear(params["frontend_proj"], frames)
    x = x + params["enc_pos"][None, : x.shape[1]]
    x = shard(x, "batch", "seq", "embed")

    def body(x, p_l):
        return encoder_block(p_l, x, cfg), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return apply_norm(params["enc_norm"], x, cfg.norm)


def decode_seq(params, tokens, enc_out, cfg: ModelConfig, remat: bool = False,
               use_kernels: bool = False):
    """Teacher-forced decoder pass → (logits, caches)."""
    b, s = tokens.shape
    x = embed(params["embed"], tokens) + params["dec_pos"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, p_l):
        x, cache = decoder_block_seq(p_l, x, enc_out, cfg, positions, use_kernels)
        return x, cache

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, params["dec_blocks"])
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return unembed(params["embed"], x), caches


def encdec_loss(params, batch, cfg: ModelConfig, rng=None, use_kernels=False,
                remat=False):
    enc_out = encode(params, batch["frames"], cfg, remat)
    logits, _ = decode_seq(params, batch["tokens"], enc_out, cfg, remat, use_kernels)
    from ..utils import shard as _shard
    from .losses import softmax_xent
    logits = _shard(logits, "batch", "seq", "vocab")
    ce = softmax_xent(logits, batch["labels"])
    return ce, {"ce": ce}


def encdec_prefill(params, frames, tokens, cfg: ModelConfig, cache_len: int,
                   use_kernels=False):
    enc_out = encode(params, frames, cfg)
    logits, caches = decode_seq(params, tokens, enc_out, cfg, False, use_kernels)

    def pad_self(kv):
        k, v = kv
        padw = [(0, 0), (0, 0), (0, cache_len - k.shape[2]), (0, 0), (0, 0)]
        return jnp.pad(k, padw), jnp.pad(v, padw)

    self_kv, ckv = caches
    return logits[:, -1], (pad_self(self_kv), ckv)


def encdec_decode(params, token, caches, pos, cfg: ModelConfig, use_kernels=False):
    x = embed(params["embed"], token[:, None])
    x = x + params["dec_pos"][pos[0]][None, None]

    def body(x, xs):
        p_l, self_kv_l, ckv_l = xs
        x, cache = decoder_block_step(p_l, x, (self_kv_l, ckv_l), pos, cfg, use_kernels)
        return x, cache

    self_kv, ckv = caches
    x, new_caches = jax.lax.scan(body, x, (params["dec_blocks"], self_kv, ckv))
    x = apply_norm(params["dec_norm"], x, cfg.norm)
    return unembed(params["embed"], x)[:, 0], new_caches
