"""Primitive layers: norms, linear, embedding, RoPE.

Pure-functional: ``init_*`` returns a param pytree (dict), ``apply`` style
functions take (params, x).  All matmuls accumulate in fp32
(``preferred_element_type``) and keep activations in ``cfg.dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..utils import shard


def init_linear(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.bfloat16,
                scale: float | None = None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def init_norm(d: int, kind: str = "rmsnorm", dtype=jnp.bfloat16):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(p, x, kind: str = "rmsnorm"):
    return layernorm(p, x) if kind == "layernorm" else rmsnorm(p, x)


def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p, ids):
    y = jnp.take(p["table"], ids, axis=0)
    return shard(y, "batch", "seq", "embed")


def unembed(p, x):
    """Logits head (optionally tied): [..., d] -> [..., vocab] in fp32."""
    return jnp.einsum("...d,vd->...v", x, p["table"],
                      preferred_element_type=jnp.float32)


# -- RoPE ---------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., seq, heads, d_head]; positions: [..., seq] (int)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, d/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., seq, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)
