"""Perf-iteration feature flags (EXPERIMENTS.md §Perf).

Every beyond-paper optimization is gated OFF by default so the paper-faithful
baseline stays the baseline; the hillclimb harness flips them via env vars
(read at trace time):

    REPRO_CACHE_UPDATE   where | scatter      decode KV-cache write policy
    REPRO_CHUNKED_CE     0 | 1                seq-chunked cross-entropy
    REPRO_CAUSAL_SKIP    0 | 1                skip fully-masked KV chunks
"""
from __future__ import annotations

import os


def cache_update_mode() -> str:
    return os.environ.get("REPRO_CACHE_UPDATE", "where")


def chunked_ce() -> bool:
    return os.environ.get("REPRO_CHUNKED_CE", "0") == "1"


def causal_skip() -> bool:
    return os.environ.get("REPRO_CAUSAL_SKIP", "0") == "1"


def window_slice_decode() -> bool:
    """O6: window-attention decode reads a dynamic slice of the KV cache
    (window+1 slots) instead of the full sequence (masked)."""
    return os.environ.get("REPRO_WINDOW_SLICE_DECODE", "0") == "1"


def kv_quant() -> bool:
    """O8: int8 MLA latent cache (per-token scales) — halves cache storage
    and read traffic; KIVI/KVQuant-style, applied to the compressed latent
    where quantization error is smallest."""
    return os.environ.get("REPRO_KV_QUANT", "0") == "1"
