"""Logical-axis sharding constraints (flax-style, dependency-free).

Models annotate activations with *logical* axis names:

    x = shard(x, "batch", "seq", "embed")

Inside a ``logical_axis_rules({...})`` context (entered by the launcher with
the active mesh), each logical name maps to a mesh axis (or None) and the
annotation becomes ``jax.lax.with_sharding_constraint``.  Outside any
context (unit tests, CPU smoke runs) the call is the identity, so model code
is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Mapping, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def current_rules() -> dict[str, str | None] | None:
    return getattr(_state, "rules", None)


def _current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def logical_axis_rules(rules: Mapping[str, str | Sequence[str] | None], mesh=None):
    prev_r = getattr(_state, "rules", None)
    prev_m = getattr(_state, "mesh", None)
    _state.rules = dict(rules)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules = prev_r
        _state.mesh = prev_m


def logical_to_spec(axes: Sequence[str | None], rules: Mapping[str, object]) -> P:
    spec = []
    used: set[str] = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        if isinstance(m, (list, tuple)):
            m = tuple(x for x in m if x not in used)
            used.update(m)
            spec.append(m if m else None)
        else:
            if m in used:
                m = None
            if m is not None:
                used.add(m)
            spec.append(m)
    return P(*spec)


def shard(x: jax.Array, *axes: str | None):
    """Annotate ``x`` with logical axes; no-op without active rules.

    Axes whose dimension does not divide the target mesh-axis size are
    dropped (partial GSPMD shardings trigger involuntary remat copies).
    """
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs {len(axes)} logical axes")
    spec = logical_to_spec(axes, rules)
    mesh = _current_mesh()
    if mesh is not None:
        sizes = dict(mesh.shape)
        cleaned = []
        for dim, entry in zip(x.shape, spec):
            ax = (entry,) if isinstance(entry, str) else entry
            if ax is None:
                cleaned.append(None)
                continue
            total = 1
            for a in ax:
                total *= sizes.get(a, 1)
            cleaned.append(entry if dim % total == 0 else None)
        spec = P(*cleaned)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
