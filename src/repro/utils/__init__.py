from .sharding_ctx import logical_axis_rules, shard, current_rules
from .tree import tree_size_bytes, tree_param_count

__all__ = ["logical_axis_rules", "shard", "current_rules",
           "tree_size_bytes", "tree_param_count"]
