"""Pytree helpers."""
from __future__ import annotations

import jax
import numpy as np


def tree_param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_size_bytes(tree) -> int:
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * x.dtype.itemsize
    return total


def tree_paths(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(k) for k, _ in flat]
