"""Whisper-medium. [arXiv:2212.04356; unverified]

Assigned: 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865 — enc-dec,
conv frontend STUB (input_specs provides precomputed frame embeddings:
1500 frames × 80-mel→conv stub feature dim).
"""
from .base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,           # encoder depth
    n_dec_layers=24,       # decoder depth
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm="layernorm",
    act="gelu",
    rope=False,            # learned positional embeddings
    tie_embeddings=True,
    frontend=FrontendConfig(kind="audio", n_tokens=1500, feat_dim=1024),
    max_seq_len=32768,     # assigned decode shapes exceed the 448 original
    source="arXiv:2212.04356; unverified",
)

SMOKE = ModelConfig(
    name="whisper-medium-smoke",
    family="encdec",
    n_layers=2,
    n_dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    norm="layernorm",
    act="gelu",
    rope=False,
    tie_embeddings=True,
    frontend=FrontendConfig(kind="audio", n_tokens=16, feat_dim=24),
    max_seq_len=64,
    source="smoke",
)
