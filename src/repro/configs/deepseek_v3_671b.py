"""DeepSeek-V3 671B. [arXiv:2412.19437; hf]

Assigned: 61L d_model=7168 128H (GQA kv=128) d_ff=2048 vocab=129280,
MoE 256e top-8 — MLA, 1 shared + 256 routed top-8, MTP.
First 3 layers dense (HF config first_k_dense_replace=3).
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,            # dense-prefix layers
    vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  capacity_factor=1.25, router_aux_free=True),
    mtp_heads=1,
    rope_theta=1e4,
    max_seq_len=131072,
    source="arXiv:2412.19437; hf",
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  capacity_factor=1.5, router_aux_free=True),
    mtp_heads=1,
    max_seq_len=128,
    source="smoke",
)
