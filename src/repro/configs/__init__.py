"""Architecture registry: one module per assigned architecture.

    from repro.configs import get_config, list_archs
    cfg = get_config("qwen2-0.5b")           # full production config
    cfg = get_config("qwen2-0.5b", smoke=True)
"""
from __future__ import annotations

import importlib

from .base import (
    FrontendConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    SHAPES,
    ShapeCell,
    SSMConfig,
    cell_applicable,
)

_ARCH_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-medium": "whisper_medium",
    "glm4-9b": "glm4_9b",
    "llama3.2-1b": "llama3_2_1b",
    "minicpm-2b": "minicpm_2b",
    "qwen2-0.5b": "qwen2_0_5b",
    "hymba-1.5b": "hymba_1_5b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_ARCH_MODULES)}")
    mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = [
    "FrontendConfig", "MLAConfig", "ModelConfig", "MoEConfig", "ParallelConfig",
    "SHAPES", "ShapeCell", "SSMConfig", "cell_applicable",
    "get_config", "list_archs",
]
