"""Qwen2-0.5B. [arXiv:2407.10671; hf]

Assigned: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936 — GQA,
QKV bias.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    max_seq_len=131072,
    source="arXiv:2407.10671; hf",
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke",
    family="dense",
    n_layers=2,
    d_model=56,            # 4 heads × 14
    n_heads=4,
    n_kv_heads=2,
    d_head=14,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    tie_embeddings=True,
    max_seq_len=128,
    source="smoke",
)
