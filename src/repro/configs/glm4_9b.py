"""GLM-4 9B. [hf:THUDM/glm-4-9b; hf]

Assigned: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 — RoPE, GQA.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,         # GLM-4 uses attention QKV bias
    rope_theta=1e4,
    max_seq_len=131072,
    source="hf:THUDM/glm-4-9b; hf",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    qkv_bias=True,
    max_seq_len=128,
    source="smoke",
)
