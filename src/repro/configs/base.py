"""Config dataclasses shared by every architecture.

Every assigned architecture gets a module in this package defining
``CONFIG`` (full production config, exact dims from the assignment) and
``SMOKE`` (reduced same-family config for CPU tests).  ``input_specs``
produces ShapeDtypeStruct stand-ins per shape cell for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_free: bool = False  # DeepSeek-V3 aux-loss-free bias balancing
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (Hymba heads) / RWKV6 head geometry."""

    state_dim: int = 16
    conv_dim: int = 4
    expand: int = 2
    head_dim: int = 64            # RWKV6 head size


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() provides precomputed embeddings."""

    kind: str                     # "audio" | "vision"
    n_tokens: int                 # frames / patches per example
    feat_dim: int                 # raw embedding dim fed to the projector


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int | None = None     # default d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"           # swiglu | gelu
    rope_theta: float = 1e4
    rope: bool = True
    max_seq_len: int = 131072
    tie_embeddings: bool = False
    residual_scale: float = 1.0   # MiniCPM depth-scaled residuals
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    window: int | None = None     # sliding-window size (hybrid/window layers)
    global_layers: tuple[int, ...] = ()   # layers with full attention (hymba)
    n_dec_layers: int = 0         # encoder-decoder: decoder depth
    mtp_heads: int = 0            # DeepSeek multi-token-prediction heads
    frontend: FrontendConfig | None = None
    meta_tokens: int = 0          # Hymba learnable prefix tokens
    dtype: Any = jnp.bfloat16
    # source citation from the assignment table
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (per DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":  # rwkv6
            h = d // (self.ssm.head_dim if self.ssm else 64)
            per_layer = d * d * 4 + d * self.d_ff * 2 + d * 32 * 5 * 2 + h * 64
        else:
            hd = self.head_dim
            if self.mla is not None:
                m = self.mla
                per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.qk_rope_head_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                per_layer += self.n_heads * hd * d
            if self.moe is not None:
                e = self.moe
                per_layer += d * e.n_experts  # router
                per_layer += (e.n_experts + e.n_shared) * 3 * d * e.d_expert
            else:
                mult = 3 if self.act == "swiglu" else 2
                per_layer += mult * d * self.d_ff
            if self.family == "hybrid" and self.ssm is not None:
                di = self.ssm.expand * d
                per_layer += d * 2 * di + di * self.ssm.state_dim * 2 + di * d
        total = emb + (L + self.n_dec_layers) * per_layer
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        d, L = self.d_model, self.n_layers
        full_expert = e.n_experts * 3 * d * e.d_expert
        act_expert = (e.top_k + e.n_shared) * 3 * d * e.d_expert
        return int(self.n_params() - L * full_expert + L * act_expert
                   - (L * e.n_shared * 3 * d * e.d_expert))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (arch × shape) dry-run cell."""

    shape_id: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    step: str                     # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> tuple[bool, str]:
    """(runs?, reason-if-skip). DESIGN.md §5 skip policy."""
    if cell.shape_id == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k dense-KV decode out of regime"
    return True, ""


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Distribution strategy knobs (launcher-level)."""

    fsdp: bool = True                # shard params/opt over data axis (ZeRO-3)
    tensor_parallel: bool = True     # Megatron TP over model axis
    expert_parallel: bool = True     # MoE experts over model axis
    expert_2d: bool = False          # experts over data×model (§Perf EP)
    sequence_parallel: bool = True   # shard seq for norms/residual
    pod_axis_role: str = "data"      # "data" | "pipeline"
    remat: str = "block"             # "none" | "block" | "full"
    grad_compression: str = "none"   # "none" | "int8" | "topk"
    collective_matmul: bool = False  # ring all-gather⊗GEMM overlap (§Perf)
    microbatches: int = 1
