"""MiniCPM-2B. [arXiv:2404.06395; hf]

Assigned: 40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753 —
WSD schedule (arch = llama-like); depth-scaled residuals
(scale_depth=1.4 → residual_scale = 1.4/sqrt(40)).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    residual_scale=1.4 / 40 ** 0.5,
    tie_embeddings=True,
    rope_theta=1e4,
    max_seq_len=131072,
    source="arXiv:2404.06395; hf",
)

SMOKE = ModelConfig(
    name="minicpm-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=4,
    n_kv_heads=4,
    d_ff=144,
    vocab_size=257,
    residual_scale=1.4 / 2 ** 0.5,
    tie_embeddings=True,
    max_seq_len=128,
    source="smoke",
)
