"""Llama-3.2-1B. [hf:meta-llama/Llama-3.2-1B; unverified]

Assigned: 16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,   # Llama-3.2-1B ties the LM head
    rope_theta=5e5,
    max_seq_len=131072,
    source="hf:meta-llama/Llama-3.2-1B; unverified",
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    tie_embeddings=True,
    max_seq_len=128,
    source="smoke",
)
