"""LLaVA-NeXT (Mistral-7B backbone). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

Assigned: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 — anyres
tiling.  The vision tower is a STUB: input_specs() provides precomputed
CLIP patch embeddings (anyres: base 576 + 4 tiles × 576 = 2880 patches,
feat 1024); the 2-layer MLP projector to d_model IS implemented.
"""
from .base import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    frontend=FrontendConfig(kind="vision", n_tokens=2880, feat_dim=1024),
    rope_theta=1e6,
    max_seq_len=131072,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    frontend=FrontendConfig(kind="vision", n_tokens=8, feat_dim=24),
    max_seq_len=128,
    source="smoke",
)
