"""Hymba-1.5B. [arXiv:2411.13676; hf]

Assigned: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — PARALLEL attention + mamba heads per layer; sliding-window
attention everywhere except 3 global-attention layers (first/middle/last);
128 learnable meta tokens.  Sub-quadratic → runs long_500k.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    ssm=SSMConfig(state_dim=16, conv_dim=4, expand=2),
    window=1024,
    global_layers=(0, 15, 31),
    meta_tokens=128,
    tie_embeddings=True,
    rope_theta=1e4,
    max_seq_len=524288 + 128,
    source="arXiv:2411.13676; hf",
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(state_dim=4, conv_dim=4, expand=2),
    window=8,
    global_layers=(0, 2),
    meta_tokens=4,
    tie_embeddings=True,
    max_seq_len=256,
    source="smoke",
)
