"""RWKV-6 (Finch) 1.6B. [arXiv:2404.05892; unverified]

Assigned: 24L d_model=2048 (attention-free) d_ff=7168 vocab=65536 —
data-dependent decay.  Head size 64 → 32 heads.  Sub-quadratic → runs
long_500k (state is O(1) in sequence length).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d_model / head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    ssm=SSMConfig(head_dim=64),
    rope=False,
    max_seq_len=1 << 20,
    source="arXiv:2404.05892; unverified",
)

SMOKE = ModelConfig(
    name="rwkv6-1.6b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(head_dim=16),
    rope=False,
    max_seq_len=256,
    source="smoke",
)
