"""Kimi K2 — trillion-param MoE. [arXiv:2501.kimi2; unverified]

Assigned: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384e top-8 (+1 shared expert, first layer dense — K2 follows the
DeepSeek-V3 layout per its tech report).
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,
    d_ff=18432,            # dense-prefix layer FFN (DSv3-style wide dense layer)
    vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared=1,
                  capacity_factor=1.25, router_aux_free=True),
    rope_theta=5e4,
    max_seq_len=131072,
    source="arXiv:2501.kimi2; unverified",
)

SMOKE = ModelConfig(
    name="kimi-k2-1t-a32b-smoke",
    family="moe",
    n_layers=3,            # 1 dense prefix + 2 MoE (dense_prefix keys on name)
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                  capacity_factor=1.5, router_aux_free=True),
    max_seq_len=128,
    source="smoke",
)
