from .pipeline import DataConfig, SyntheticLMDataset, make_dataset

__all__ = ["DataConfig", "SyntheticLMDataset", "make_dataset"]
