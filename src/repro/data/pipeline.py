"""Deterministic synthetic token pipeline (host-sharded, prefetching).

Production posture without a corpus on disk: a seeded Zipf-ish token
stream, deterministic per (seed, host, step) so (a) restarts resume exactly
(fault tolerance), (b) each data-parallel host reads a DISJOINT shard, and
(c) elastic rescale re-partitions the stream without replaying examples.
A real deployment swaps `_tokens_for` with a tokenized-shard reader; the
iterator contract (per-host batches, ``state_dict``/``load_state_dict``)
stays identical.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    prefetch: int = 2


class SyntheticLMDataset:
    """Deterministic infinite LM stream.  Batch = {tokens, labels}."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global batch must divide across hosts")
        self.cfg = cfg
        self.step = 0

    # -- determinism / checkpointing -----------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, state: dict) -> None:
        self.step = int(state["step"])

    def repartition(self, n_hosts: int, host_id: int) -> "SyntheticLMDataset":
        """Elastic rescale: same stream, new host partition, same step."""
        new = SyntheticLMDataset(dataclasses.replace(
            self.cfg, n_hosts=n_hosts, host_id=host_id))
        new.step = self.step
        return new

    # -- batches ----------------------------------------------------------------
    def _tokens_for(self, step: int, row: int) -> np.ndarray:
        """One example row: seeded by (seed, step, global_row) only."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, row]))
        # Zipf-ish marginal over the vocab with short-range repetition
        base = rng.zipf(1.3, size=c.seq_len + 1) % c.vocab_size
        rep = rng.random(c.seq_len + 1) < 0.15
        shifted = np.roll(base, 1)
        return np.where(rep, shifted, base).astype(np.int32)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        c = self.cfg
        per_host = c.global_batch // c.n_hosts
        rows = [c.host_id * per_host + r for r in range(per_host)]
        seqs = np.stack([self._tokens_for(step, r) for r in rows])
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            batch = self.batch_at(self.step)
            self.step += 1
            yield batch


def make_dataset(vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_hosts: int = 1, host_id: int = 0):
    return SyntheticLMDataset(DataConfig(
        vocab_size=vocab_size, seq_len=seq_len, global_batch=global_batch,
        seed=seed, n_hosts=n_hosts, host_id=host_id))
