"""Opara core: the paper's contribution as a composable JAX module."""
from .graph import IntensityClass, OpCost, OpGraph, OpKind, OpNode
from .profiler import (
    HardwareSpec,
    ModelProfiler,
    OpProfile,
    ProfileTable,
    V5E,
    apply_profile,
    detach_profile,
)
from .stream_alloc import StreamPlan, allocate_streams, count_syncs
from .nimble import allocate_streams_nimble
from .launch_order import (
    ORDER_POLICIES,
    critical_path_order,
    depth_first_order,
    opara_launch_order,
    resource_only_order,
    topo_order,
)
from .fusion import (
    Wave,
    WaveSchedule,
    build_waves,
    fusion_stats,
    regroup_waves,
    repack_waves,
)
from .simulator import (
    SimConfig,
    SimResult,
    estimate_makespan,
    sequential_makespan,
    simulate,
)
from .capture import CapturedGraph, Step, capture, run_sequential_uncompiled
from .scheduler import (
    ALLOC_POLICIES,
    RefineConfig,
    SchedulePlan,
    autotune,
    compare_policies,
    compile_plan,
    estimate_plan,
    refine,
    schedule,
    simulate_plan,
)
from .session import (
    CompiledModel,
    Session,
    SessionConfig,
    calibration_key,
    default_session,
    graph_signature,
    reset_default_session,
)
from .api import (
    cache_stats,
    calibrate,
    clear_caches,
    optimize,
    plan,
)

__all__ = [
    "IntensityClass", "OpCost", "OpGraph", "OpKind", "OpNode",
    "HardwareSpec", "ModelProfiler", "OpProfile", "ProfileTable", "V5E",
    "apply_profile", "detach_profile",
    "StreamPlan", "allocate_streams", "count_syncs", "allocate_streams_nimble",
    "ORDER_POLICIES", "critical_path_order", "depth_first_order",
    "opara_launch_order", "resource_only_order", "topo_order",
    "Wave", "WaveSchedule", "build_waves", "fusion_stats", "regroup_waves",
    "repack_waves",
    "SimConfig", "SimResult", "estimate_makespan", "sequential_makespan",
    "simulate",
    "CapturedGraph", "Step", "capture", "run_sequential_uncompiled",
    "ALLOC_POLICIES", "RefineConfig", "SchedulePlan", "autotune",
    "compare_policies", "compile_plan", "estimate_plan", "refine",
    "schedule", "simulate_plan",
    "CompiledModel", "Session", "SessionConfig", "default_session",
    "reset_default_session",
    "cache_stats", "calibrate", "calibration_key", "clear_caches",
    "graph_signature", "optimize", "plan",
]
