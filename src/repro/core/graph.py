"""Operator DAG intermediate representation.

This is the graph Opara schedules: every node is a DNN operator with a
callable payload (pure function of jnp arrays), explicit data dependencies,
and a resource profile filled in by the Model Profiler
(:mod:`repro.core.profiler`).

The IR intentionally mirrors ``torch.fx.Graph`` at the granularity the paper
uses (one node per framework-level operator: a GEMM, a norm, a gather, ...),
not per-HLO.  Models in :mod:`repro.models` emit an ``OpGraph`` for their
block structure via :class:`GraphBuilder`.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable, Mapping, Sequence


class OpKind(enum.Enum):
    """Coarse operator taxonomy (used for fusion signatures + intensity)."""

    GEMM = "gemm"              # dense matmul / einsum
    CONV = "conv"              # convolution (stub frontends)
    ATTENTION = "attention"    # fused attention block
    SCAN = "scan"              # linear recurrence (SSM / RWKV)
    NORM = "norm"              # layernorm / rmsnorm
    ELEMENTWISE = "elementwise"
    GATHER = "gather"          # embedding lookup / index select
    SCATTER = "scatter"        # MoE dispatch / combine
    REDUCE = "reduce"          # softmax denominators, pooling, logits reduce
    INPUT = "input"
    OUTPUT = "output"


class IntensityClass(enum.Enum):
    """Paper §3.3: operators are classified compute- vs memory-intensive."""

    COMPUTE = "compute"
    MEMORY = "memory"


@dataclasses.dataclass
class OpCost:
    """Resource demands of one operator.

    GPU Opara profiles (threads, registers, shared memory) per block; the TPU
    analogue (DESIGN.md §2) is (FLOPs, HBM bytes, VMEM working set).

    ``resource_demand()`` is the scalar Alg. 2 sorts on ("least amount of GPU
    resources" in the paper): we use the VMEM working set, the unit that
    fragments on TPU the way SM slots fragment on A100.
    """

    flops: float = 0.0
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    vmem_bytes: float = 0.0          # working-set estimate
    # fraction of the device's parallel compute the op can occupy (GPU: SM
    # occupancy; TPU: MXU/VPU lane utilization).  Small ops occupy little —
    # the paper's Fig. 1 under-utilization — leaving room for concurrent
    # lanes; big-batch ops saturate (Fig. 8 diminishing gains).
    occupancy: float | None = None
    measured_us: float | None = None  # optional measured wall-time

    OCCUPANCY_UNIT = 128 * 2**20     # demand units when occupancy is set

    @property
    def bytes_total(self) -> float:
        return self.bytes_read + self.bytes_written

    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_total, 1.0)

    def resource_demand(self) -> float:
        if self.occupancy is not None:
            return self.occupancy * self.OCCUPANCY_UNIT
        return self.vmem_bytes

    def intensity(self, machine_balance: float) -> IntensityClass:
        if self.arithmetic_intensity() >= machine_balance:
            return IntensityClass.COMPUTE
        return IntensityClass.MEMORY


@dataclasses.dataclass
class OpNode:
    """One operator in the DAG."""

    op_id: int
    name: str
    kind: OpKind
    fn: Callable[..., Any] | None = None   # payload: positional jnp arrays
    inputs: tuple[int, ...] = ()           # producer op_ids (ordered args)
    out_shape: tuple[int, ...] | None = None
    out_dtype: Any = None
    cost: OpCost = dataclasses.field(default_factory=OpCost)
    # Fusion signature: ops with the same non-None signature appearing in the
    # same wave can be horizontally fused (stacked into one kernel).
    fuse_sig: tuple | None = None
    # Free-form metadata (e.g. which weight a GEMM consumes).
    meta: dict = dataclasses.field(default_factory=dict)

    def __hash__(self) -> int:  # allow set membership keyed by identity
        return self.op_id


class OpGraph:
    """A DAG of :class:`OpNode`.  Insertion order is a topological order.

    Invariants (enforced by :meth:`validate` and hypothesis tests):
      * acyclic — every edge points from a lower to a higher ``op_id``
        (builders always reference already-created nodes);
      * ``inputs`` of a node only reference existing nodes.
    """

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.nodes: dict[int, OpNode] = {}
        self._next_id = 0

    # -- construction -------------------------------------------------------
    def add(
        self,
        name: str,
        kind: OpKind,
        inputs: Sequence[int] = (),
        fn: Callable[..., Any] | None = None,
        out_shape: tuple[int, ...] | None = None,
        out_dtype: Any = None,
        cost: OpCost | None = None,
        fuse_sig: tuple | None = None,
        **meta: Any,
    ) -> int:
        for i in inputs:
            if i not in self.nodes:
                raise ValueError(f"op {name!r}: unknown input id {i}")
        op_id = self._next_id
        self._next_id += 1
        self.nodes[op_id] = OpNode(
            op_id=op_id,
            name=name,
            kind=kind,
            fn=fn,
            inputs=tuple(inputs),
            out_shape=out_shape,
            out_dtype=out_dtype,
            cost=cost or OpCost(),
            fuse_sig=fuse_sig,
            meta=dict(meta),
        )
        return op_id

    # -- topology queries ----------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def __iter__(self) -> Iterable[OpNode]:
        return iter(self.nodes.values())

    def predecessors(self, op_id: int) -> tuple[int, ...]:
        return self.nodes[op_id].inputs

    def successors_map(self) -> dict[int, list[int]]:
        succ: dict[int, list[int]] = {i: [] for i in self.nodes}
        for node in self.nodes.values():
            for p in node.inputs:
                succ[p].append(node.op_id)
        return succ

    def indegree_map(self) -> dict[int, int]:
        return {i: len(set(n.inputs)) for i, n in self.nodes.items()}

    def roots(self) -> list[int]:
        return [i for i, n in self.nodes.items() if not n.inputs]

    def leaves(self) -> list[int]:
        succ = self.successors_map()
        return [i for i in self.nodes if not succ[i]]

    def topological_order(self) -> list[int]:
        """Kahn order with FIFO tie-break == insertion order (the paper's
        default "topological sorting order" baseline)."""
        indeg = self.indegree_map()
        succ = self.successors_map()
        ready = sorted(i for i, d in indeg.items() if d == 0)
        out: list[int] = []
        import heapq

        heapq.heapify(ready)
        while ready:
            i = heapq.heappop(ready)
            out.append(i)
            for s in succ[i]:
                # inputs may repeat; only decrement once per unique edge
                pass
            for s in set(succ[i]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(out) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return out

    def depth_first_order(self) -> list[int]:
        """Depth-first topological order (paper Fig. 2 "order 1" baseline)."""
        succ = self.successors_map()
        indeg = self.indegree_map()
        stack = sorted((i for i, d in indeg.items() if d == 0), reverse=True)
        out: list[int] = []
        while stack:
            i = stack.pop()
            out.append(i)
            for s in sorted(set(succ[i]), reverse=True):
                indeg[s] -= 1
                if indeg[s] == 0:
                    stack.append(s)
        if len(out) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return out

    def validate(self) -> None:
        for node in self.nodes.values():
            for p in node.inputs:
                if p not in self.nodes:
                    raise ValueError(f"dangling edge {p}->{node.op_id}")
                if p >= node.op_id:
                    raise ValueError(
                        f"non-topological edge {p}->{node.op_id}; graph must be "
                        "built producer-first"
                    )
        self.topological_order()  # raises on cycle

    def max_width(self) -> int:
        """Maximum antichain width by longest-path leveling (the paper notes
        Alg. 1's inner loop is bounded by graph width, typically < 20)."""
        level: dict[int, int] = {}
        for i in self.topological_order():
            node = self.nodes[i]
            level[i] = 1 + max((level[p] for p in node.inputs), default=-1)
        from collections import Counter

        return max(Counter(level.values()).values()) if level else 0

    def critical_path_cost(self, duration: Mapping[int, float]) -> float:
        """Lower bound on makespan given per-op durations."""
        best: dict[int, float] = {}
        for i in self.topological_order():
            node = self.nodes[i]
            best[i] = duration[i] + max((best[p] for p in node.inputs), default=0.0)
        return max(best.values(), default=0.0)


def sequential_chain(n: int, kind: OpKind = OpKind.GEMM) -> OpGraph:
    """Tiny helper used by tests: a pure chain (no parallelism)."""
    g = OpGraph("chain")
    prev: list[int] = []
    for i in range(n):
        prev = [g.add(f"op{i}", kind, inputs=prev)]
    return g
