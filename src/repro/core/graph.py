"""Operator DAG intermediate representation.

This is the graph Opara schedules: every node is a DNN operator with a
callable payload (pure function of jnp arrays), explicit data dependencies,
and a resource profile filled in by the Model Profiler
(:mod:`repro.core.profiler`).

The IR intentionally mirrors ``torch.fx.Graph`` at the granularity the paper
uses (one node per framework-level operator: a GEMM, a norm, a gather, ...),
not per-HLO.  Models in :mod:`repro.models` emit an ``OpGraph`` for their
block structure via :class:`GraphBuilder`.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Iterable, Mapping, Sequence


class OpKind(enum.Enum):
    """Coarse operator taxonomy (used for fusion signatures + intensity)."""

    GEMM = "gemm"              # dense matmul / einsum
    CONV = "conv"              # convolution (stub frontends)
    ATTENTION = "attention"    # fused attention block
    SCAN = "scan"              # linear recurrence (SSM / RWKV)
    NORM = "norm"              # layernorm / rmsnorm
    ELEMENTWISE = "elementwise"
    GATHER = "gather"          # embedding lookup / index select
    SCATTER = "scatter"        # MoE dispatch / combine
    REDUCE = "reduce"          # softmax denominators, pooling, logits reduce
    INPUT = "input"
    OUTPUT = "output"


class IntensityClass(enum.Enum):
    """Paper §3.3: operators are classified compute- vs memory-intensive."""

    COMPUTE = "compute"
    MEMORY = "memory"


@dataclasses.dataclass
class OpCost:
    """Resource demands of one operator.

    GPU Opara profiles (threads, registers, shared memory) per block; the TPU
    analogue (DESIGN.md §2) is (FLOPs, HBM bytes, VMEM working set).

    ``resource_demand()`` is the scalar Alg. 2 sorts on ("least amount of GPU
    resources" in the paper): we use the VMEM working set, the unit that
    fragments on TPU the way SM slots fragment on A100.
    """

    flops: float = 0.0
    bytes_read: float = 0.0
    bytes_written: float = 0.0
    vmem_bytes: float = 0.0          # working-set estimate
    # fraction of the device's parallel compute the op can occupy (GPU: SM
    # occupancy; TPU: MXU/VPU lane utilization).  Small ops occupy little —
    # the paper's Fig. 1 under-utilization — leaving room for concurrent
    # lanes; big-batch ops saturate (Fig. 8 diminishing gains).
    occupancy: float | None = None
    measured_us: float | None = None  # optional measured wall-time

    OCCUPANCY_UNIT = 128 * 2**20     # demand units when occupancy is set

    @property
    def bytes_total(self) -> float:
        return self.bytes_read + self.bytes_written

    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_total, 1.0)

    def resource_demand(self) -> float:
        if self.occupancy is not None:
            return self.occupancy * self.OCCUPANCY_UNIT
        return self.vmem_bytes

    def intensity(self, machine_balance: float) -> IntensityClass:
        if self.arithmetic_intensity() >= machine_balance:
            return IntensityClass.COMPUTE
        return IntensityClass.MEMORY


@dataclasses.dataclass
class OpNode:
    """One operator in the DAG."""

    op_id: int
    name: str
    kind: OpKind
    fn: Callable[..., Any] | None = None   # payload: positional jnp arrays
    inputs: tuple[int, ...] = ()           # producer op_ids (ordered args)
    out_shape: tuple[int, ...] | None = None
    out_dtype: Any = None
    cost: OpCost = dataclasses.field(default_factory=OpCost)
    # Fusion signature: ops with the same non-None signature appearing in the
    # same wave can be horizontally fused (stacked into one kernel).
    fuse_sig: tuple | None = None
    # Free-form metadata (e.g. which weight a GEMM consumes).
    meta: dict = dataclasses.field(default_factory=dict)

    def __hash__(self) -> int:  # allow set membership keyed by identity
        return self.op_id


@dataclasses.dataclass
class _Topology:
    """Memoized topology bundle shared by every pipeline stage."""

    succ: dict[int, list[int]]         # per-edge successors (duplicates kept)
    unique_succ: dict[int, list[int]]  # deduplicated successors
    indeg: dict[int, int]              # unique-edge indegrees
    order: list[int]                   # Kahn order (may be short on cycles)


class OpGraph:
    """A DAG of :class:`OpNode`.  Insertion order is a topological order.

    Invariants (enforced by :meth:`validate` and hypothesis tests):
      * acyclic — every edge points from a lower to a higher ``op_id``
        (builders always reference already-created nodes);
      * ``inputs`` of a node only reference existing nodes.
    """

    def __init__(self, name: str = "graph") -> None:
        self.name = name
        self.nodes: dict[int, OpNode] = {}
        self._next_id = 0
        # Memoized topology (successors / indegrees / topo order).  Every
        # pipeline stage (validate → profile → alloc → order → waves →
        # capture) walks the same DAG; without the cache schedule() is
        # O(k·(V+E)) with k = number of stages.  Invalidated by add().
        self._topo: _Topology | None = None
        # Memoized structural node signature (compiled-plan cache key part);
        # also invalidated by add().  _sig_digest is its sha1 — cache keys
        # embed the digest so probing the plan/executable LRUs does not
        # re-hash a multi-thousand-entry nested tuple per lookup.
        self._node_sig: tuple | None = None
        self._sig_digest: str | None = None
        # Fingerprint of the measured-profile table currently hydrated onto
        # node costs (None = analytic state).  Set/cleared by the profiler's
        # apply/detach lifecycle; cache keys combine it with node_signature()
        # so calibrated and uncalibrated plans never collide while the raw
        # timings stay OUT of the structural signature.
        self.calibration_fp: tuple | None = None

    # -- construction -------------------------------------------------------
    def add(
        self,
        name: str,
        kind: OpKind,
        inputs: Sequence[int] = (),
        fn: Callable[..., Any] | None = None,
        out_shape: tuple[int, ...] | None = None,
        out_dtype: Any = None,
        cost: OpCost | None = None,
        fuse_sig: tuple | None = None,
        **meta: Any,
    ) -> int:
        for i in inputs:
            if i not in self.nodes:
                raise ValueError(f"op {name!r}: unknown input id {i}")
        op_id = self._next_id
        self._next_id += 1
        self._topo = None       # invalidate memoized topology
        self._node_sig = None   # ... and the structural signature
        self._sig_digest = None
        if self.calibration_fp is not None:
            # structural mutation invalidates any hydrated measured profile
            # (the table no longer covers the graph) — drop back to analytic
            for n in self.nodes.values():
                n.cost.measured_us = None
            self.calibration_fp = None
        self.nodes[op_id] = OpNode(
            op_id=op_id,
            name=name,
            kind=kind,
            fn=fn,
            inputs=tuple(inputs),
            out_shape=out_shape,
            out_dtype=out_dtype,
            cost=cost or OpCost(),
            fuse_sig=fuse_sig,
            meta=dict(meta),
        )
        return op_id

    # -- topology queries ----------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def __iter__(self) -> Iterable[OpNode]:
        return iter(self.nodes.values())

    def predecessors(self, op_id: int) -> tuple[int, ...]:
        return self.nodes[op_id].inputs

    # -- memoized topology ---------------------------------------------------
    def _topology(self) -> "_Topology":
        """Compute (once) successors, unique successors, indegrees and the
        Kahn topological order.  All public topology queries read this cache;
        ``add()`` invalidates it.  Returned structures are SHARED — callers
        must not mutate them (use the public accessors, which copy where the
        call convention requires a private mutable map)."""
        if self._topo is None:
            succ: dict[int, list[int]] = {i: [] for i in self.nodes}
            usucc: dict[int, list[int]] = {i: [] for i in self.nodes}
            indeg: dict[int, int] = {}
            for node in self.nodes.values():
                uniq = set(node.inputs)
                indeg[node.op_id] = len(uniq)
                for p in node.inputs:
                    succ[p].append(node.op_id)
                for p in uniq:
                    usucc[p].append(node.op_id)

            import heapq

            work = dict(indeg)
            ready = [i for i, d in work.items() if d == 0]
            heapq.heapify(ready)
            out: list[int] = []
            while ready:
                i = heapq.heappop(ready)
                out.append(i)
                for s in usucc[i]:
                    work[s] -= 1
                    if work[s] == 0:
                        heapq.heappush(ready, s)
            self._topo = _Topology(succ=succ, unique_succ=usucc, indeg=indeg,
                                   order=out)
        return self._topo

    def successors_map(self) -> dict[int, list[int]]:
        """op_id -> successor ids (one entry per edge, duplicates kept).
        Shared cache — treat as read-only."""
        return self._topology().succ

    def unique_successors_map(self) -> dict[int, list[int]]:
        """op_id -> unique successor ids.  Shared cache — read-only."""
        return self._topology().unique_succ

    def indegree_map(self) -> dict[int, int]:
        """Fresh copy (callers decrement it during scheduling)."""
        return dict(self._topology().indeg)

    def roots(self) -> list[int]:
        return [i for i, n in self.nodes.items() if not n.inputs]

    def leaves(self) -> list[int]:
        succ = self._topology().succ
        return [i for i in self.nodes if not succ[i]]

    def topological_order(self) -> list[int]:
        """Kahn order with FIFO tie-break == insertion order (the paper's
        default "topological sorting order" baseline).  Memoized; raises on
        cycles."""
        topo = self._topology()
        if len(topo.order) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return list(topo.order)

    def depth_first_order(self) -> list[int]:
        """Depth-first topological order (paper Fig. 2 "order 1" baseline)."""
        topo = self._topology()
        indeg = dict(topo.indeg)
        stack = sorted((i for i, d in indeg.items() if d == 0), reverse=True)
        out: list[int] = []
        while stack:
            i = stack.pop()
            out.append(i)
            for s in sorted(topo.unique_succ[i], reverse=True):
                indeg[s] -= 1
                if indeg[s] == 0:
                    stack.append(s)
        if len(out) != len(self.nodes):
            raise ValueError("graph has a cycle")
        return out

    def invalidate_signature(self) -> None:
        """Must be called after mutating structural node fields in place
        (analytic costs, fusion signatures, payloads/consts) — ``add()`` is
        the only mutation the signature cache sees on its own.  Measured
        timings are NOT structural: the profiler's apply/detach lifecycle
        tracks them via ``calibration_fp`` instead."""
        self._node_sig = None
        self._sig_digest = None

    def node_signature(self) -> tuple:
        """Memoized structural fingerprint of every node: everything the
        scheduling pipeline reads (kind, edges, shapes, dtypes, fusion
        signature, analytic cost, payload marker, const shapes) and nothing
        it doesn't (weight values, payload identities, measured timings —
        those are tracked separately via ``calibration_fp`` so hydrating a
        measured profile does not change the graph's structural identity).
        The compiled-plan and calibration caches on :class:`repro.core.Session`
        build their keys from this."""
        if self._node_sig is None:
            self._node_sig = tuple(
                (
                    n.kind.value,
                    n.inputs,
                    n.out_shape,
                    str(n.out_dtype),
                    n.fuse_sig,
                    # analytic cost fields + resource_demand(), the scalar
                    # the wave repacker admits on.  Redundant with occupancy/
                    # vmem_bytes TODAY, but pinned explicitly so a future
                    # resource_demand() reading inputs outside this tuple
                    # cannot silently escape the plan/autotune cache keys.
                    (n.cost.flops, n.cost.bytes_read, n.cost.bytes_written,
                     n.cost.vmem_bytes, n.cost.occupancy,
                     n.cost.resource_demand()),
                    n.fn is None,
                    n.meta.get("payload"),
                    tuple(tuple(getattr(c, "shape", ()))
                          for c in n.meta.get("consts", ())),
                )
                for n in self.nodes.values()
            )
        return self._node_sig

    def signature_digest(self) -> str:
        """Memoized sha1 of :meth:`node_signature` — the compact component
        plan/executable cache keys embed.  Probing an LRU hashes the whole
        key; on multi-thousand-op graphs hashing the raw nested tuple costs
        ~1 ms per probe, so keys carry this 40-char digest instead (the full
        tuple remains the calibration cache's key part, where its repr also
        serves as the on-disk collision check)."""
        if self._sig_digest is None:
            import hashlib

            self._sig_digest = hashlib.sha1(
                repr(self.node_signature()).encode()).hexdigest()
        return self._sig_digest

    def input_signature(self, inputs: Mapping[int, Any]) -> tuple:
        """Shape/dtype fingerprint of a concrete input binding — the
        ``measured_inputs`` part of the calibration-cache key.  Two bindings
        with identical shapes and dtypes are interchangeable for profiling
        (operator wall time depends on geometry, not values)."""
        sig = []
        for i in sorted(inputs):
            if i not in self.nodes:
                raise ValueError(f"input binding references unknown op id {i}")
            a = inputs[i]
            shape = getattr(a, "shape", None)
            dtype = getattr(a, "dtype", None)
            if shape is None or dtype is None:
                import numpy as _np
                arr = _np.asarray(a)
                shape, dtype = arr.shape, arr.dtype
            sig.append((i, tuple(shape), str(dtype)))
        return tuple(sig)

    def validate(self) -> None:
        for node in self.nodes.values():
            for p in node.inputs:
                if p not in self.nodes:
                    raise ValueError(f"dangling edge {p}->{node.op_id}")
                if p >= node.op_id:
                    raise ValueError(
                        f"non-topological edge {p}->{node.op_id}; graph must be "
                        "built producer-first"
                    )
        self.topological_order()  # raises on cycle

    def max_width(self) -> int:
        """Maximum antichain width by longest-path leveling (the paper notes
        Alg. 1's inner loop is bounded by graph width, typically < 20)."""
        level: dict[int, int] = {}
        for i in self.topological_order():
            node = self.nodes[i]
            level[i] = 1 + max((level[p] for p in node.inputs), default=-1)
        from collections import Counter

        return max(Counter(level.values()).values()) if level else 0

    def critical_path_cost(self, duration: Mapping[int, float]) -> float:
        """Lower bound on makespan given per-op durations."""
        best: dict[int, float] = {}
        for i in self.topological_order():
            node = self.nodes[i]
            best[i] = duration[i] + max((best[p] for p in node.inputs), default=0.0)
        return max(best.values(), default=0.0)


def sequential_chain(n: int, kind: OpKind = OpKind.GEMM) -> OpGraph:
    """Tiny helper used by tests: a pure chain (no parallelism)."""
    g = OpGraph("chain")
    prev: list[int] = []
    for i in range(n):
        prev = [g.add(f"op{i}", kind, inputs=prev)]
    return g
