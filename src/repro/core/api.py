"""User-facing Opara API.

    from repro.core import api as opara

    g = ...            # OpGraph emitted by a model (repro.models.*)
    exe = opara.optimize(g)          # full pipeline → single executable
    outs = exe({"tokens": x})

``optimize`` = Alg.1 streams + profile + Alg.2 order + wave fusion + capture,
i.e. the whole paper pipeline with one call, non-intrusively wrapping any
operator graph.  ``plan(..., autotune=True)`` / ``optimize(...,
autotune=True)`` swap the fixed policies for the simulator-guided schedule
search (:func:`repro.core.scheduler.autotune`); the search result is cached
under the same plan cache (keyed by the ``sim_cfg`` cost model alongside the
structural signature), so tuning happens once per graph structure and the
warm path is identical to the single-policy one.

Compiled-plan cache
-------------------
Scheduling is a pure function of graph *structure* (op kinds, edges, shapes,
dtypes, analytic costs), the hydrated calibration (if any) and the chosen
policies — never of the weight values.  ``plan()`` therefore memoizes
:class:`SchedulePlan`s under a structural :func:`graph_signature`; a second
``plan()``/``schedule()`` on an architecturally-identical graph (e.g. every
``serving`` engine tick, or rebuilding the same model) does zero
re-profiling, re-allocation and re-ordering.  On a hit for a *different*
graph object the plan is rebound to the caller's graph (op_ids are
structural: same build order → same ids).

Measured-profile calibration cache
----------------------------------
The paper "profiles each DNN inference only once" (§3.2).  ``plan(...,
measured_inputs=...)`` realizes that: the first call runs the single
profiling inference and stores the resulting :class:`ProfileTable` keyed by
``(graph.node_signature(), graph.input_signature(inputs), hw.name)``; every
later call — including on a *structurally identical* graph object such as a
reloaded checkpoint — hydrates ``measured_us`` from the cache (zero
re-timing) and then takes the warm plan-cache path.  The hydrated table's
fingerprint rides in :func:`graph_signature`, so calibrated and analytic
plans for the same structure never collide.  :func:`calibrate` is the
stand-alone entry point (e.g. to control ``repeats``).

The calibration cache has a disk tier: tables are persisted as JSON under
``$REPRO_CALIB_DIR`` (default ``~/.cache/repro/calib``), keyed by the same
(node_signature, input_signature, hw.name) triple, so serving processes
re-hydrate measured profiles across restarts without re-timing.
``plan(..., load=False)`` / ``calibrate(..., load=False)`` skip the disk
read (escape hatch for invalidated timings, e.g. after a runtime upgrade).

``optimize()`` adds a third cache level for the captured executable.  An
executable closes over payload callables and weights, so its key is the
plan signature PLUS a weights fingerprint of every node's ``fn`` and
``meta["consts"]`` arrays.  Two fingerprint modes (``weights_key``):
``"identity"`` (default) uses ``id()`` — same graph object or same arrays →
the IDENTICAL executable object, no re-lowering, no re-trace; cached entries
pin their graph alive, so ``id()`` fingerprints cannot collide with live
objects.  ``"content"`` (opt-in) hashes array bytes, so a checkpoint reload
that recreates *identical values* in fresh arrays still reuses the
executable — at the cost of hashing every weight once per ``optimize`` call.

Invalidation: all three caches are LRU-bounded (:data:`_CACHE_SIZE`);
mutating a graph via ``add()`` changes its signature (and drops any hydrated
calibration) so stale hits are impossible.  ``clear_caches()`` resets
everything, including ``cache_stats()`` counters (tests).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from collections import OrderedDict
from typing import Any, Mapping

import numpy as np

from .capture import CapturedGraph
from .graph import OpGraph
from .profiler import (
    HardwareSpec,
    ModelProfiler,
    ProfileTable,
    V5E,
    apply_profile,
)
from .scheduler import SchedulePlan, compile_plan, schedule
from .scheduler import autotune as autotune_schedule
from .simulator import SimConfig

_CACHE_SIZE = 64
_plan_cache: OrderedDict[tuple, SchedulePlan] = OrderedDict()
_exec_cache: OrderedDict[tuple, CapturedGraph] = OrderedDict()
_calib_cache: OrderedDict[tuple, ProfileTable] = OrderedDict()
_stats = {"plan_hits": 0, "plan_misses": 0, "exec_hits": 0, "exec_misses": 0,
          "calib_hits": 0, "calib_misses": 0, "calib_disk_hits": 0}

# Disk tier of the calibration cache: ProfileTables serialized under
# ``$REPRO_CALIB_DIR`` (default ``~/.cache/repro/calib``), one JSON file per
# (node_signature, input_signature, hw.name) triple, so a serving process
# restart re-hydrates measured profiles without a profiling inference.
# Bounded: stores beyond _DISK_CACHE_MAX entries evict the oldest-mtime
# files (a coarse LRU — loads don't bump mtime, but a serving fleet's hot
# geometries get re-stored whenever the memory LRU cycles them).
_CALIB_DIR_ENV = "REPRO_CALIB_DIR"
_DISK_CACHE_MAX = 512


def graph_signature(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    max_lanes: int | None = None,
    sim_cfg: SimConfig | None = None,
) -> tuple:
    """Structural cache key: everything scheduling reads, nothing it doesn't.

    Per node: kind, edges, output shape/dtype, fusion signature, analytic
    cost fields (including the derived ``resource_demand()`` the repacker
    admits on), payload marker and const shapes (capture's stackability
    inputs) — see :meth:`OpGraph.node_signature`, which memoizes the node
    part per graph version.  The hydrated calibration fingerprint (if any)
    is a separate component: measured timings change schedules, but they are
    not part of the graph's structural identity.  ``sim_cfg`` (a frozen,
    hashable :class:`SimConfig`) joins the key for autotuned plans — the
    cost model's resource cap and penalties steer the search, so two
    configs must never share a tuned plan.  Weight *values* and payload
    identities are deliberately excluded — they cannot change a schedule.

    The per-node part enters as :meth:`OpGraph.signature_digest` (memoized
    sha1 of the full node tuple) so cache probes stay O(1) in graph size.
    """
    return (graph.signature_digest(), graph.calibration_fp,
            alloc_policy, order_policy, hw, max_lanes, sim_cfg)


def calibration_key(graph: OpGraph, inputs: Mapping[int, Any],
                    hw: HardwareSpec = V5E) -> tuple:
    """Calibration-cache key: structure × input geometry × hardware."""
    return (graph.node_signature(), graph.input_signature(inputs), hw.name)


def _content_digest(a: Any) -> tuple:
    arr = np.asarray(a)
    return (str(arr.dtype), arr.shape,
            hashlib.sha1(arr.tobytes()).hexdigest())


def _weights_fingerprint(graph: OpGraph, weights_key: str = "identity") -> tuple:
    """Fingerprint of every payload + const array (executable cache key part).

    ``identity`` — ``id()`` of callables and arrays (fast; live-object safe
    because cached executables pin their graph).  ``content`` — code-object
    identity for callables (stable across re-created lambdas from the same
    source) + a byte digest of each const, so recreated-but-equal arrays
    (checkpoint reload) share the executable.
    """
    if weights_key == "identity":
        return tuple(
            (id(n.fn), tuple(id(c) for c in n.meta.get("consts", ())))
            for n in graph
        )
    if weights_key == "content":
        return tuple(
            (id(getattr(n.fn, "__code__", n.fn)),
             tuple(_content_digest(c) for c in n.meta.get("consts", ())))
            for n in graph
        )
    raise ValueError(f"unknown weights_key {weights_key!r}")


def _lru_get(cache: OrderedDict, key: tuple) -> Any | None:
    if key in cache:
        cache.move_to_end(key)
        return cache[key]
    return None


def _lru_put(cache: OrderedDict, key: tuple, value: Any) -> None:
    cache[key] = value
    cache.move_to_end(key)
    while len(cache) > _CACHE_SIZE:
        cache.popitem(last=False)


def _calib_dir() -> str:
    return os.environ.get(_CALIB_DIR_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "calib")


def _calib_path(key: tuple) -> str:
    digest = hashlib.sha1(repr(key).encode()).hexdigest()
    return os.path.join(_calib_dir(), f"{digest}.json")


def _calib_disk_load(key: tuple) -> ProfileTable | None:
    try:
        with open(_calib_path(key)) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    if doc.get("key") != repr(key):   # sha1 collision / stale format
        return None
    return ProfileTable(
        hw_name=doc["hw_name"],
        measured_us=tuple((int(i), float(us)) for i, us in doc["measured_us"]))


def _calib_disk_store(key: tuple, table: ProfileTable) -> None:
    """Best-effort atomic write; serving must never fail on a full disk."""
    tmp = None
    try:
        os.makedirs(_calib_dir(), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=_calib_dir(), suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"key": repr(key), "hw_name": table.hw_name,
                       "measured_us": [list(m) for m in table.measured_us]}, f)
        os.replace(tmp, _calib_path(key))
        _calib_disk_evict()
    except OSError:
        if tmp is not None:   # don't strand the temp file on a full disk
            try:
                os.unlink(tmp)
            except OSError:
                pass


def _calib_disk_evict() -> None:
    """Drop oldest-mtime entries beyond _DISK_CACHE_MAX (runs per store —
    rare: stores happen only on full cache misses)."""
    d = _calib_dir()
    try:
        entries = [e for e in os.scandir(d) if e.name.endswith(".json")]
        if len(entries) <= _DISK_CACHE_MAX:
            return
        entries.sort(key=lambda e: e.stat().st_mtime)
        for e in entries[:len(entries) - _DISK_CACHE_MAX]:
            try:
                os.unlink(e.path)
            except OSError:
                pass
    except OSError:
        pass


def calibrate(
    graph: OpGraph,
    inputs: Mapping[int, Any],
    hw: HardwareSpec = V5E,
    repeats: int = 3,
    load: bool = True,
) -> ProfileTable:
    """Hydrate ``graph`` with a measured profile, timing at most once.

    Memory-cache hit → the stored table is re-applied (zero re-timing);
    memory miss → the disk tier is consulted (``load=False`` skips it, e.g.
    after a kernel/runtime upgrade that invalidates persisted timings);
    full miss → one profiling inference (the paper's "profile each DNN
    inference only once"), stored to both tiers for every structurally
    identical graph — including one built by a later process — that follows.
    """
    key = calibration_key(graph, inputs, hw)
    table = _lru_get(_calib_cache, key)
    if table is not None:
        _stats["calib_hits"] += 1            # memory-tier hit
    elif load and (table := _calib_disk_load(key)) is not None:
        _stats["calib_disk_hits"] += 1       # disk-tier hit (counted apart)
        _lru_put(_calib_cache, key, table)
    else:
        _stats["calib_misses"] += 1
        table = ModelProfiler(hw).measure(graph, inputs, repeats=repeats)
        _lru_put(_calib_cache, key, table)
        _calib_disk_store(key, table)
    if graph.calibration_fp != table.fingerprint:
        apply_profile(graph, table)
    return table


def _autotune_key_parts(sim_cfg: SimConfig | None) -> tuple[str, str, SimConfig]:
    """The autotuned-plan cache-key normalization, shared by plan() and
    optimize() so the executable-cache key can never drift from the
    plan-cache key: policy slots carry a sentinel (the tuner picks the real
    policies) and sim_cfg defaults the same way autotune_schedule does, so
    an explicit default SimConfig() shares the implicit-None entry."""
    return "__autotune__", "__autotune__", sim_cfg or SimConfig()


def plan(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    measured_inputs: Mapping[int, Any] | None = None,
    cache: bool = True,
    autotune: bool = False,
    sim_cfg: SimConfig | None = None,
    load: bool = True,
) -> SchedulePlan:
    """Cached scheduling; ``autotune=True`` replaces the single-policy
    pipeline with the simulator-guided search (``alloc_policy`` /
    ``order_policy`` are then ignored — the tuner picks them) under
    ``sim_cfg``'s cost model.  The search result lands in the same plan
    cache, so the warm path costs the same ~0.04 ms either way.  ``load``
    gates the calibration cache's disk tier (see :func:`calibrate`).
    """
    if autotune:
        alloc_policy, order_policy, sim_cfg = _autotune_key_parts(sim_cfg)
    if not cache:
        if autotune:
            return autotune_schedule(graph, hw=hw, cfg=sim_cfg,
                                     measured_inputs=measured_inputs)
        return schedule(graph, alloc_policy, order_policy, hw,
                        measured_inputs=measured_inputs, sim_cfg=sim_cfg)
    if measured_inputs is not None:
        calibrate(graph, measured_inputs, hw, load=load)
    key = graph_signature(graph, alloc_policy, order_policy, hw,
                          sim_cfg=sim_cfg)
    hit = _lru_get(_plan_cache, key)
    if hit is not None:
        _stats["plan_hits"] += 1
        if hit.graph is graph:
            return hit
        # same structure, different graph object: rebind (op_ids match)
        return dataclasses.replace(hit, graph=graph)
    _stats["plan_misses"] += 1
    # measured timings (if any) are already hydrated onto node costs, so the
    # plain pipeline schedules with them — no re-timing here.
    if autotune:
        p = autotune_schedule(graph, hw=hw, cfg=sim_cfg)
    else:
        p = schedule(graph, alloc_policy, order_policy, hw, sim_cfg=sim_cfg)
    _lru_put(_plan_cache, key, p)
    return p


def optimize(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    output_ids=None,
    gemm_kernel: str = "auto",
    cache: bool = True,
    weights_key: str = "identity",
    autotune: bool = False,
    sim_cfg: SimConfig | None = None,
) -> CapturedGraph:
    if weights_key not in ("identity", "content"):
        raise ValueError(f"unknown weights_key {weights_key!r}")
    if autotune:
        # the executable-cache key below must stay byte-identical to the
        # plan-cache key plan() builds internally — one shared normalizer
        alloc_policy, order_policy, sim_cfg = _autotune_key_parts(sim_cfg)
    p = plan(graph, alloc_policy, order_policy, hw, cache=cache,
             autotune=autotune, sim_cfg=sim_cfg)
    if not cache:
        return compile_plan(p, output_ids=output_ids, gemm_kernel=gemm_kernel)
    key = (
        graph_signature(graph, alloc_policy, order_policy, hw,
                        sim_cfg=sim_cfg),
        weights_key,
        _weights_fingerprint(graph, weights_key),
        tuple(output_ids) if output_ids is not None else None,
        gemm_kernel,
    )
    hit = _lru_get(_exec_cache, key)
    if hit is not None:
        _stats["exec_hits"] += 1
        return hit
    _stats["exec_misses"] += 1
    exe = compile_plan(p, output_ids=output_ids, gemm_kernel=gemm_kernel)
    _lru_put(_exec_cache, key, exe)
    return exe


def cache_stats() -> dict[str, int]:
    return dict(_stats, plan_entries=len(_plan_cache),
                exec_entries=len(_exec_cache),
                calib_entries=len(_calib_cache))


def clear_caches() -> None:
    _plan_cache.clear()
    _exec_cache.clear()
    _calib_cache.clear()
    for k in _stats:
        _stats[k] = 0
