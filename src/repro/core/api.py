"""Legacy module-function Opara API (shims over :mod:`repro.core.session`).

.. deprecated::
    New code should construct a :class:`repro.core.Session`::

        from repro.core import Session, SessionConfig

        sess = Session(SessionConfig(autotune=True))
        model = sess.compile(graph, inputs=profiling_inputs)
        outs = model({"tokens": x})

    See ``docs/api.md`` for the full migration table.

Historically this module owned the whole pipeline behind three functions
(``plan`` / ``optimize`` / ``calibrate``) whose kwargs grew into a
cross-product (``alloc_policy``, ``order_policy``, ``hw``, ``sim_cfg``,
``autotune``, ``weights_key``, ``load``, …) backed by three process-global
LRU caches.  That state now lives on :class:`repro.core.session.Session`;
the functions below delegate to the process-wide
:func:`repro.core.session.default_session` — so existing callers keep the
exact same caching/amortization behavior — and emit ``DeprecationWarning``
when passed the superseded configuration kwargs (per-call data such as
``measured_inputs``, ``repeats``, ``output_ids`` and ``cache`` stays
warning-free: those remain arguments on the ``Session`` methods too).

``cache_stats()`` / ``clear_caches()`` report on and reset the default
session only; explicitly-constructed sessions are isolated and unaffected.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Mapping

from .capture import CapturedGraph
from .graph import OpGraph
from .profiler import HardwareSpec, ProfileTable, V5E
from .scheduler import SchedulePlan
from .simulator import SimConfig
from .session import (
    Session,
    SessionConfig,
    calibration_key,
    default_session,
    graph_signature,
)

__all__ = [
    "cache_stats", "calibrate", "calibration_key", "clear_caches",
    "graph_signature", "optimize", "plan",
]

# Sentinel distinguishing "kwarg not passed" from an explicit default value:
# only explicitly-passed config kwargs trigger the deprecation path.
_UNSET: Any = object()

# legacy kwarg spelling → SessionConfig field (where they differ)
_CONFIG_FIELD = {"load": "load_calibration"}


def _effective(fn_name: str, **overrides: Any) -> tuple[Session, SessionConfig]:
    """Resolve the default session + a per-call config with any explicitly
    passed legacy kwargs applied (warning once per call site)."""
    sess = default_session()
    passed = {k: v for k, v in overrides.items() if v is not _UNSET}
    if passed:
        warnings.warn(
            f"passing {sorted(passed)} to repro.core.api.{fn_name}() is "
            "deprecated; construct a repro.core.Session(SessionConfig(...)) "
            "instead (see docs/api.md for the migration table)",
            DeprecationWarning, stacklevel=3)
        cfg_kwargs = {_CONFIG_FIELD.get(k, k): v for k, v in passed.items()}
        return sess, dataclasses.replace(sess.config, **cfg_kwargs)
    return sess, sess.config


def calibrate(
    graph: OpGraph,
    inputs: Mapping[int, Any],
    hw: HardwareSpec = _UNSET,
    repeats: int | None = None,
    load: bool | None = None,
) -> ProfileTable:
    """Deprecated shim for :meth:`Session.calibrate` on the default session.

    ``repeats`` / ``load`` left unset defer to the session config
    (``calibration_repeats`` / ``load_calibration``), exactly like
    :meth:`Session.calibrate`."""
    sess, cfg = _effective("calibrate", hw=hw)
    table, _ = sess._calibrate(graph, inputs, cfg, repeats=repeats, load=load)
    return table


def plan(
    graph: OpGraph,
    alloc_policy: str = _UNSET,
    order_policy: str = _UNSET,
    hw: HardwareSpec = _UNSET,
    measured_inputs: Mapping[int, Any] | None = None,
    cache: bool = True,
    autotune: bool = _UNSET,
    sim_cfg: SimConfig | None = _UNSET,
    load: bool = _UNSET,
) -> SchedulePlan:
    """Deprecated shim for :meth:`Session.plan` on the default session."""
    sess, cfg = _effective(
        "plan", alloc_policy=alloc_policy, order_policy=order_policy, hw=hw,
        autotune=autotune, sim_cfg=sim_cfg, load=load)
    p, _ = sess._plan(graph, cfg, measured_inputs=measured_inputs,
                      cache=cache)
    return p


def optimize(
    graph: OpGraph,
    alloc_policy: str = _UNSET,
    order_policy: str = _UNSET,
    hw: HardwareSpec = _UNSET,
    output_ids=None,
    gemm_kernel: str = _UNSET,
    cache: bool = True,
    weights_key: str = _UNSET,
    autotune: bool = _UNSET,
    sim_cfg: SimConfig | None = _UNSET,
) -> CapturedGraph:
    """Deprecated shim for :meth:`Session.optimize` on the default session."""
    sess, cfg = _effective(
        "optimize", alloc_policy=alloc_policy, order_policy=order_policy,
        hw=hw, gemm_kernel=gemm_kernel, weights_key=weights_key,
        autotune=autotune, sim_cfg=sim_cfg)
    p, _ = sess._plan(graph, cfg, cache=cache)
    exe, _ = sess._capture(graph, cfg, p, output_ids=output_ids, cache=cache)
    return exe


def cache_stats() -> dict[str, int]:
    """Hit/miss counters + entry counts of the DEFAULT session's caches."""
    return default_session().cache_stats()


def clear_caches() -> None:
    """Reset the DEFAULT session's memory tiers and counters."""
    default_session().clear_caches()
