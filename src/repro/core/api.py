"""User-facing Opara API.

    from repro.core import api as opara

    g = ...            # OpGraph emitted by a model (repro.models.*)
    exe = opara.optimize(g)          # full pipeline → single executable
    outs = exe({"tokens": x})

``optimize`` = Alg.1 streams + profile + Alg.2 order + wave fusion + capture,
i.e. the whole paper pipeline with one call, non-intrusively wrapping any
operator graph.
"""
from __future__ import annotations

from typing import Any, Mapping

from .capture import CapturedGraph
from .graph import OpGraph
from .profiler import HardwareSpec, V5E
from .scheduler import SchedulePlan, compile_plan, schedule


def plan(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    measured_inputs: Mapping[int, Any] | None = None,
) -> SchedulePlan:
    return schedule(graph, alloc_policy, order_policy, hw, measured_inputs=measured_inputs)


def optimize(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    output_ids=None,
) -> CapturedGraph:
    p = plan(graph, alloc_policy, order_policy, hw)
    return compile_plan(p, output_ids=output_ids)
