"""User-facing Opara API.

    from repro.core import api as opara

    g = ...            # OpGraph emitted by a model (repro.models.*)
    exe = opara.optimize(g)          # full pipeline → single executable
    outs = exe({"tokens": x})

``optimize`` = Alg.1 streams + profile + Alg.2 order + wave fusion + capture,
i.e. the whole paper pipeline with one call, non-intrusively wrapping any
operator graph.

Compiled-plan cache
-------------------
Scheduling is a pure function of graph *structure* (op kinds, edges, shapes,
dtypes, analytic costs) and the chosen policies — never of the weight
values.  ``plan()`` therefore memoizes :class:`SchedulePlan`s under a
structural :func:`graph_signature`; a second ``plan()``/``schedule()`` on an
architecturally-identical graph (e.g. every ``serving`` engine tick, or
rebuilding the same model) does zero re-profiling, re-allocation and
re-ordering.  On a hit for a *different* graph object the plan is rebound to
the caller's graph (op_ids are structural: same build order → same ids).

``optimize()`` adds a second cache level for the captured executable.  An
executable closes over payload callables and weights, so its key is the
plan signature PLUS an identity fingerprint of every node's ``fn`` and
``meta["consts"]`` arrays: same graph object (or same weight arrays) → the
IDENTICAL executable object, no re-lowering, no re-trace.  Cached entries
pin their graph alive, so ``id()`` fingerprints cannot collide with live
objects.

Invalidation: both caches are LRU-bounded (:data:`_CACHE_SIZE`); mutating a
graph via ``add()`` changes its signature (and its topology cache) so stale
hits are impossible.  ``clear_caches()`` resets everything (tests).
``measured_inputs`` plans are never cached — measured profiles depend on
input values.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Mapping

from .capture import CapturedGraph
from .graph import OpGraph
from .profiler import HardwareSpec, V5E
from .scheduler import SchedulePlan, compile_plan, schedule

_CACHE_SIZE = 64
_plan_cache: OrderedDict[tuple, SchedulePlan] = OrderedDict()
_exec_cache: OrderedDict[tuple, CapturedGraph] = OrderedDict()
_stats = {"plan_hits": 0, "plan_misses": 0, "exec_hits": 0, "exec_misses": 0}


def graph_signature(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    max_lanes: int | None = None,
) -> tuple:
    """Structural cache key: everything scheduling reads, nothing it doesn't.

    Per node: kind, edges, output shape/dtype, fusion signature, analytic
    cost fields, payload marker and const shapes (capture's stackability
    inputs) — see :meth:`OpGraph.node_signature`, which memoizes the node
    part per graph version.  Weight *values* and payload identities are
    deliberately excluded — they cannot change a schedule.
    """
    return (graph.node_signature(), alloc_policy, order_policy, hw, max_lanes)


def _weights_fingerprint(graph: OpGraph) -> tuple:
    """Identity of every payload + const array (executable cache key part)."""
    return tuple(
        (id(n.fn), tuple(id(c) for c in n.meta.get("consts", ())))
        for n in graph
    )


def _lru_get(cache: OrderedDict, key: tuple) -> Any | None:
    if key in cache:
        cache.move_to_end(key)
        return cache[key]
    return None


def _lru_put(cache: OrderedDict, key: tuple, value: Any) -> None:
    cache[key] = value
    cache.move_to_end(key)
    while len(cache) > _CACHE_SIZE:
        cache.popitem(last=False)


def plan(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    measured_inputs: Mapping[int, Any] | None = None,
    cache: bool = True,
) -> SchedulePlan:
    if measured_inputs is not None or not cache:
        return schedule(graph, alloc_policy, order_policy, hw,
                        measured_inputs=measured_inputs)
    key = graph_signature(graph, alloc_policy, order_policy, hw)
    hit = _lru_get(_plan_cache, key)
    if hit is not None:
        _stats["plan_hits"] += 1
        if hit.graph is graph:
            return hit
        # same structure, different graph object: rebind (op_ids match)
        return dataclasses.replace(hit, graph=graph)
    _stats["plan_misses"] += 1
    p = schedule(graph, alloc_policy, order_policy, hw)
    _lru_put(_plan_cache, key, p)
    return p


def optimize(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    output_ids=None,
    gemm_kernel: str = "auto",
    cache: bool = True,
) -> CapturedGraph:
    p = plan(graph, alloc_policy, order_policy, hw, cache=cache)
    if not cache:
        return compile_plan(p, output_ids=output_ids, gemm_kernel=gemm_kernel)
    key = (
        graph_signature(graph, alloc_policy, order_policy, hw),
        _weights_fingerprint(graph),
        tuple(output_ids) if output_ids is not None else None,
        gemm_kernel,
    )
    hit = _lru_get(_exec_cache, key)
    if hit is not None:
        _stats["exec_hits"] += 1
        return hit
    _stats["exec_misses"] += 1
    exe = compile_plan(p, output_ids=output_ids, gemm_kernel=gemm_kernel)
    _lru_put(_exec_cache, key, exe)
    return exe


def cache_stats() -> dict[str, int]:
    return dict(_stats, plan_entries=len(_plan_cache),
                exec_entries=len(_exec_cache))


def clear_caches() -> None:
    _plan_cache.clear()
    _exec_cache.clear()
    for k in _stats:
        _stats[k] = 0
