"""Graph Capturer (paper §3.4) — scheduled DAG → ONE jitted executable.

The CUDA-Graph analogue on TPU is AOT compilation: executing the whole wave
schedule inside a single ``jax.jit`` region removes per-op dispatch exactly
like replaying a captured graph removes kernel-launch overhead.

Execution semantics:
  * waves run in order;
  * within a wave, fusion groups of size > 1 are executed as ONE stacked op
    (``jnp.stack`` inputs → vmapped payload → unstack), which XLA lowers to a
    single batched GEMM — the horizontal-fusion realization of streams;
  * singleton groups run as-is; XLA still sees them inside one program and
    can interleave their DMA with neighbouring waves' compute (launch-order
    interleaving of memory/compute ops makes this overlap *available*).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from .fusion import WaveSchedule
from .graph import OpGraph


@dataclasses.dataclass
class CapturedGraph:
    """Executable artifact. Call with a dict {input_name: array}."""

    graph: OpGraph
    schedule: WaveSchedule
    input_ids: list[int]
    output_ids: list[int]
    fn: Callable[..., Any]           # python callable (uncompiled)
    jitted: Callable[..., Any]       # jit'd single-program executable

    def __call__(self, inputs: Mapping[str, Any]) -> list[Any]:
        args = self._bind(inputs)
        return self.jitted(*args)

    def call_uncompiled(self, inputs: Mapping[str, Any]) -> list[Any]:
        args = self._bind(inputs)
        return self.fn(*args)

    def _bind(self, inputs: Mapping[str, Any]) -> list[Any]:
        args = []
        for i in self.input_ids:
            name = self.graph.nodes[i].name
            if name not in inputs:
                raise KeyError(f"missing input {name!r}")
            args.append(inputs[name])
        return args


def _can_stack(graph: OpGraph, group: Sequence[int]) -> bool:
    """A group is stackable if all ops share fuse_sig, fn arity and
    per-branch constant shapes.

    Contract: branch-varying parameters (weights) must be declared in
    ``meta["consts"]`` — the capturer stacks them alongside the inputs and
    executes ONE vmapped payload (the fused kernel).  Ops whose closures
    hide differing state must leave ``fuse_sig=None``.
    """
    if len(group) < 2:
        return False
    first = graph.nodes[group[0]]
    if first.fn is None or first.fuse_sig is None:
        return False
    c0 = first.meta.get("consts", ())
    for g in group:
        n = graph.nodes[g]
        if n.fuse_sig != first.fuse_sig or n.fn is None:
            return False
        cg = n.meta.get("consts", ())
        if len(cg) != len(c0):
            return False
        if any(jnp.shape(a) != jnp.shape(b) for a, b in zip(cg, c0)):
            return False
    return True


def capture(
    graph: OpGraph,
    schedule: WaveSchedule,
    output_ids: Sequence[int] | None = None,
    donate_inputs: bool = False,
) -> CapturedGraph:
    """Build the single-program executable from a wave schedule."""
    graph.validate()
    input_ids = [n.op_id for n in graph if n.fn is None]
    if output_ids is None:
        output_ids = graph.leaves()
    output_ids = list(output_ids)

    # Pre-resolve execution program: list of steps; each step is either
    # ("single", op_id) or ("stacked", [op_ids]) — decided once at capture.
    program: list[tuple[str, Any]] = []
    for wave in schedule.waves:
        for group in wave.fusion_groups:
            if _can_stack(graph, group):
                program.append(("stacked", list(group)))
            else:
                for op in group:
                    if graph.nodes[op].fn is not None:
                        program.append(("single", op))

    def run(*args: Any) -> list[Any]:
        env: dict[int, Any] = dict(zip(input_ids, args))
        for tag, payload in program:
            if tag == "single":
                node = graph.nodes[payload]
                consts = node.meta.get("consts", ())
                env[payload] = node.fn(*[env[p] for p in node.inputs], *consts)
            else:
                ops = payload
                nodes = [graph.nodes[o] for o in ops]
                # stack each positional operand AND each per-branch constant
                arity = len(nodes[0].inputs)
                stacked = [
                    jnp.stack([env[n.inputs[a]] for n in nodes]) for a in range(arity)
                ]
                n_consts = len(nodes[0].meta.get("consts", ()))
                stacked += [
                    jnp.stack([jnp.asarray(n.meta["consts"][c]) for n in nodes])
                    for c in range(n_consts)
                ]
                fn0 = nodes[0].fn
                outs = jax.vmap(fn0)(*stacked)
                for k, o in enumerate(ops):
                    env[o] = jax.tree_util.tree_map(lambda x: x[k], outs)
        return [env[o] for o in output_ids]

    jit_kwargs: dict[str, Any] = {}
    if donate_inputs:
        jit_kwargs["donate_argnums"] = tuple(range(len(input_ids)))
    return CapturedGraph(
        graph=graph,
        schedule=schedule,
        input_ids=input_ids,
        output_ids=output_ids,
        fn=run,
        jitted=jax.jit(run, **jit_kwargs),
    )


def run_sequential_uncompiled(graph: OpGraph, inputs: Mapping[str, Any]) -> list[Any]:
    """Eager per-op execution in topo order — the "stock PyTorch" baseline:
    every op is dispatched separately from Python (launch overhead included).
    """
    env: dict[int, Any] = {}
    for i in graph.topological_order():
        node = graph.nodes[i]
        if node.fn is None:
            env[i] = inputs[node.name]
        else:
            consts = node.meta.get("consts", ())
            env[i] = jax.block_until_ready(
                node.fn(*[env[p] for p in node.inputs], *consts))
    return [env[o] for o in graph.leaves()]
