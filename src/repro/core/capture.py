"""Graph Capturer (paper §3.4) — scheduled DAG → ONE jitted executable.

The CUDA-Graph analogue on TPU is AOT compilation: executing the whole wave
schedule inside a single ``jax.jit`` region removes per-op dispatch exactly
like replaying a captured graph removes kernel-launch overhead.

Two-phase **program compiler** (the Nimble insight — move every scheduling
decision ahead of time so the replay path does zero per-op work):

Phase 1, ``_lower`` (capture time, runs once per plan):
  * every wave is resolved into a flat list of :class:`Step`s — either one
    payload call or one fused stacked call;
  * per-branch constants (weights) of stacked groups are stacked **once**
    into device arrays held *outside* the trace, so re-tracing never
    re-stacks and the jaxpr sees them as hoisted constants;
  * GEMM-kind fusion groups whose payloads declare ``meta["payload"] ==
    "matmul"`` are routed to the ``branch_gemm`` Pallas kernel (interpret
    mode on CPU, MXU tiles on TPU) with a ``vmap`` fallback for
    non-tileable shapes or oversized interpret-mode grids;
  * matmul groups whose branches share ``(K, F)`` but differ in row count
    (the MoE expert fan-out with unequal routed token counts) cannot be
    ``jnp.stack``-ed — they lower to ONE ``grouped_gemm`` step instead:
    branch inputs are concatenated with a capture-time offset table and the
    ragged Pallas kernel walks a tile→group map (ref fallback inside the
    wrapper keeps it a single fused op on non-tileable shapes);
  * each op gets a slot in a flat list environment and each slot a
    precomputed last-use step, so intermediates are dropped as soon as
    they are dead (list indexing replaces dict hashing in the hot loop).

Phase 2, ``run`` (trace/replay): walks the pre-lowered step list — no
grouping decisions, no const re-stacking, no dict lookups.

Execution semantics are unchanged from the wave model:
  * waves run in order;
  * within a wave, fusion groups of size > 1 execute as ONE stacked op
    (batched GEMM / vmapped payload / ragged grouped GEMM) — the
    horizontal-fusion realization of streams;
  * singleton groups run as-is; XLA still sees them inside one program and
    can interleave their DMA with neighbouring waves' compute.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from ..kernels import INTERPRET_GRID_LIMIT as _INTERPRET_GRID_LIMIT
from ..runtime.faults import FaultPlan, get_active as _active_faults
from ..runtime.guard import DegradationLog
from .fusion import WaveSchedule
from .graph import OpGraph

# Routing targets for a lowered step.
_CALL = "call"                  # single payload call
_VMAP = "vmap"                  # stacked group via vmapped payload
_BRANCH_GEMM = "branch_gemm"    # stacked group via the Pallas fused GEMM
_GROUPED_GEMM = "grouped_gemm"  # ragged-M group via the grouped Pallas GEMM

# _INTERPRET_GRID_LIMIT (imported above): in interpret mode (CPU) a Pallas
# grid is unrolled at trace time; beyond that many grid points the vmap
# fallback compiles and runs faster.  ONE constant shared with the kernel
# wrappers so their internal ref fallbacks agree with the route decision.


@dataclasses.dataclass
class Step:
    """One pre-lowered execution step (all decisions made at capture time)."""

    route: str                          # _CALL | _VMAP | _BRANCH_GEMM |
                                        # _GROUPED_GEMM
    fn: Callable[..., Any] | None       # payload (vmapped for _VMAP)
    arg_slots: tuple                    # _CALL: (slot, ...) positional args
                                        # stacked: per-arg tuple of branch slots
    consts: tuple                       # hoisted constants (stacked: device
                                        # arrays stacked ONCE at capture time)
    out_slots: tuple[int, ...]          # one slot per branch (singles: one)
    free_slots: tuple[int, ...]         # slots dead after this step
    op_ids: tuple[int, ...]             # provenance (tests / debugging)
    group_sizes: tuple[int, ...] = ()   # _GROUPED_GEMM: per-branch row counts
                                        # (the capture-time offset table)


@dataclasses.dataclass
class CapturedGraph:
    """Executable artifact. Call with a dict {input_name: array}."""

    graph: OpGraph
    schedule: WaveSchedule
    input_ids: list[int]
    output_ids: list[int]
    fn: Callable[..., Any]           # python callable (uncompiled)
    jitted: Callable[..., Any]       # jit'd single-program executable
    steps: list[Step] = dataclasses.field(default_factory=list)
    # input names in input_ids order, precomputed at capture time so the
    # replay path does no per-call graph walks
    input_names: tuple[str, ...] = ()
    # capture-time route fallbacks (branch_gemm→vmap, grouped→sequential)
    # plus any call-time jitted→sequential rescue — read by
    # Session.cache_stats()["degraded_routes"] and CompiledModel.explain()
    degradations: DegradationLog = dataclasses.field(
        default_factory=DegradationLog)

    def __post_init__(self) -> None:
        if not self.input_names:
            self.input_names = tuple(
                self.graph.nodes[i].name for i in self.input_ids)

    def __call__(self, inputs: Mapping[str, Any]) -> list[Any]:
        args = self._bind(inputs)
        try:
            return self.jitted(*args)
        except Exception as exc:
            # bottom rung of the ladder: the compiled program failed to
            # trace/launch — replay per-op in topo order (the differential
            # harness's own ground truth).  If that fails too, the original
            # error was real: surface it, not the fallback's.
            try:
                outs = run_sequential_uncompiled(self.graph, inputs,
                                                 self.output_ids)
            except Exception:
                raise exc
            self.degradations.note("execute", "jitted->sequential",
                                   repr(exc), warn=True)
            return outs

    def call_uncompiled(self, inputs: Mapping[str, Any]) -> list[Any]:
        args = self._bind(inputs)
        return self.fn(*args)

    def _bind(self, inputs: Mapping[str, Any]) -> list[Any]:
        args = []
        for name in self.input_names:
            if name not in inputs:
                raise KeyError(f"missing input {name!r}")
            args.append(inputs[name])
        if len(inputs) != len(self.input_names):
            # a typo'd name would otherwise pass silently whenever the real
            # input happens to be bound too — fail loudly instead
            unknown = sorted(set(inputs) - set(self.input_names))
            if unknown:
                raise KeyError(
                    f"unrecognized input name(s) {unknown}; expected "
                    f"{sorted(self.input_names)}")
        return args

    def program_stats(self) -> dict[str, float]:
        routes = [s.route for s in self.steps]
        return {
            "n_steps": float(len(self.steps)),
            "n_single": float(routes.count(_CALL)),
            "n_vmap": float(routes.count(_VMAP)),
            "n_branch_gemm": float(routes.count(_BRANCH_GEMM)),
            "n_grouped_gemm": float(routes.count(_GROUPED_GEMM)),
        }


def _branch_input_shapes(
    graph: OpGraph, group: Sequence[int], arg: int = 0,
) -> list[tuple[int, ...] | None]:
    """Declared ``out_shape`` of each branch's ``arg``-th input producer
    (``None`` where the builder did not declare one)."""
    return [graph.nodes[graph.nodes[g].inputs[arg]].out_shape for g in group]


def _uniform_group(graph: OpGraph, group: Sequence[int]) -> bool:
    """Shared eligibility core for BOTH fused routes (stacked and grouped):
    every op has a payload, the same fuse_sig and arity, and per-branch
    constants of identical shapes AND dtypes (``jnp.stack`` over mixed
    dtypes would silently promote, so the fused group would return a
    different dtype than unfused execution)."""
    if len(group) < 2:
        return False
    first = graph.nodes[group[0]]
    if first.fn is None or first.fuse_sig is None:
        return False
    c0 = first.meta.get("consts", ())
    arity0 = len(first.inputs)
    for g in group:
        n = graph.nodes[g]
        if n.fuse_sig != first.fuse_sig or n.fn is None:
            return False
        if len(n.inputs) != arity0:
            return False
        cg = n.meta.get("consts", ())
        if len(cg) != len(c0):
            return False
        if any(jnp.shape(a) != jnp.shape(b) for a, b in zip(cg, c0)):
            return False
        if any(jnp.result_type(a) != jnp.result_type(b)
               for a, b in zip(cg, c0)):
            return False
    return True


def _stack_consts(graph: OpGraph, group: Sequence[int]) -> tuple:
    """Const hoisting: per-branch constants stacked ONCE at capture time,
    outside the trace — jax.jit sees ready-made device constants."""
    nodes = [graph.nodes[o] for o in group]
    n_consts = len(nodes[0].meta.get("consts", ()))
    return tuple(
        jnp.stack([jnp.asarray(n.meta["consts"][c]) for n in nodes])
        for c in range(n_consts))


def _can_stack(graph: OpGraph, group: Sequence[int]) -> bool:
    """A group is stackable if it is uniform (:func:`_uniform_group`) and
    no two branches *declare* different input shapes (``jnp.stack`` at run
    time needs equal shapes; ragged matmul groups take the grouped route
    instead).

    Contract: branch-varying parameters (weights) must be declared in
    ``meta["consts"]`` — the capturer stacks them alongside the inputs and
    executes ONE fused payload.  Ops whose closures hide differing state
    must leave ``fuse_sig=None``.
    """
    if not _uniform_group(graph, group):
        return False
    for a in range(len(graph.nodes[group[0]].inputs)):
        known = {s for s in _branch_input_shapes(graph, group, a)
                 if s is not None}
        if len(known) > 1:
            return False
    return True


def _gemm_routable(graph: OpGraph, group: Sequence[int]) -> bool:
    """True iff the stacked group can go to the fused branch-GEMM kernel.

    Contract (explicit opt-in, no payload guessing): every node declares
    ``meta["payload"] == "matmul"`` — payload semantics are exactly
    ``x @ w (+ b)`` with ``consts == (w,)`` or ``(w, b)``, ``w.ndim == 2``.
    """
    for g in group:
        n = graph.nodes[g]
        if n.meta.get("payload") != "matmul" or len(n.inputs) != 1:
            return False
        consts = n.meta.get("consts", ())
        if len(consts) not in (1, 2):
            return False
        if jnp.ndim(consts[0]) != 2:
            return False
        if len(consts) == 2 and jnp.ndim(consts[1]) != 1:
            return False
    return True


def _ragged_group_sizes(
    graph: OpGraph, group: Sequence[int],
) -> tuple[int, ...] | None:
    """Per-branch row counts for the grouped ragged-M GEMM route, or
    ``None`` when the group does not qualify.

    Qualifying groups are matmul-marked (``_gemm_routable``) with uniform
    const shapes/dtypes, whose branch inputs all *declare* 2-D
    ``[M_i, K]`` shapes sharing K but differing in at least one M — the
    unequal-token MoE expert fan-out.  Equal-M groups stay on the stacked
    path (``_can_stack``), which is strictly cheaper.
    """
    if not (_gemm_routable(graph, group) and _uniform_group(graph, group)):
        return None
    shapes = _branch_input_shapes(graph, group)
    if any(s is None or len(s) != 2 for s in shapes):
        return None
    k = jnp.shape(graph.nodes[group[0]].meta["consts"][0])[0]
    if any(s[1] != k for s in shapes):
        return None
    sizes = tuple(int(s[0]) for s in shapes)
    if len(set(sizes)) < 2:
        return None   # uniform M: the stacked path handles it
    # mixed input dtypes would promote under jnp.concatenate
    dtypes = {graph.nodes[graph.nodes[g].inputs[0]].out_dtype
              for g in group}
    dtypes.discard(None)
    if len(dtypes) > 1:
        return None
    return sizes


def _pick_gemm_route(w: jax.Array, n_branches: int, gemm_kernel: str,
                     m: int | None = None) -> str:
    """Decide Pallas vs vmap for an eligible GEMM group (capture time).

    The interpret-mode grid estimate runs the SAME tile selection as the
    ``branch_gemm`` wrapper (``select_tiles``), so the decision counts the
    grid the kernel would actually launch — including the M dimension when
    the branch input shape is declared.  ``m=None`` (undeclared shape)
    counts a single row tile, matching the legacy M-blind estimate — an
    optimistic floor, so builders that want the exact decision should
    declare ``out_shape`` on branch inputs.  Non-tileable shapes go to the
    kernel wrapper's einsum-ref fallback, which is one fused op with no
    unrolled grid.
    """
    if gemm_kernel == "vmap":
        return _VMAP
    if gemm_kernel == "pallas":
        return _BRANCH_GEMM
    # "auto": on TPU always take the fused kernel; on CPU (interpret mode)
    # only when the unrolled grid stays small.
    from ..kernels import interpret_mode
    from ..kernels.branch_gemm.ops import select_tiles

    if not interpret_mode():
        return _BRANCH_GEMM
    k, f = w.shape
    tiles = select_tiles(m if m is not None else 8, k, f)
    if tiles is None:
        return _BRANCH_GEMM   # einsum-ref fallback: fused, no grid
    bm, bf, bk = tiles
    m_tiles = (m // bm) if m is not None else 1
    grid_points = n_branches * m_tiles * (f // bf) * (k // bk)
    return _BRANCH_GEMM if grid_points <= _INTERPRET_GRID_LIMIT else _VMAP


def _branch_gemm_step() -> Callable[..., Any]:
    """Build the fused-GEMM callable for one stacked group.

    The executor calls it ``fn(x_stacked, *step.consts)`` — the pre-stacked
    weights ``w: [N, K, F]`` (and optionally bias ``b: [N, F]``) flow in
    through ``Step.consts``.  The input arrives stacked ``x: [N, *batch,
    K]``; batch dims are flattened for the kernel's [N, M, K] @ [N, K, F]
    contract and restored after.
    """
    def fused(x: jax.Array, w: jax.Array, *rest: jax.Array) -> jax.Array:
        from ..kernels.branch_gemm.ops import branch_gemm

        n, k, f = w.shape[0], w.shape[1], w.shape[2]
        batch_shape = x.shape[1:-1]
        out = branch_gemm(x.reshape(n, -1, k), w)
        out = out.reshape((n,) + batch_shape + (f,))
        if rest:  # bias [N, F] broadcast over batch dims
            b = rest[0]
            out = out + b.reshape((n,) + (1,) * len(batch_shape) + (f,))
        return out

    return fused


def _grouped_gemm_step(group_sizes: tuple[int, ...]) -> Callable[..., Any]:
    """Build the ragged fused-GEMM callable for one grouped step.

    The executor calls it ``fn([x_0, ..., x_{N-1}], *step.consts)`` with
    the per-branch 2-D inputs UNstacked (their row counts differ); the fn
    hands the parts straight to the grouped kernel wrapper — which pads
    each to the row tile and concatenates ONCE — and gets one output per
    branch back.  ``group_sizes`` is the capture-time offset table the
    trace-time shapes must honor.
    """
    def fused(xs: Sequence[jax.Array], w: jax.Array,
              *rest: jax.Array) -> list[jax.Array]:
        from ..kernels.grouped_gemm.ops import grouped_gemm_parts

        for x, m in zip(xs, group_sizes):
            assert x.shape[0] == m, (
                f"branch rows {x.shape[0]} != captured size {m}")
        outs = grouped_gemm_parts(list(xs), w)
        if rest:  # per-branch bias [N, F]
            b = rest[0]
            outs = [o + b[i] for i, o in enumerate(outs)]
        return outs

    return fused


def _validate_waves(graph: OpGraph, schedule: WaveSchedule) -> None:
    """The capturer's input contract, packer-agnostic: waves must partition
    the graph and every producer must sit in a strictly earlier wave.  Both
    :func:`repro.core.fusion.build_waves` and ``repack_waves`` guarantee
    this; the check catches hand-built or corrupted schedules before they
    lower into a program that reads uninitialized slots."""
    wave_of: dict[int, int] = {}
    for w in schedule.waves:
        for op in w.op_ids:
            if op in wave_of:
                raise ValueError(f"op {op} appears in waves {wave_of[op]} "
                                 f"and {w.index}")
            wave_of[op] = w.index
    if set(wave_of) != set(graph.nodes):
        missing = set(graph.nodes) - set(wave_of)
        raise ValueError(f"wave schedule does not cover ops {sorted(missing)[:5]}")
    for node in graph:
        for p in node.inputs:
            if wave_of[p] >= wave_of[node.op_id]:
                raise ValueError(
                    f"dependency {p}->{node.op_id} not satisfied: producer in "
                    f"wave {wave_of[p]}, consumer in wave {wave_of[node.op_id]}")


def _single_steps(graph: OpGraph, group: Sequence[int],
                  slot_of: dict[int, int]) -> list[Step]:
    """Per-op call steps — the fallback floor every fused route degrades to
    (semantically identical to unfused execution by construction)."""
    out: list[Step] = []
    for op in group:
        node = graph.nodes[op]
        if node.fn is None:
            continue
        out.append(Step(
            route=_CALL, fn=node.fn,
            arg_slots=tuple(slot_of[p] for p in node.inputs),
            consts=tuple(node.meta.get("consts", ())),
            out_slots=(slot_of[op],), free_slots=(),
            op_ids=(op,)))
    return out


def _lower_group(
    graph: OpGraph,
    group: Sequence[int],
    slot_of: dict[int, int],
    gemm_kernel: str,
    faults: FaultPlan | None,
    log: DegradationLog,
) -> list[Step]:
    """Lower one fusion group down the route ladder:
    grouped_gemm → branch_gemm → vmap → per-op sequential.

    Injected faults (sites ``kernel_compile`` / ``grouped_gemm_route``) and
    REAL construction failures (a const that won't stack, a kernel that
    won't build) take the same recovery edge: the next-slower route that
    computes the identical function, recorded in ``log``."""
    if _can_stack(graph, group):
        nodes = [graph.nodes[o] for o in group]
        arity = len(nodes[0].inputs)
        arg_slots = tuple(
            tuple(slot_of[n.inputs[a]] for n in nodes)
            for a in range(arity)
        )
        try:
            consts = _stack_consts(graph, group)
        except Exception as exc:
            log.note("kernel_compile", "stacked->sequential", repr(exc),
                     warn=True)
            return _single_steps(graph, group, slot_of)
        if _gemm_routable(graph, group):
            # _can_stack guarantees all declared shapes agree — use
            # the first declared one (any branch may omit it)
            shape = next((s for s in
                          _branch_input_shapes(graph, group)
                          if s is not None), None)
            m = (int(math.prod(shape[:-1]))
                 if shape is not None else None)
            route = _pick_gemm_route(
                nodes[0].meta["consts"][0], len(group), gemm_kernel,
                m=m)
            if route == _BRANCH_GEMM and faults is not None:
                try:
                    faults.fire("kernel_compile")
                except Exception as exc:
                    log.note("kernel_compile", "branch_gemm->vmap",
                             repr(exc))
                    route = _VMAP
        else:
            route = _VMAP
        try:
            fn = (_branch_gemm_step() if route == _BRANCH_GEMM
                  else jax.vmap(nodes[0].fn))
        except Exception as exc:
            log.note("kernel_compile", f"{route}->sequential", repr(exc),
                     warn=True)
            return _single_steps(graph, group, slot_of)
        return [Step(
            route=route, fn=fn, arg_slots=arg_slots, consts=consts,
            out_slots=tuple(slot_of[o] for o in group),
            free_slots=(), op_ids=tuple(group))]
    if (gemm_kernel != "vmap"
            and (ragged := _ragged_group_sizes(graph, group)) is not None):
        # ragged-M matmul group: ONE grouped kernel instead of N
        # serialized branches (jnp.stack is impossible here)
        if faults is not None:
            try:
                faults.fire("grouped_gemm_route")
            except Exception as exc:
                log.note("grouped_gemm_route", "grouped_gemm->sequential",
                         repr(exc))
                return _single_steps(graph, group, slot_of)
        nodes = [graph.nodes[o] for o in group]
        try:
            consts = _stack_consts(graph, group)
        except Exception as exc:
            log.note("grouped_gemm_route", "grouped_gemm->sequential",
                     repr(exc), warn=True)
            return _single_steps(graph, group, slot_of)
        return [Step(
            route=_GROUPED_GEMM, fn=_grouped_gemm_step(ragged),
            arg_slots=(tuple(slot_of[n.inputs[0]] for n in nodes),),
            consts=consts,
            out_slots=tuple(slot_of[o] for o in group),
            free_slots=(), op_ids=tuple(group),
            group_sizes=ragged)]
    return _single_steps(graph, group, slot_of)


def _lower(
    graph: OpGraph,
    schedule: WaveSchedule,
    output_ids: Sequence[int],
    gemm_kernel: str = "auto",
    faults: FaultPlan | None = None,
    log: DegradationLog | None = None,
) -> tuple[list[Step], dict[int, int], int, DegradationLog]:
    """Phase 1: wave schedule → pre-lowered step list + slot assignment."""
    slot_of = {op: k for k, op in enumerate(graph.nodes)}
    n_slots = len(slot_of)
    log = log if log is not None else DegradationLog()

    steps: list[Step] = []
    for wave in schedule.waves:
        for group in wave.fusion_groups:
            steps.extend(
                _lower_group(graph, group, slot_of, gemm_kernel, faults, log))

    # dead-slot analysis: a slot is freed right after its last consuming
    # step — or, for outputs nothing ever consumes (and which aren't program
    # outputs), right after its producing step — unless it backs an output.
    keep = {slot_of[o] for o in output_ids}
    last_use: dict[int, int] = {}
    for k, step in enumerate(steps):
        consumed = (step.arg_slots if step.route == _CALL
                    else [s for slots in step.arg_slots for s in slots])
        for s in consumed:
            last_use[s] = k
    free_at: dict[int, list[int]] = {}
    for s, last in last_use.items():
        if s not in keep:
            free_at.setdefault(last, []).append(s)
    for k, step in enumerate(steps):
        dead = [s for s in free_at.get(k, ()) if s not in step.out_slots]
        # unconsumed non-output results die the moment they are produced
        dead += [s for s in step.out_slots
                 if s not in keep and s not in last_use]
        step.free_slots = tuple(dead)
    return steps, slot_of, n_slots, log


def capture(
    graph: OpGraph,
    schedule: WaveSchedule,
    output_ids: Sequence[int] | None = None,
    donate_inputs: bool = False,
    gemm_kernel: str = "auto",
    faults: FaultPlan | None = None,
) -> CapturedGraph:
    """Build the single-program executable from a wave schedule.

    ``gemm_kernel`` routes eligible stacked GEMM groups: ``"auto"`` (Pallas
    on TPU / small interpret grids, vmap otherwise), ``"pallas"`` (always
    the fused kernel, einsum-ref fallback for non-tileable shapes) or
    ``"vmap"`` (always the generic stacked payload).  Ragged-M matmul
    groups take the grouped kernel under ``"auto"``/``"pallas"`` and fall
    back to per-branch calls under ``"vmap"`` (a ragged group cannot be
    vmapped).

    ``faults`` (default: the process-wide plan, if any) arms the
    ``plan_validate`` / ``kernel_compile`` / ``grouped_gemm_route``
    injection sites.  Route-level recovery happens here (see
    :func:`_lower_group`, recorded on ``CapturedGraph.degradations``);
    a ``plan_validate`` failure raises out — :class:`repro.core.Session`
    owns that rung (re-schedule sequential).
    """
    if gemm_kernel not in ("auto", "pallas", "vmap"):
        raise ValueError(f"unknown gemm_kernel {gemm_kernel!r}")
    if faults is None:
        faults = _active_faults()
    if faults is not None:
        # models a corrupted/stale plan arriving at the capturer: the same
        # ValueError surface _validate_waves raises for real corruption
        faults.fire("plan_validate")
    graph.validate()
    _validate_waves(graph, schedule)
    input_ids = [n.op_id for n in graph if n.fn is None]
    if output_ids is None:
        output_ids = graph.leaves()
    output_ids = list(output_ids)

    steps, slot_of, n_slots, deg_log = _lower(
        graph, schedule, output_ids, gemm_kernel, faults=faults)
    input_slots = [slot_of[i] for i in input_ids]
    output_slots = [slot_of[o] for o in output_ids]
    tree_map = jax.tree_util.tree_map

    def run(*args: Any) -> list[Any]:
        env: list[Any] = [None] * n_slots
        for s, a in zip(input_slots, args):
            env[s] = a
        for step in steps:
            if step.route == _CALL:
                out = step.fn(*[env[s] for s in step.arg_slots], *step.consts)
                env[step.out_slots[0]] = out
            elif step.route == _GROUPED_GEMM:
                outs = step.fn([env[s] for s in step.arg_slots[0]],
                               *step.consts)
                for k, slot in enumerate(step.out_slots):
                    env[slot] = outs[k]
            else:
                stacked = [jnp.stack([env[s] for s in slots])
                           for slots in step.arg_slots]
                outs = step.fn(*stacked, *step.consts)
                for k, slot in enumerate(step.out_slots):
                    env[slot] = tree_map(lambda x: x[k], outs)
            for s in step.free_slots:
                env[s] = None
        return [env[s] for s in output_slots]

    jit_kwargs: dict[str, Any] = {}
    if donate_inputs:
        jit_kwargs["donate_argnums"] = tuple(range(len(input_ids)))
    return CapturedGraph(
        graph=graph,
        schedule=schedule,
        input_ids=input_ids,
        output_ids=output_ids,
        fn=run,
        jitted=jax.jit(run, **jit_kwargs),
        steps=steps,
        degradations=deg_log,
    )


def run_sequential_uncompiled(
    graph: OpGraph,
    inputs: Mapping[str, Any],
    output_ids: Sequence[int] | None = None,
) -> list[Any]:
    """Eager per-op execution in topo order — the "stock PyTorch" baseline:
    every op is dispatched separately from Python (launch overhead included).

    ``output_ids`` selects which ops' results are returned (default: the
    graph's leaves) — pass a :class:`CapturedGraph`'s ``output_ids`` so a
    differential comparison reads the SAME outputs the compiled program
    returns instead of silently re-deriving them.
    """
    env: dict[int, Any] = {}
    for i in graph.topological_order():
        node = graph.nodes[i]
        if node.fn is None:
            env[i] = inputs[node.name]
        else:
            consts = node.meta.get("consts", ())
            env[i] = jax.block_until_ready(
                node.fn(*[env[p] for p in node.inputs], *consts))
    if output_ids is None:
        output_ids = graph.leaves()
    return [env[o] for o in output_ids]
