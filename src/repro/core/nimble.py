"""Nimble baseline [Kwon et al., NeurIPS'20] — stream assignment via
minimum-path-cover / bipartite maximum matching.

The paper (§5, Table 1) compares against Nimble, which "transforms the
computation graph into a bipartite graph and then identifies its maximum
matching to determine an appropriate stream for each operator".  A minimum
path cover of the DAG (streams = vertex-disjoint paths) equals
|V| − |maximum matching| on the bipartite split graph (König/Dilworth).
Nimble applies this to the *transitive reduction*; combined with matching on
the (transitively closed) graph the cost is O(n^3) — which is exactly the
complexity gap Table 1 measures against Opara's O(n) Alg. 1.

We implement Hopcroft–Karp on the closure for fidelity to Nimble's claimed
behaviour (fewer streams, i.e. minimum lanes) and to reproduce Table 1's
runtime gap.
"""
from __future__ import annotations

from collections import deque

from .graph import OpGraph
from .stream_alloc import StreamPlan

_INF = float("inf")


def _transitive_closure(graph: OpGraph) -> dict[int, set[int]]:
    """Reachability sets via reverse-topological DP (O(V·E) bitset-ish)."""
    succ = graph.unique_successors_map()
    order = graph.topological_order()
    reach: dict[int, set[int]] = {}
    for i in reversed(order):
        r: set[int] = set()
        for s in succ[i]:
            r.add(s)
            r |= reach[s]
        reach[i] = r
    return reach


def _hopcroft_karp(adj: dict[int, list[int]], left: list[int]) -> dict[int, int]:
    """Maximum bipartite matching; returns match_left: u -> v."""
    match_l: dict[int, int | None] = {u: None for u in left}
    match_r: dict[int, int | None] = {}

    def bfs() -> bool:
        dist: dict[int, float] = {}
        q: deque[int] = deque()
        for u in left:
            if match_l[u] is None:
                dist[u] = 0
                q.append(u)
            else:
                dist[u] = _INF
        found = False
        while q:
            u = q.popleft()
            for v in adj[u]:
                w = match_r.get(v)
                if w is None:
                    found = True
                elif dist.get(w, _INF) is _INF:
                    dist[w] = dist[u] + 1
                    q.append(w)
        bfs.dist = dist  # type: ignore[attr-defined]
        return found

    def dfs(u: int) -> bool:
        dist = bfs.dist  # type: ignore[attr-defined]
        for v in adj[u]:
            w = match_r.get(v)
            if w is None or (dist.get(w, _INF) == dist[u] + 1 and dfs(w)):
                match_l[u] = v
                match_r[v] = u
                return True
        dist[u] = _INF
        return False

    while bfs():
        for u in left:
            if match_l[u] is None:
                dfs(u)
    return {u: v for u, v in match_l.items() if v is not None}


def allocate_streams_nimble(graph: OpGraph, use_closure: bool = True) -> StreamPlan:
    """Minimum path cover stream assignment (Nimble's scheme).

    With ``use_closure=True`` paths may "jump over" intermediate nodes
    (Dilworth chains — minimum number of streams = max antichain); this is
    the O(n^3)-ish variant whose cost Table 1 reports.
    """
    ids = list(graph.nodes)
    if use_closure:
        reach = _transitive_closure(graph)
        adj = {u: sorted(reach[u]) for u in ids}
    else:
        succ = graph.unique_successors_map()
        adj = {u: sorted(succ[u]) for u in ids}

    match = _hopcroft_karp(adj, ids)

    # chains: follow matched edges from unmatched-on-the-right starts
    matched_right = set(match.values())
    stream_of: dict[int, int] = {}
    n_streams = 0
    for u in sorted(ids):
        if u in matched_right:
            continue  # not a chain head
        s = n_streams
        n_streams += 1
        cur: int | None = u
        while cur is not None:
            stream_of[cur] = s
            cur = match.get(cur)
    # isolated safety: anything missed gets its own stream
    for u in ids:
        if u not in stream_of:
            stream_of[u] = n_streams
            n_streams += 1
    return StreamPlan(stream_of=stream_of, n_streams=n_streams)
