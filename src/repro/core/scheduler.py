"""End-to-end Opara pipeline (paper Fig. 4) plus the autotune loop.

DNN model + inputs → Stream Allocator → Model Profiler → Operator Launcher
→ Wave (Re)packer → Graph Capturer → parallelized executable.

``schedule()`` is the single-policy entry point; :func:`autotune` closes the
loop on predicted makespan: it evaluates the cross-product of
{alloc policies} × {order policies} × {repack on/off} against the
simulator's fast cost model (:func:`repro.core.simulator.estimate_makespan`)
and returns the min-makespan plan — the IOS insight (cost-model-guided
inter-operator schedule search) kept off the inference critical path the
Nimble way, by hiding the search behind the per-session plan cache
(:class:`repro.core.Session`).

Every stage is swappable so benchmarks can mix and match (e.g. Nimble
streams + topo order = the Nimble baseline; one stream + topo order =
sequential CUDA Graph baseline).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Iterable, Mapping

from .capture import CapturedGraph, capture
from .fusion import (
    WaveEditor,
    WaveSchedule,
    build_waves,
    fusion_stats,
    regroup_waves,
    repack_waves,
)
from .graph import OpGraph
from .launch_order import ORDER_POLICIES, validate_order
from .nimble import allocate_streams_nimble
from .profiler import HardwareSpec, ModelProfiler, OpProfile, V5E, apply_profile
from .simulator import (
    SimConfig,
    SimResult,
    SweepState,
    _sweep,
    estimate_makespan,
    op_tables,
    sequential_makespan,
    simulate,
    sweep_extend,
)
from .stream_alloc import StreamPlan, allocate_streams, count_syncs


@dataclasses.dataclass
class SchedulePlan:
    """Everything the capturer / simulator needs, plus bookkeeping."""

    graph: OpGraph
    stream_plan: StreamPlan
    order: list[int]
    waves: WaveSchedule
    profiles: dict[int, OpProfile]
    alloc_policy: str
    order_policy: str
    alloc_time_ms: float
    order_time_ms: float
    # -- per-stage timing hooks (CompiledModel.explain() reads these) -------
    profile_time_ms: float = 0.0            # profiler stage (stage 2)
    wave_time_ms: float = 0.0               # wave build / repack (stage 4)
    # -- autotune / repack bookkeeping --------------------------------------
    repacked: bool = False                  # waves came from repack_waves
    sim_cfg: SimConfig | None = None        # cost-model config used, if any
    est_makespan_us: float | None = None    # winning candidate's estimate
    autotune_ms: float = 0.0                # search wall time (0 = no search)
    n_candidates: int = 1                   # schedules evaluated
    # -- iterative refinement provenance (:func:`refine`) -------------------
    refined: bool = False                   # refinement improved the plan
    refine_ms: float = 0.0                  # refinement wall time
    refine_iters: int = 0                   # accepted moves
    refine_delta_us: float = 0.0            # est improvement over the seed

    @property
    def n_streams(self) -> int:
        return self.stream_plan.n_streams

    def stats(self) -> dict[str, float]:
        cap = (self.sim_cfg or SimConfig()).resource_cap
        s = fusion_stats(self.waves, self.profiles, resource_cap=cap)
        s.update(
            n_streams=float(self.n_streams),
            n_syncs=float(count_syncs(self.graph, self.stream_plan)),
            alloc_time_ms=self.alloc_time_ms,
            order_time_ms=self.order_time_ms,
            profile_time_ms=self.profile_time_ms,
            wave_time_ms=self.wave_time_ms,
            repacked=float(self.repacked),
            autotune_ms=self.autotune_ms,
            n_candidates=float(self.n_candidates),
            refined=float(self.refined),
            refine_ms=self.refine_ms,
            refine_iters=float(self.refine_iters),
            refine_delta_us=self.refine_delta_us,
        )
        if self.est_makespan_us is not None:
            s["est_makespan_us"] = self.est_makespan_us
        return s


ALLOC_POLICIES = {
    "opara": allocate_streams,
    "nimble": allocate_streams_nimble,
    "sequential": lambda g: StreamPlan(stream_of={i: 0 for i in g.nodes}, n_streams=1),
}

# Default autotune search space.  Above the op limit the cold-path budget
# (autotune ≤ ~2× a single-policy schedule) trims the space: Nimble's
# min-path-cover allocator is O(n³), and the order list drops to the two
# strongest candidates (the caller can always pass a wider space).
AUTOTUNE_ORDER_POLICIES = ("opara", "topo", "critical_path")
AUTOTUNE_ORDER_POLICIES_LARGE = ("opara", "topo")
NIMBLE_ALLOC_OP_LIMIT = 512


def schedule(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    max_lanes: int | None = None,
    measured_inputs: Mapping[int, Any] | None = None,
    repack: bool = False,
    sim_cfg: SimConfig | None = None,
) -> SchedulePlan:
    """Run the full scheduling pipeline (no compilation).

    ``measured_inputs`` forces a fresh profiling inference (measure + hydrate
    via the profiler's apply lifecycle).  This path always re-times — use
    :meth:`repro.core.Session.plan`, which consults the calibration cache
    first, when "profile once" amortization is wanted.

    ``repack=True`` swaps the launch-order wave bucketing for the resource-
    and interference-aware repacker (:func:`repro.core.fusion.repack_waves`)
    under ``sim_cfg``'s resource cap; the launch order is then re-linearized
    wave-major so the dispatch sequence matches what was packed.
    """
    graph.validate()
    profiler = ModelProfiler(hw)
    if measured_inputs is not None:
        apply_profile(graph, profiler.measure(graph, measured_inputs))
    t0 = time.perf_counter()
    profiles = profiler.profile(graph)
    t_profile = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    plan = ALLOC_POLICIES[alloc_policy](graph)
    t_alloc = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    order = ORDER_POLICIES[order_policy](graph, profiles)
    t_order = (time.perf_counter() - t0) * 1e3
    validate_order(graph, order)

    if alloc_policy == "sequential":
        max_lanes = 1
    t0 = time.perf_counter()
    if repack:
        waves = repack_waves(graph, plan, order, profiles,
                             cfg=sim_cfg or SimConfig(), max_lanes=max_lanes)
        order = waves.flat_order()
        validate_order(graph, order)
    else:
        waves = build_waves(graph, plan, order, max_lanes=max_lanes)
    t_waves = (time.perf_counter() - t0) * 1e3
    return SchedulePlan(
        graph=graph,
        stream_plan=plan,
        order=order,
        waves=waves,
        profiles=profiles,
        alloc_policy=alloc_policy,
        order_policy=order_policy,
        alloc_time_ms=t_alloc,
        order_time_ms=t_order,
        profile_time_ms=t_profile,
        wave_time_ms=t_waves,
        repacked=repack,
        sim_cfg=sim_cfg,
    )


@dataclasses.dataclass(frozen=True)
class RefineConfig:
    """Budget knobs for :func:`refine` (frozen + hashable — it joins the
    session plan-cache key).

    ``budget_factor`` caps the total cost-model work at ``budget_factor ×
    n_ops`` op placements — one full ``_sweep`` of the graph costs
    ``n_ops``.  A rebalance repack (see below) is pre-charged ``2 × n_ops``
    (packer work + the ranking sweep), and on graphs above
    ``REFINE_WALK_OP_LIMIT`` ops the boundary walk only starts while ``2 ×
    n_ops`` of budget remains (editor build + one full suffix pass).  The
    default of 4 therefore buys one rebalance variant plus either a second
    variant or the boundary walk — which keeps autotune+refine within the
    ~2×-single-policy-schedule cold budget on multi-thousand-op graphs;
    raise it (e.g. to 8) to walk the whole ladder.  ``min_budget`` is an
    absolute placement floor: on small graphs ``budget_factor × n_ops``
    would starve the boundary walk to save fractions of a millisecond, so
    the budget never drops below this many placements.  ``plateau`` stops the
    search after that many consecutively *rejected* candidates;
    ``max_rounds`` bounds full passes over the wave boundaries (a round
    with no accepted move also stops); ``checkpoint_stride`` is the wave
    interval between :class:`repro.core.simulator.SweepState` checkpoints
    that make suffix re-estimation cheap.

    ``rebalance`` is the phase-1 ladder of repack parameterizations
    ``(cap_scale, max_lanes)`` tried before the boundary walk: the packer is
    re-run with the packing cap scaled by ``cap_scale`` (packing to e.g.
    75 % of the cap leaves headroom that trades wave width against the
    simulator's resource-cap admission stalls) and/or the wave width capped
    at ``max_lanes`` (narrower waves shrink head-of-line exposure), and each
    candidate is ranked by a full ``_sweep`` under the TRUE config — only a
    strictly better packing is adopted, so the true ``resource_cap`` always
    holds for the result.  ``max_lanes=None`` keeps the caller's lane bound.
    """

    budget_factor: float = 4.0
    min_budget: int = 8192
    plateau: int = 64
    max_rounds: int = 3
    checkpoint_stride: int = 16
    migrate_per_boundary: int = 2
    rebalance: tuple[tuple[float, int | None], ...] = (
        (0.75, None), (0.85, None), (1.0, 8))

    def __post_init__(self) -> None:
        if self.budget_factor <= 0:
            raise ValueError("budget_factor must be > 0")
        if self.min_budget < 0:
            raise ValueError("min_budget must be >= 0")
        if self.plateau < 1 or self.max_rounds < 1:
            raise ValueError("plateau and max_rounds must be >= 1")
        if self.checkpoint_stride < 1:
            raise ValueError("checkpoint_stride must be >= 1")
        if self.migrate_per_boundary < 0:
            raise ValueError("migrate_per_boundary must be >= 0")
        for scale, lanes in self.rebalance:
            if scale <= 0:
                raise ValueError("rebalance cap_scale must be > 0")
            if lanes is not None and lanes < 1:
                raise ValueError("rebalance max_lanes must be >= 1 or None")


def _normalize_refine(refine: "bool | RefineConfig | None") -> RefineConfig | None:
    """``False``/``None`` → off, ``True`` → defaults (so ``refine=True`` and
    an explicit default config share plan-cache entries)."""
    if refine is None or refine is False:
        return None
    if refine is True:
        return RefineConfig()
    if isinstance(refine, RefineConfig):
        return refine
    raise TypeError(f"refine must be bool or RefineConfig, got {refine!r}")


# accepted move must beat the incumbent by more than float noise
_REFINE_EPS = 1e-9

# above this size the boundary walk runs only on leftover budget (the
# rebalance ladder is the productive phase on huge graphs; see RefineConfig)
REFINE_WALK_OP_LIMIT = 1024


def refine(
    plan: SchedulePlan,
    cfg: SimConfig | None = None,
    refine_cfg: "bool | RefineConfig | None" = None,
    max_lanes: int | None = None,
) -> SchedulePlan:
    """IOS-style iterative schedule refinement under the ``_sweep`` oracle.

    Starts from ``plan`` (typically the :func:`autotune` winner) and
    searches in two phases, accepting a candidate only when its predicted
    makespan is *strictly* better:

    1. **Rebalance** — re-runs the wave packer under the perturbed
       parameterizations of ``RefineConfig.rebalance`` (scaled packing cap,
       bounded lane width) and ranks each candidate packing by a full
       ``_sweep`` under the true config.  This is the move that pays on
       multi-thousand-op graphs, where the static sweep's single packing
       sits at a strong local optimum of the boundary-move neighborhood.
    2. **Boundary walk** — walks the wave boundaries of the incumbent
       proposing local edits: merge / split of adjacent waves, op migration
       across a boundary respecting dependencies and ``resource_cap``,
       whole-wave exchanges, cross-class swaps and in-wave class
       re-interleaving (intensity rebalancing).  Boundaries are visited
       back-to-front so each candidate re-estimates only the schedule
       suffix behind the edit, resumed from the nearest
       :class:`SweepState` checkpoint (delta re-estimation with a shared
       per-op end array — ``SweepState.fork``).

    See :class:`RefineConfig` for the budget / plateau semantics that bound
    the cold cost.

    Returns a new :class:`SchedulePlan` (``refined=True`` provenance, waves
    re-emitted with fusion groups recomputed for edited waves only) — or the
    input plan with refinement bookkeeping attached when no candidate beat
    the seed.  The result is never worse than the seed: the launch order is
    only replaced when its predicted makespan strictly improves on the
    seed's.
    """
    rcfg = _normalize_refine(refine_cfg) or RefineConfig()
    cfg = cfg or plan.sim_cfg or SimConfig()
    t0 = time.perf_counter()
    graph = plan.graph
    n = len(graph.nodes)
    tables = op_tables(graph, plan.stream_plan, plan.profiles)

    seed_est = (plan.est_makespan_us if plan.est_makespan_us is not None
                and plan.sim_cfg == cfg else _sweep(tables, plan.order, cfg))
    default_lanes = (max_lanes if max_lanes is not None
                     else max(plan.n_streams, 1))

    budget = max(rcfg.budget_factor * n, rcfg.min_budget)
    swept = 0
    evals = 0
    accepted = 0
    stride = rcfg.checkpoint_stride

    # incumbent: the seed waves' own linearization (for non-repacked seeds
    # this can differ from plan.order — adoption is still gated on beating
    # seed_est below, so the result is never worse than the seed)
    seed_flat = [op for w in plan.waves.waves for op in w.op_ids]
    if seed_flat == plan.order:
        current = seed_est
    else:
        current = _sweep(tables, seed_flat, cfg)
        swept += n
        evals += 1
    best_final = current

    # -- phase 1: rebalance — repack under perturbed knobs, rank under the
    # true config, adopt the best strictly-better packing as the incumbent
    best_var: tuple[float, WaveSchedule] | None = None
    for scale, lanes in rcfg.rebalance:
        if swept + 2 * n >= budget:     # pre-charge: a variant costs 2n
            break
        scaled = scale != 1.0 and not math.isinf(cfg.resource_cap)
        lanes_eff = default_lanes if lanes is None else min(lanes, default_lanes)
        if not scaled and lanes_eff == default_lanes:
            continue            # identical knobs to the seed packer
        pack_cfg = (dataclasses.replace(
            cfg, resource_cap=cfg.resource_cap * scale) if scaled else cfg)
        ws = repack_waves(graph, plan.stream_plan, plan.order, plan.profiles,
                          cfg=pack_cfg, max_lanes=lanes_eff, group=False)
        swept += 2 * n          # packer work + the ranking sweep below
        evals += 1
        var_est = _sweep(tables, ws.flat_order(), cfg)
        if var_est < current - _REFINE_EPS and (
                best_var is None or var_est < best_var[0]):
            best_var = (var_est, ws)
    waves_in = plan.waves
    if best_var is not None:
        current = best_final = best_var[0]
        waves_in = regroup_waves(graph, best_var[1])
        swept += n              # the regroup pass
        accepted += 1

    # -- phase 2: boundary walk — built lazily, and on large graphs only
    # while enough budget remains for the editor's dense indices plus one
    # full suffix pass (below the op limit both are sub-millisecond, so the
    # walk always runs and the placement budget alone bounds it)
    editor: WaveEditor | None = None
    if n <= REFINE_WALK_OP_LIMIT or swept + 2 * n <= budget:
        editor = WaveEditor(graph, waves_in, plan.profiles, cfg=cfg,
                            max_lanes=default_lanes)
        # checkpoints[i] = (wave index k, SweepState after waves[:k]); entry
        # 0 is the empty state, later entries are recorded lazily while
        # sweeping
        checkpoints: list[tuple[int, SweepState]] = [(0, SweepState(n))]

        def eval_from(j: int, replacement: list[list[int]],
                      n_replaced: int) -> float:
            """Predicted makespan of the schedule with
            ``lists[j:j+n_replaced]`` replaced — sweeps only from the
            nearest checkpoint ≤ j."""
            nonlocal swept
            ci = max(i for i, (k, _) in enumerate(checkpoints) if k <= j)
            k, st = checkpoints[ci]
            # fork, not clone: all states share one per-op end array (see
            # SweepState.fork — entries behind the fork point are rewritten
            # before any read), so an eval costs O(prefix-from-checkpoint +
            # suffix) with no O(n) copy
            st = st.fork()
            lists = editor.lists
            while k < j:    # unmodified prefix: re-record checkpoint density
                sweep_extend(tables, lists[k], cfg, st)
                swept += len(lists[k])
                k += 1
                if k % stride == 0 and k > checkpoints[-1][0] and k < j:
                    checkpoints.append((k, st.fork()))
            suffix: list[int] = [op for w in replacement for op in w]
            for w in lists[j + n_replaced:]:
                suffix.extend(w)
            sweep_extend(tables, suffix, cfg, st)
            swept += len(suffix)
            return st.makespan

        rejects_in_row = 0
        stopped = False
        for _round in range(rcfg.max_rounds):
            accepted_this_round = 0
            j = editor.n_waves - 1
            while j >= 0 and not stopped:
                if swept >= budget:
                    stopped = True
                    break
                cands: list[tuple[int, list[list[int]]]] = []
                if j + 1 < editor.n_waves:
                    merged = editor.merge_candidate(j)
                    if merged is not None:
                        cands.append((2, merged))
                    cands += [(2, c) for c in editor.migrate_candidates(
                        j, rcfg.migrate_per_boundary)]
                    cands += [(2, c) for c in editor.push_candidates(j)]
                    swapped = editor.swap_candidate(j)
                    if swapped is not None:
                        cands.append((2, swapped))
                    exchanged = editor.exchange_candidate(j)
                    if exchanged is not None:
                        cands.append((2, exchanged))
                split = editor.split_candidate(j)
                if split is not None:
                    cands.append((1, split))
                reordered = editor.reorder_candidate(j)
                if reordered is not None:
                    cands.append((1, reordered))
                accepted_here = False
                for n_replaced, replacement in cands:
                    est = eval_from(j, replacement, n_replaced)
                    evals += 1
                    if est < current - _REFINE_EPS:
                        editor.apply(j, n_replaced, replacement)
                        while checkpoints[-1][0] > j:  # suffix states stale
                            checkpoints.pop()
                        current = est
                        best_final = est
                        accepted += 1
                        accepted_this_round += 1
                        rejects_in_row = 0
                        # sibling proposals were built against the
                        # pre-accept waves — regenerate at this boundary
                        accepted_here = True
                        break
                    rejects_in_row += 1
                    if rejects_in_row >= rcfg.plateau:
                        stopped = True
                        break
                    if swept >= budget:
                        stopped = True
                        break
                if not accepted_here:
                    j -= 1
            if stopped or accepted_this_round == 0:
                break

    refine_ms = (time.perf_counter() - t0) * 1e3
    n_candidates = plan.n_candidates + evals
    if accepted == 0 or best_final >= seed_est - _REFINE_EPS:
        # nothing beat the seed: keep its schedule, attach the bookkeeping
        return dataclasses.replace(
            plan, sim_cfg=cfg, est_makespan_us=seed_est, refined=False,
            refine_ms=refine_ms, refine_iters=0, n_candidates=n_candidates)
    if editor is not None and editor.n_edits > 0:
        waves = editor.schedule()
    else:
        waves = waves_in            # ladder winner, already regrouped
    order = waves.flat_order()
    validate_order(graph, order)
    return dataclasses.replace(
        plan, order=order, waves=waves, sim_cfg=cfg,
        est_makespan_us=best_final, refined=True, refine_ms=refine_ms,
        refine_iters=accepted, refine_delta_us=seed_est - best_final,
        n_candidates=n_candidates)


# autotune's ``refine`` parameter shadows the function; alias it for the call
_refine_plan = refine


def autotune(
    graph: OpGraph,
    hw: HardwareSpec = V5E,
    cfg: SimConfig | None = None,
    alloc_policies: Iterable[str] | None = None,
    order_policies: Iterable[str] | None = None,
    repack_options: Iterable[bool] = (False, True),
    max_lanes: int | None = None,
    measured_inputs: Mapping[int, Any] | None = None,
    refine: "bool | RefineConfig" = False,
) -> SchedulePlan:
    """Simulator-guided schedule search: pick the min-predicted-makespan
    plan from {alloc} × {order} × {repack on/off}.

    Work is shared across candidates — the graph is profiled once, each
    allocator and each order run once — so the search costs one pipeline
    pass plus a wave-build + cost-model sweep per candidate.  The result is
    an ordinary :class:`SchedulePlan` (with ``est_makespan_us`` /
    ``autotune_ms`` / ``n_candidates`` filled in), cacheable under the plan
    cache exactly like a single-policy schedule.

    ``refine`` (``True`` or a :class:`RefineConfig`) hands the static-sweep
    winner to :func:`refine` for iterative local search — the IOS move —
    with its wall time folded into ``autotune_ms`` and surfaced separately
    as ``refine_ms``.
    """
    graph.validate()
    cfg = cfg or SimConfig()
    repack_options = tuple(repack_options)   # membership-tested twice below
    profiler = ModelProfiler(hw)
    if measured_inputs is not None:
        apply_profile(graph, profiler.measure(graph, measured_inputs))
    t_search0 = time.perf_counter()
    profiles = profiler.profile(graph)
    t_profile = (time.perf_counter() - t_search0) * 1e3

    small = len(graph) <= NIMBLE_ALLOC_OP_LIMIT
    if alloc_policies is None:
        alloc_policies = ("opara", "nimble") if small else ("opara",)
    if order_policies is None:
        order_policies = (AUTOTUNE_ORDER_POLICIES if small
                          else AUTOTUNE_ORDER_POLICIES_LARGE)

    allocs: dict[str, tuple[StreamPlan, float]] = {}
    for ap in alloc_policies:
        t0 = time.perf_counter()
        allocs[ap] = (ALLOC_POLICIES[ap](graph),
                      (time.perf_counter() - t0) * 1e3)
    orders: dict[str, tuple[list[int], float]] = {}
    for op_ in order_policies:
        t0 = time.perf_counter()
        order = ORDER_POLICIES[op_](graph, profiles)
        orders[op_] = (order, (time.perf_counter() - t0) * 1e3)
        validate_order(graph, order)

    # Evaluate candidates on (streams, order) alone — the cost model never
    # reads waves, so the wave build (the costliest per-candidate step) is
    # deferred to the single winner.  Repacked candidates are the exception:
    # repacking IS a wave build, and its flat order is what gets estimated —
    # every order is repacked and ranked on its own flat order, so the
    # order×repack interaction is explored on large graphs too (repacking
    # only the plain-sweep winner left e.g. bert-180L at ``repacked: false``
    # whenever a repacked non-winner order would have beaten it).
    best: tuple[float, str, str, bool, Any, list[int], WaveSchedule | None] | None = None
    n_candidates = 0

    def consider(est, ap, op_, rp, splan, cand_order, waves) -> None:
        nonlocal best, n_candidates
        n_candidates += 1
        if best is None or est < best[0]:
            best = (est, ap, op_, rp, splan, cand_order, waves)

    for ap, (splan, t_alloc) in allocs.items():
        tables = op_tables(graph, splan, profiles)   # one prefetch per alloc
        if False in repack_options:
            for op_, (order, t_order) in orders.items():
                est = _sweep(tables, order, cfg)
                consider(est, ap, op_, False, splan, order, None)
        if True in repack_options:
            for op_ in orders:
                order = orders[op_][0]
                # group=False: candidates are ranked on flat_order() alone,
                # so fusion grouping is deferred to the single winner below
                waves = repack_waves(graph, splan, order, profiles,
                                     cfg=cfg, max_lanes=max_lanes,
                                     group=False)
                cand_order: list[int] = waves.flat_order()
                est = _sweep(tables, cand_order, cfg)
                consider(est, ap, op_, True, splan, cand_order, waves)
    assert best is not None, "autotune needs a non-empty candidate space"
    est, ap, op_, rp, splan, cand_order, waves = best
    t0 = time.perf_counter()
    if waves is None:
        waves = build_waves(graph, splan, cand_order, max_lanes=max_lanes)
    else:
        waves = regroup_waves(graph, waves)
    t_waves = (time.perf_counter() - t0) * 1e3
    plan = SchedulePlan(
        graph=graph, stream_plan=splan, order=cand_order, waves=waves,
        profiles=profiles, alloc_policy=ap, order_policy=op_,
        alloc_time_ms=allocs[ap][1], order_time_ms=orders[op_][1],
        profile_time_ms=t_profile, wave_time_ms=t_waves,
        repacked=rp, sim_cfg=cfg, est_makespan_us=est,
        autotune_ms=(time.perf_counter() - t_search0) * 1e3,
        n_candidates=n_candidates)
    rcfg = _normalize_refine(refine)
    if rcfg is not None:
        plan = _refine_plan(plan, cfg=cfg, refine_cfg=rcfg,
                            max_lanes=max_lanes)
        plan = dataclasses.replace(
            plan, autotune_ms=(time.perf_counter() - t_search0) * 1e3)
    return plan


def compile_plan(plan: SchedulePlan, output_ids=None, donate_inputs=False,
                 gemm_kernel: str = "auto", faults=None) -> CapturedGraph:
    return capture(plan.graph, plan.waves, output_ids=output_ids,
                   donate_inputs=donate_inputs, gemm_kernel=gemm_kernel,
                   faults=faults)


def simulate_plan(plan: SchedulePlan, cfg: SimConfig | None = None) -> SimResult:
    return simulate(plan.graph, plan.stream_plan, plan.order, plan.profiles,
                    cfg or SimConfig())


def estimate_plan(plan: SchedulePlan, cfg: SimConfig | None = None) -> float:
    """Cost-model makespan of an existing plan (the autotuner's objective)."""
    return estimate_makespan(plan.graph, plan.stream_plan, plan.order,
                             plan.profiles, cfg or SimConfig())


def compare_policies(
    graph: OpGraph,
    hw: HardwareSpec = V5E,
    cfg: SimConfig | None = None,
    opara_plan: SchedulePlan | None = None,
    tuned_meta: dict[str, str] | None = None,
) -> dict[str, dict[str, float]]:
    """The paper's four-way comparison on one graph (Fig. 5a analogue).

    The ``opara`` row is the full closed-loop pipeline — autotuned over
    {alloc} × {order} × {repack} — simulated under the same config as the
    baselines.  Callers that already ran the search (e.g. benchmarks also
    reporting the tuned plan's packing stats) pass it as ``opara_plan`` so
    it is not repeated.  Returns {policy: {makespan_us, ...}} — numeric
    metrics only; the tuned plan's *string* provenance (picked alloc/order
    policies) goes into ``tuned_meta`` if the caller passes a dict for it,
    keeping the rows honestly ``dict[str, float]``.
    """
    cfg = cfg or SimConfig()
    results: dict[str, dict[str, float]] = {}
    seq_plan = schedule(graph, "sequential", "topo", hw)
    t_seq_nograph = sequential_makespan(
        graph, seq_plan.profiles, dataclasses.replace(cfg, graph_capture=False)
    )
    t_seq = sequential_makespan(graph, seq_plan.profiles, cfg)
    results["pytorch_eager"] = {"makespan_us": t_seq_nograph, "speedup_vs_eager": 1.0}
    results["cuda_graph_sequential"] = {
        "makespan_us": t_seq,
        "speedup_vs_eager": t_seq_nograph / t_seq,
    }
    plans = {
        "nimble": schedule(graph, "nimble", "topo", hw),
        "opara": opara_plan if opara_plan is not None
        else autotune(graph, hw=hw, cfg=cfg),
    }
    for name, p in plans.items():
        r = simulate(graph, p.stream_plan, p.order, p.profiles, cfg)
        results[name] = {
            "makespan_us": r.makespan_us,
            "speedup_vs_eager": t_seq_nograph / r.makespan_us,
            "speedup_vs_cuda_graph": t_seq / r.makespan_us,
            "n_streams": float(p.n_streams),
            "n_syncs": float(r.n_syncs),
            "utilization": r.utilization(max(p.n_streams, 1)),
        }
        if name == "opara":
            results[name].update(
                repacked=float(p.repacked),
                n_candidates=float(p.n_candidates),
                est_makespan_us=float(p.est_makespan_us or 0.0),
                refined=float(p.refined),
                refine_iters=float(p.refine_iters),
                refine_delta_us=float(p.refine_delta_us),
            )
            if tuned_meta is not None:
                tuned_meta["tuned_alloc"] = p.alloc_policy
                tuned_meta["tuned_order"] = p.order_policy
    return results
