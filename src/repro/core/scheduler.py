"""End-to-end Opara pipeline (paper Fig. 4).

DNN model + inputs → Stream Allocator → Model Profiler → Operator Launcher
→ Graph Capturer → parallelized executable.

``schedule()`` is the core entry point; :mod:`repro.core.api` wraps it for
user models.  Every stage is swappable so benchmarks can mix and match
(e.g. Nimble streams + topo order = the Nimble baseline; one stream + topo
order = sequential CUDA Graph baseline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping

from .capture import CapturedGraph, capture
from .fusion import WaveSchedule, build_waves, fusion_stats
from .graph import OpGraph
from .launch_order import ORDER_POLICIES, validate_order
from .nimble import allocate_streams_nimble
from .profiler import HardwareSpec, ModelProfiler, OpProfile, V5E, apply_profile
from .simulator import SimConfig, SimResult, sequential_makespan, simulate
from .stream_alloc import StreamPlan, allocate_streams, count_syncs


@dataclasses.dataclass
class SchedulePlan:
    """Everything the capturer / simulator needs, plus bookkeeping."""

    graph: OpGraph
    stream_plan: StreamPlan
    order: list[int]
    waves: WaveSchedule
    profiles: dict[int, OpProfile]
    alloc_policy: str
    order_policy: str
    alloc_time_ms: float
    order_time_ms: float

    @property
    def n_streams(self) -> int:
        return self.stream_plan.n_streams

    def stats(self) -> dict[str, float]:
        s = fusion_stats(self.waves)
        s.update(
            n_streams=float(self.n_streams),
            n_syncs=float(count_syncs(self.graph, self.stream_plan)),
            alloc_time_ms=self.alloc_time_ms,
            order_time_ms=self.order_time_ms,
        )
        return s


ALLOC_POLICIES = {
    "opara": allocate_streams,
    "nimble": allocate_streams_nimble,
    "sequential": lambda g: StreamPlan(stream_of={i: 0 for i in g.nodes}, n_streams=1),
}


def schedule(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    max_lanes: int | None = None,
    measured_inputs: Mapping[int, Any] | None = None,
) -> SchedulePlan:
    """Run the full scheduling pipeline (no compilation).

    ``measured_inputs`` forces a fresh profiling inference (measure + hydrate
    via the profiler's apply lifecycle).  This path always re-times — use
    :func:`repro.core.api.plan`, which consults the calibration cache first,
    when "profile once" amortization is wanted.
    """
    graph.validate()
    profiler = ModelProfiler(hw)
    if measured_inputs is not None:
        apply_profile(graph, profiler.measure(graph, measured_inputs))
    profiles = profiler.profile(graph)

    t0 = time.perf_counter()
    plan = ALLOC_POLICIES[alloc_policy](graph)
    t_alloc = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    order = ORDER_POLICIES[order_policy](graph, profiles)
    t_order = (time.perf_counter() - t0) * 1e3
    validate_order(graph, order)

    if alloc_policy == "sequential":
        max_lanes = 1
    waves = build_waves(graph, plan, order, max_lanes=max_lanes)
    return SchedulePlan(
        graph=graph,
        stream_plan=plan,
        order=order,
        waves=waves,
        profiles=profiles,
        alloc_policy=alloc_policy,
        order_policy=order_policy,
        alloc_time_ms=t_alloc,
        order_time_ms=t_order,
    )


def compile_plan(plan: SchedulePlan, output_ids=None, donate_inputs=False,
                 gemm_kernel: str = "auto") -> CapturedGraph:
    return capture(plan.graph, plan.waves, output_ids=output_ids,
                   donate_inputs=donate_inputs, gemm_kernel=gemm_kernel)


def simulate_plan(plan: SchedulePlan, cfg: SimConfig = SimConfig()) -> SimResult:
    return simulate(plan.graph, plan.stream_plan, plan.order, plan.profiles, cfg)


def compare_policies(
    graph: OpGraph,
    hw: HardwareSpec = V5E,
    cfg: SimConfig = SimConfig(),
) -> dict[str, dict[str, float]]:
    """The paper's four-way comparison on one graph (Fig. 5a analogue).

    Returns {policy: {makespan_us, speedup_vs_sequential, n_streams, ...}}.
    """
    results: dict[str, dict[str, float]] = {}
    seq_plan = schedule(graph, "sequential", "topo", hw)
    t_seq_nograph = sequential_makespan(
        graph, seq_plan.profiles, dataclasses.replace(cfg, graph_capture=False)
    )
    t_seq = sequential_makespan(graph, seq_plan.profiles, cfg)
    results["pytorch_eager"] = {"makespan_us": t_seq_nograph, "speedup_vs_eager": 1.0}
    results["cuda_graph_sequential"] = {
        "makespan_us": t_seq,
        "speedup_vs_eager": t_seq_nograph / t_seq,
    }
    for name, alloc, order in [
        ("nimble", "nimble", "topo"),
        ("opara", "opara", "opara"),
    ]:
        p = schedule(graph, alloc, order, hw)
        r = simulate_plan(p, cfg)
        results[name] = {
            "makespan_us": r.makespan_us,
            "speedup_vs_eager": t_seq_nograph / r.makespan_us,
            "speedup_vs_cuda_graph": t_seq / r.makespan_us,
            "n_streams": float(p.n_streams),
            "n_syncs": float(r.n_syncs),
            "utilization": r.utilization(max(p.n_streams, 1)),
        }
    return results
