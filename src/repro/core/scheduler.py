"""End-to-end Opara pipeline (paper Fig. 4) plus the autotune loop.

DNN model + inputs → Stream Allocator → Model Profiler → Operator Launcher
→ Wave (Re)packer → Graph Capturer → parallelized executable.

``schedule()`` is the single-policy entry point; :func:`autotune` closes the
loop on predicted makespan: it evaluates the cross-product of
{alloc policies} × {order policies} × {repack on/off} against the
simulator's fast cost model (:func:`repro.core.simulator.estimate_makespan`)
and returns the min-makespan plan — the IOS insight (cost-model-guided
inter-operator schedule search) kept off the inference critical path the
Nimble way, by hiding the search behind the per-session plan cache
(:class:`repro.core.Session`).

Every stage is swappable so benchmarks can mix and match (e.g. Nimble
streams + topo order = the Nimble baseline; one stream + topo order =
sequential CUDA Graph baseline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Mapping

from .capture import CapturedGraph, capture
from .fusion import WaveSchedule, build_waves, fusion_stats, repack_waves
from .graph import OpGraph
from .launch_order import ORDER_POLICIES, validate_order
from .nimble import allocate_streams_nimble
from .profiler import HardwareSpec, ModelProfiler, OpProfile, V5E, apply_profile
from .simulator import (
    SimConfig,
    SimResult,
    _sweep,
    estimate_makespan,
    op_tables,
    sequential_makespan,
    simulate,
)
from .stream_alloc import StreamPlan, allocate_streams, count_syncs


@dataclasses.dataclass
class SchedulePlan:
    """Everything the capturer / simulator needs, plus bookkeeping."""

    graph: OpGraph
    stream_plan: StreamPlan
    order: list[int]
    waves: WaveSchedule
    profiles: dict[int, OpProfile]
    alloc_policy: str
    order_policy: str
    alloc_time_ms: float
    order_time_ms: float
    # -- per-stage timing hooks (CompiledModel.explain() reads these) -------
    profile_time_ms: float = 0.0            # profiler stage (stage 2)
    wave_time_ms: float = 0.0               # wave build / repack (stage 4)
    # -- autotune / repack bookkeeping --------------------------------------
    repacked: bool = False                  # waves came from repack_waves
    sim_cfg: SimConfig | None = None        # cost-model config used, if any
    est_makespan_us: float | None = None    # winning candidate's estimate
    autotune_ms: float = 0.0                # search wall time (0 = no search)
    n_candidates: int = 1                   # schedules evaluated

    @property
    def n_streams(self) -> int:
        return self.stream_plan.n_streams

    def stats(self) -> dict[str, float]:
        cap = (self.sim_cfg or SimConfig()).resource_cap
        s = fusion_stats(self.waves, self.profiles, resource_cap=cap)
        s.update(
            n_streams=float(self.n_streams),
            n_syncs=float(count_syncs(self.graph, self.stream_plan)),
            alloc_time_ms=self.alloc_time_ms,
            order_time_ms=self.order_time_ms,
            profile_time_ms=self.profile_time_ms,
            wave_time_ms=self.wave_time_ms,
            repacked=float(self.repacked),
            autotune_ms=self.autotune_ms,
            n_candidates=float(self.n_candidates),
        )
        if self.est_makespan_us is not None:
            s["est_makespan_us"] = self.est_makespan_us
        return s


ALLOC_POLICIES = {
    "opara": allocate_streams,
    "nimble": allocate_streams_nimble,
    "sequential": lambda g: StreamPlan(stream_of={i: 0 for i in g.nodes}, n_streams=1),
}

# Default autotune search space.  Above the op limit the cold-path budget
# (autotune ≤ ~2× a single-policy schedule) trims the space: Nimble's
# min-path-cover allocator is O(n³), and the order list drops to the two
# strongest candidates (the caller can always pass a wider space).
AUTOTUNE_ORDER_POLICIES = ("opara", "topo", "critical_path")
AUTOTUNE_ORDER_POLICIES_LARGE = ("opara", "topo")
NIMBLE_ALLOC_OP_LIMIT = 512


def schedule(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    max_lanes: int | None = None,
    measured_inputs: Mapping[int, Any] | None = None,
    repack: bool = False,
    sim_cfg: SimConfig | None = None,
) -> SchedulePlan:
    """Run the full scheduling pipeline (no compilation).

    ``measured_inputs`` forces a fresh profiling inference (measure + hydrate
    via the profiler's apply lifecycle).  This path always re-times — use
    :meth:`repro.core.Session.plan`, which consults the calibration cache
    first, when "profile once" amortization is wanted.

    ``repack=True`` swaps the launch-order wave bucketing for the resource-
    and interference-aware repacker (:func:`repro.core.fusion.repack_waves`)
    under ``sim_cfg``'s resource cap; the launch order is then re-linearized
    wave-major so the dispatch sequence matches what was packed.
    """
    graph.validate()
    profiler = ModelProfiler(hw)
    if measured_inputs is not None:
        apply_profile(graph, profiler.measure(graph, measured_inputs))
    t0 = time.perf_counter()
    profiles = profiler.profile(graph)
    t_profile = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    plan = ALLOC_POLICIES[alloc_policy](graph)
    t_alloc = (time.perf_counter() - t0) * 1e3

    t0 = time.perf_counter()
    order = ORDER_POLICIES[order_policy](graph, profiles)
    t_order = (time.perf_counter() - t0) * 1e3
    validate_order(graph, order)

    if alloc_policy == "sequential":
        max_lanes = 1
    t0 = time.perf_counter()
    if repack:
        waves = repack_waves(graph, plan, order, profiles,
                             cfg=sim_cfg or SimConfig(), max_lanes=max_lanes)
        order = waves.flat_order()
        validate_order(graph, order)
    else:
        waves = build_waves(graph, plan, order, max_lanes=max_lanes)
    t_waves = (time.perf_counter() - t0) * 1e3
    return SchedulePlan(
        graph=graph,
        stream_plan=plan,
        order=order,
        waves=waves,
        profiles=profiles,
        alloc_policy=alloc_policy,
        order_policy=order_policy,
        alloc_time_ms=t_alloc,
        order_time_ms=t_order,
        profile_time_ms=t_profile,
        wave_time_ms=t_waves,
        repacked=repack,
        sim_cfg=sim_cfg,
    )


def autotune(
    graph: OpGraph,
    hw: HardwareSpec = V5E,
    cfg: SimConfig | None = None,
    alloc_policies: Iterable[str] | None = None,
    order_policies: Iterable[str] | None = None,
    repack_options: Iterable[bool] = (False, True),
    max_lanes: int | None = None,
    measured_inputs: Mapping[int, Any] | None = None,
) -> SchedulePlan:
    """Simulator-guided schedule search: pick the min-predicted-makespan
    plan from {alloc} × {order} × {repack on/off}.

    Work is shared across candidates — the graph is profiled once, each
    allocator and each order run once — so the search costs one pipeline
    pass plus a wave-build + cost-model sweep per candidate.  The result is
    an ordinary :class:`SchedulePlan` (with ``est_makespan_us`` /
    ``autotune_ms`` / ``n_candidates`` filled in), cacheable under the plan
    cache exactly like a single-policy schedule.
    """
    graph.validate()
    cfg = cfg or SimConfig()
    repack_options = tuple(repack_options)   # membership-tested twice below
    profiler = ModelProfiler(hw)
    if measured_inputs is not None:
        apply_profile(graph, profiler.measure(graph, measured_inputs))
    t_search0 = time.perf_counter()
    profiles = profiler.profile(graph)
    t_profile = (time.perf_counter() - t_search0) * 1e3

    small = len(graph) <= NIMBLE_ALLOC_OP_LIMIT
    if alloc_policies is None:
        alloc_policies = ("opara", "nimble") if small else ("opara",)
    if order_policies is None:
        order_policies = (AUTOTUNE_ORDER_POLICIES if small
                          else AUTOTUNE_ORDER_POLICIES_LARGE)

    allocs: dict[str, tuple[StreamPlan, float]] = {}
    for ap in alloc_policies:
        t0 = time.perf_counter()
        allocs[ap] = (ALLOC_POLICIES[ap](graph),
                      (time.perf_counter() - t0) * 1e3)
    orders: dict[str, tuple[list[int], float]] = {}
    for op_ in order_policies:
        t0 = time.perf_counter()
        order = ORDER_POLICIES[op_](graph, profiles)
        orders[op_] = (order, (time.perf_counter() - t0) * 1e3)
        validate_order(graph, order)

    # Evaluate candidates on (streams, order) alone — the cost model never
    # reads waves, so the wave build (the costliest per-candidate step) is
    # deferred to the single winner.  Repacked candidates are the exception:
    # repacking IS a wave build, and its flat order is what gets estimated.
    # Above the op limit the repack leg is staged: plain sweeps rank the
    # orders first and only the most promising one is repacked, keeping the
    # whole search inside the ~2×-single-policy cold budget.
    best: tuple[float, str, str, bool, Any, list[int], WaveSchedule | None] | None = None
    n_candidates = 0

    def consider(est, ap, op_, rp, splan, cand_order, waves) -> None:
        nonlocal best, n_candidates
        n_candidates += 1
        if best is None or est < best[0]:
            best = (est, ap, op_, rp, splan, cand_order, waves)

    for ap, (splan, t_alloc) in allocs.items():
        tables = op_tables(graph, splan, profiles)   # one prefetch per alloc
        plain_best: tuple[float, str] | None = None
        if False in repack_options:
            for op_, (order, t_order) in orders.items():
                est = _sweep(tables, order, cfg)
                consider(est, ap, op_, False, splan, order, None)
                if plain_best is None or est < plain_best[0]:
                    plain_best = (est, op_)
        if True in repack_options:
            if small:
                repack_orders = list(orders)
            elif plain_best is not None:
                repack_orders = [plain_best[1]]
            else:
                repack_orders = list(orders)[:1]
            for op_ in repack_orders:
                order = orders[op_][0]
                waves = repack_waves(graph, splan, order, profiles,
                                     cfg=cfg, max_lanes=max_lanes)
                cand_order: list[int] = waves.flat_order()
                est = _sweep(tables, cand_order, cfg)
                consider(est, ap, op_, True, splan, cand_order, waves)
    assert best is not None, "autotune needs a non-empty candidate space"
    est, ap, op_, rp, splan, cand_order, waves = best
    t0 = time.perf_counter()
    if waves is None:
        waves = build_waves(graph, splan, cand_order, max_lanes=max_lanes)
    t_waves = (time.perf_counter() - t0) * 1e3
    return SchedulePlan(
        graph=graph, stream_plan=splan, order=cand_order, waves=waves,
        profiles=profiles, alloc_policy=ap, order_policy=op_,
        alloc_time_ms=allocs[ap][1], order_time_ms=orders[op_][1],
        profile_time_ms=t_profile, wave_time_ms=t_waves,
        repacked=rp, sim_cfg=cfg, est_makespan_us=est,
        autotune_ms=(time.perf_counter() - t_search0) * 1e3,
        n_candidates=n_candidates)


def compile_plan(plan: SchedulePlan, output_ids=None, donate_inputs=False,
                 gemm_kernel: str = "auto", faults=None) -> CapturedGraph:
    return capture(plan.graph, plan.waves, output_ids=output_ids,
                   donate_inputs=donate_inputs, gemm_kernel=gemm_kernel,
                   faults=faults)


def simulate_plan(plan: SchedulePlan, cfg: SimConfig = SimConfig()) -> SimResult:
    return simulate(plan.graph, plan.stream_plan, plan.order, plan.profiles, cfg)


def estimate_plan(plan: SchedulePlan, cfg: SimConfig = SimConfig()) -> float:
    """Cost-model makespan of an existing plan (the autotuner's objective)."""
    return estimate_makespan(plan.graph, plan.stream_plan, plan.order,
                             plan.profiles, cfg)


def compare_policies(
    graph: OpGraph,
    hw: HardwareSpec = V5E,
    cfg: SimConfig = SimConfig(),
    opara_plan: SchedulePlan | None = None,
) -> dict[str, dict[str, float]]:
    """The paper's four-way comparison on one graph (Fig. 5a analogue).

    The ``opara`` row is the full closed-loop pipeline — autotuned over
    {alloc} × {order} × {repack} — simulated under the same config as the
    baselines.  Callers that already ran the search (e.g. benchmarks also
    reporting the tuned plan's packing stats) pass it as ``opara_plan`` so
    it is not repeated.  Returns {policy: {makespan_us, ...}}.
    """
    results: dict[str, dict[str, float]] = {}
    seq_plan = schedule(graph, "sequential", "topo", hw)
    t_seq_nograph = sequential_makespan(
        graph, seq_plan.profiles, dataclasses.replace(cfg, graph_capture=False)
    )
    t_seq = sequential_makespan(graph, seq_plan.profiles, cfg)
    results["pytorch_eager"] = {"makespan_us": t_seq_nograph, "speedup_vs_eager": 1.0}
    results["cuda_graph_sequential"] = {
        "makespan_us": t_seq,
        "speedup_vs_eager": t_seq_nograph / t_seq,
    }
    plans = {
        "nimble": schedule(graph, "nimble", "topo", hw),
        "opara": opara_plan if opara_plan is not None
        else autotune(graph, hw=hw, cfg=cfg),
    }
    for name, p in plans.items():
        r = simulate(graph, p.stream_plan, p.order, p.profiles, cfg)
        results[name] = {
            "makespan_us": r.makespan_us,
            "speedup_vs_eager": t_seq_nograph / r.makespan_us,
            "speedup_vs_cuda_graph": t_seq / r.makespan_us,
            "n_streams": float(p.n_streams),
            "n_syncs": float(r.n_syncs),
            "utilization": r.utilization(max(p.n_streams, 1)),
        }
        if name == "opara":
            results[name].update(
                repacked=float(p.repacked),
                n_candidates=float(p.n_candidates),
                est_makespan_us=float(p.est_makespan_us or 0.0),
                tuned_alloc=p.alloc_policy,   # type: ignore[arg-type]
                tuned_order=p.order_policy,   # type: ignore[arg-type]
            )
    return results
