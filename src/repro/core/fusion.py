"""Wave construction + horizontal fusion.

DESIGN.md §2: on TPU, "N operators running concurrently on N streams" is
realized by packing independent operators into a **wave** and fusing
same-signature ops in a wave into ONE batched kernel (stacked GEMM /
grouped einsum).  This is the TPU-native mechanism that recovers the MXU
under-utilization the paper's Fig. 1 measures for small kernels.

Waves are built from the Opara launch order: walk ops in launch order and
place each op in the earliest wave after all of its producers' waves, capped
by ``max_lanes`` (the stream count).  Ops in one wave are mutually
independent by construction.

Fusion groups: within a wave, ops sharing ``fuse_sig`` (same kind + same
operand shapes/dtype) form one group executed as a single stacked op by the
capturer (or routed to the `branch_gemm` Pallas kernel on TPU).

Two packers:

* :func:`build_waves` — launch-order bucketing capped by lane count only
  (the historical packer; still the ``repack=False`` baseline the autotuner
  compares against);
* :func:`repack_waves` — resource- and interference-aware: a wave admits an
  op only while the wave's summed ``resource_demand()`` stays under
  ``SimConfig.resource_cap``, and ready ops are drawn alternately from the
  memory- and compute-intensive pools (greedy complementary fill) so
  co-resident ops mix intensity classes and the simulator's same-class
  interference penalty stops firing on every wave.
"""
from __future__ import annotations

import dataclasses
import heapq

from .graph import IntensityClass, OpGraph
from .profiler import OpProfile
from .simulator import SimConfig
from .stream_alloc import StreamPlan


@dataclasses.dataclass
class Wave:
    index: int
    op_ids: list[int]
    fusion_groups: list[list[int]]  # partition of op_ids


@dataclasses.dataclass
class WaveSchedule:
    waves: list[Wave]

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    @property
    def n_fused_kernels(self) -> int:
        return sum(len(w.fusion_groups) for w in self.waves)

    def flat_order(self) -> list[int]:
        return [op for w in self.waves for op in w.op_ids]


def build_waves(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    max_lanes: int | None = None,
) -> WaveSchedule:
    """Greedy wave packing honoring the launch order.

    wave_of[op] = max(wave_of[producers]) + 1, but never earlier than an op
    launched before it *in the same stream* (streams stay FIFO), and each
    wave holds at most ``max_lanes`` ops (hardware lanes = streams).
    """
    if max_lanes is None:
        max_lanes = max(plan.n_streams, 1)
    wave_of: dict[int, int] = {}
    last_wave_in_stream: dict[int, int] = {}
    load: dict[int, int] = {}  # wave -> #ops
    for op in order:
        node = graph.nodes[op]
        w = 0
        for p in node.inputs:
            w = max(w, wave_of[p] + 1)
        s = plan.stream_of[op]
        if s in last_wave_in_stream:
            w = max(w, last_wave_in_stream[s] + 1)
        while load.get(w, 0) >= max_lanes:
            w += 1
        wave_of[op] = w
        last_wave_in_stream[s] = w
        load[w] = load.get(w, 0) + 1

    # single-pass bucketing: `order` is walked once; ops land in their wave
    # bucket in launch order (was an O(n_waves · n_ops) rescan).
    buckets: dict[int, list[int]] = {}
    for op in order:
        buckets.setdefault(wave_of[op], []).append(op)
    waves: list[Wave] = []
    for k in sorted(buckets):
        ops = buckets[k]
        waves.append(Wave(index=len(waves), op_ids=ops,
                          fusion_groups=_group(graph, ops)))
    return WaveSchedule(waves=waves)


def repack_waves(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    profiles: dict[int, OpProfile],
    cfg: SimConfig = SimConfig(),
    max_lanes: int | None = None,
) -> WaveSchedule:
    """Resource- and interference-aware wave repacking.

    Waves are built one at a time from the ready frontier (ops whose
    producers all sit in *closed* waves), so dependencies hold by
    construction.  Admission into the open wave requires the wave's summed
    ``resource_demand()`` to stay under ``cfg.resource_cap`` (an op whose
    demand alone exceeds the cap gets a wave to itself — the simulator's
    empty-device admission rule).  Ready ops live in two pools keyed by
    intensity class; each draw prefers the class that balances the wave
    (greedy complementary fill), with the launch order breaking ties inside
    a pool — so Algorithm 2's resource-ascending order survives within each
    class while waves deliberately mix classes.

    Fusion groups are recomputed per repacked wave: same-signature ops that
    still co-reside stack into one kernel; ops a resource boundary separated
    fall back to per-branch steps in the capturer automatically.
    """
    if max_lanes is None:
        max_lanes = max(plan.n_streams, 1)
    cap = cfg.resource_cap
    indeg = graph.indegree_map()
    succ = graph.unique_successors_map()

    # hot-loop precompute on dense op-id-indexed lists: the autotuner repacks
    # the same graph once per order candidate, so per-op attribute chases and
    # dict hashing add up on large graphs
    n = len(graph.nodes)
    pos = [0] * n
    for k, op in enumerate(order):
        pos[op] = k
    demand = [0.0] * n
    is_mem = [False] * n
    for op, p in profiles.items():
        demand[op] = p.cost.resource_demand()
        is_mem[op] = p.intensity is IntensityClass.MEMORY
    pool_mem: list[tuple[int, int]] = []
    pool_comp: list[tuple[int, int]] = []

    def push(op: int) -> None:
        heapq.heappush(pool_mem if is_mem[op] else pool_comp, (pos[op], op))

    for op, d in indeg.items():
        if d == 0:
            push(op)

    waves: list[Wave] = []
    while pool_mem or pool_comp:
        wave_ops: list[int] = []
        used = 0.0
        n_mem = n_comp = 0
        skipped_mem: list[tuple[int, int]] = []
        skipped_comp: list[tuple[int, int]] = []
        while len(wave_ops) < max_lanes:
            # complementary fill: draw from the class the wave has fewer of
            if n_mem <= n_comp:
                pool = pool_mem if pool_mem else pool_comp
            else:
                pool = pool_comp if pool_comp else pool_mem
            if not pool:
                break
            item = heapq.heappop(pool)
            op = item[1]
            mem = is_mem[op]
            if wave_ops and used + demand[op] > cap:
                # does not fit — defer to the next wave
                (skipped_mem if mem else skipped_comp).append(item)
                continue
            wave_ops.append(op)
            used += demand[op]
            if mem:
                n_mem += 1
            else:
                n_comp += 1
        for item in skipped_mem:
            heapq.heappush(pool_mem, item)
        for item in skipped_comp:
            heapq.heappush(pool_comp, item)
        # close the wave: successors of its ops become ready for the next
        wave_ops.sort(key=pos.__getitem__)   # list.__getitem__: op -> rank
        waves.append(Wave(index=len(waves), op_ids=wave_ops,
                          fusion_groups=_group(graph, wave_ops)))
        for op in wave_ops:
            for s in succ[op]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    push(s)
    return WaveSchedule(waves=waves)


def _group(graph: OpGraph, ops: list[int]) -> list[list[int]]:
    groups: dict[object, list[int]] = {}
    singles: list[list[int]] = []
    for op in ops:
        sig = graph.nodes[op].fuse_sig
        if sig is None:
            singles.append([op])
        else:
            groups.setdefault(sig, []).append(op)
    return list(groups.values()) + singles


def fusion_stats(
    sched: WaveSchedule,
    profiles: dict[int, OpProfile] | None = None,
    resource_cap: float | None = None,
) -> dict[str, float]:
    """Packing statistics; with ``profiles`` also repack-efficacy metrics.

    ``mean/max_wave_resource_util`` — per-wave summed ``resource_demand()``
    over ``resource_cap`` (how full the pool is packed; >1 on a single-op
    wave means an op that alone exceeds the cap).  ``same_class_overlap_frac``
    — fraction of ops in multi-op waves that share the wave with another op
    of their own intensity class, i.e. how often the simulator's same-class
    interference penalty fires; the repacker's complementary fill drives it
    down.
    """
    n_ops = sum(len(w.op_ids) for w in sched.waves)
    out = {
        "n_ops": float(n_ops),
        "n_waves": float(sched.n_waves),
        "n_kernels_after_fusion": float(sched.n_fused_kernels),
        "mean_wave_width": n_ops / max(sched.n_waves, 1),
        "fusion_ratio": n_ops / max(sched.n_fused_kernels, 1),
    }
    if profiles is None:
        return out
    if resource_cap is None:
        resource_cap = SimConfig().resource_cap
    utils: list[float] = []
    n_overlapped = 0
    n_in_multi = 0
    for w in sched.waves:
        utils.append(
            sum(profiles[o].cost.resource_demand() for o in w.op_ids)
            / max(resource_cap, 1e-9))
        if len(w.op_ids) < 2:
            continue
        n_in_multi += len(w.op_ids)
        per_class = {}
        for o in w.op_ids:
            c = profiles[o].intensity
            per_class[c] = per_class.get(c, 0) + 1
        n_overlapped += sum(k for k in per_class.values() if k >= 2)
    out.update(
        mean_wave_resource_util=sum(utils) / max(len(utils), 1),
        max_wave_resource_util=max(utils, default=0.0),
        same_class_overlap_frac=n_overlapped / max(n_in_multi, 1),
    )
    return out
