"""Wave construction + horizontal fusion.

DESIGN.md §2: on TPU, "N operators running concurrently on N streams" is
realized by packing independent operators into a **wave** and fusing
same-signature ops in a wave into ONE batched kernel (stacked GEMM /
grouped einsum).  This is the TPU-native mechanism that recovers the MXU
under-utilization the paper's Fig. 1 measures for small kernels.

Waves are built from the Opara launch order: walk ops in launch order and
place each op in the earliest wave after all of its producers' waves, capped
by ``max_lanes`` (the stream count).  Ops in one wave are mutually
independent by construction.

Fusion groups: within a wave, ops sharing ``fuse_sig`` (same kind + same
operand shapes/dtype) form one group executed as a single stacked op by the
capturer (or routed to the `branch_gemm` Pallas kernel on TPU).
"""
from __future__ import annotations

import dataclasses

from .graph import OpGraph
from .stream_alloc import StreamPlan


@dataclasses.dataclass
class Wave:
    index: int
    op_ids: list[int]
    fusion_groups: list[list[int]]  # partition of op_ids


@dataclasses.dataclass
class WaveSchedule:
    waves: list[Wave]

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    @property
    def n_fused_kernels(self) -> int:
        return sum(len(w.fusion_groups) for w in self.waves)

    def flat_order(self) -> list[int]:
        return [op for w in self.waves for op in w.op_ids]


def build_waves(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    max_lanes: int | None = None,
) -> WaveSchedule:
    """Greedy wave packing honoring the launch order.

    wave_of[op] = max(wave_of[producers]) + 1, but never earlier than an op
    launched before it *in the same stream* (streams stay FIFO), and each
    wave holds at most ``max_lanes`` ops (hardware lanes = streams).
    """
    if max_lanes is None:
        max_lanes = max(plan.n_streams, 1)
    wave_of: dict[int, int] = {}
    last_wave_in_stream: dict[int, int] = {}
    load: dict[int, int] = {}  # wave -> #ops
    for op in order:
        node = graph.nodes[op]
        w = 0
        for p in node.inputs:
            w = max(w, wave_of[p] + 1)
        s = plan.stream_of[op]
        if s in last_wave_in_stream:
            w = max(w, last_wave_in_stream[s] + 1)
        while load.get(w, 0) >= max_lanes:
            w += 1
        wave_of[op] = w
        last_wave_in_stream[s] = w
        load[w] = load.get(w, 0) + 1

    # single-pass bucketing: `order` is walked once; ops land in their wave
    # bucket in launch order (was an O(n_waves · n_ops) rescan).
    buckets: dict[int, list[int]] = {}
    for op in order:
        buckets.setdefault(wave_of[op], []).append(op)
    waves: list[Wave] = []
    for k in sorted(buckets):
        ops = buckets[k]
        waves.append(Wave(index=len(waves), op_ids=ops,
                          fusion_groups=_group(graph, ops)))
    return WaveSchedule(waves=waves)


def _group(graph: OpGraph, ops: list[int]) -> list[list[int]]:
    groups: dict[object, list[int]] = {}
    singles: list[list[int]] = []
    for op in ops:
        sig = graph.nodes[op].fuse_sig
        if sig is None:
            singles.append([op])
        else:
            groups.setdefault(sig, []).append(op)
    return list(groups.values()) + singles


def fusion_stats(sched: WaveSchedule) -> dict[str, float]:
    n_ops = sum(len(w.op_ids) for w in sched.waves)
    return {
        "n_ops": float(n_ops),
        "n_waves": float(sched.n_waves),
        "n_kernels_after_fusion": float(sched.n_fused_kernels),
        "mean_wave_width": n_ops / max(sched.n_waves, 1),
        "fusion_ratio": n_ops / max(sched.n_fused_kernels, 1),
    }
