"""Wave construction + horizontal fusion.

DESIGN.md §2: on TPU, "N operators running concurrently on N streams" is
realized by packing independent operators into a **wave** and fusing
same-signature ops in a wave into ONE batched kernel (stacked GEMM /
grouped einsum).  This is the TPU-native mechanism that recovers the MXU
under-utilization the paper's Fig. 1 measures for small kernels.

Waves are built from the Opara launch order: walk ops in launch order and
place each op in the earliest wave after all of its producers' waves, capped
by ``max_lanes`` (the stream count).  Ops in one wave are mutually
independent by construction.

Fusion groups: within a wave, ops sharing ``fuse_sig`` (same kind + same
operand shapes/dtype) form one group executed as a single stacked op by the
capturer (or routed to the `branch_gemm` Pallas kernel on TPU).

Two packers:

* :func:`build_waves` — launch-order bucketing capped by lane count only
  (the historical packer; still the ``repack=False`` baseline the autotuner
  compares against);
* :func:`repack_waves` — resource- and interference-aware: a wave admits an
  op only while the wave's summed ``resource_demand()`` stays under
  ``SimConfig.resource_cap``, and ready ops are drawn alternately from the
  memory- and compute-intensive pools (greedy complementary fill) so
  co-resident ops mix intensity classes and the simulator's same-class
  interference penalty stops firing on every wave.
"""
from __future__ import annotations

import dataclasses
import heapq

from .graph import IntensityClass, OpGraph
from .profiler import OpProfile
from .simulator import SimConfig
from .stream_alloc import StreamPlan


@dataclasses.dataclass
class Wave:
    index: int
    op_ids: list[int]
    fusion_groups: list[list[int]]  # partition of op_ids


@dataclasses.dataclass
class WaveSchedule:
    waves: list[Wave]

    @property
    def n_waves(self) -> int:
        return len(self.waves)

    @property
    def n_fused_kernels(self) -> int:
        return sum(len(w.fusion_groups) for w in self.waves)

    def flat_order(self) -> list[int]:
        return [op for w in self.waves for op in w.op_ids]


def build_waves(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    max_lanes: int | None = None,
) -> WaveSchedule:
    """Greedy wave packing honoring the launch order.

    wave_of[op] = max(wave_of[producers]) + 1, but never earlier than an op
    launched before it *in the same stream* (streams stay FIFO), and each
    wave holds at most ``max_lanes`` ops (hardware lanes = streams).
    """
    if max_lanes is None:
        max_lanes = max(plan.n_streams, 1)
    wave_of: dict[int, int] = {}
    last_wave_in_stream: dict[int, int] = {}
    load: dict[int, int] = {}  # wave -> #ops
    for op in order:
        node = graph.nodes[op]
        w = 0
        for p in node.inputs:
            w = max(w, wave_of[p] + 1)
        s = plan.stream_of[op]
        if s in last_wave_in_stream:
            w = max(w, last_wave_in_stream[s] + 1)
        while load.get(w, 0) >= max_lanes:
            w += 1
        wave_of[op] = w
        last_wave_in_stream[s] = w
        load[w] = load.get(w, 0) + 1

    # single-pass bucketing: `order` is walked once; ops land in their wave
    # bucket in launch order (was an O(n_waves · n_ops) rescan).
    buckets: dict[int, list[int]] = {}
    for op in order:
        buckets.setdefault(wave_of[op], []).append(op)
    waves: list[Wave] = []
    for k in sorted(buckets):
        ops = buckets[k]
        waves.append(Wave(index=len(waves), op_ids=ops,
                          fusion_groups=_group(graph, ops)))
    return WaveSchedule(waves=waves)


def repack_waves(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    profiles: dict[int, OpProfile],
    cfg: SimConfig | None = None,
    max_lanes: int | None = None,
    group: bool = True,
) -> WaveSchedule:
    """Resource- and interference-aware wave repacking.

    Waves are built one at a time from the ready frontier (ops whose
    producers all sit in *closed* waves), so dependencies hold by
    construction.  Admission into the open wave requires the wave's summed
    ``resource_demand()`` to stay under ``cfg.resource_cap`` (an op whose
    demand alone exceeds the cap gets a wave to itself — the simulator's
    empty-device admission rule).  Ready ops live in two pools keyed by
    intensity class; each draw prefers the class that balances the wave
    (greedy complementary fill), with the launch order breaking ties inside
    a pool — so Algorithm 2's resource-ascending order survives within each
    class while waves deliberately mix classes.

    Fusion groups are recomputed per repacked wave: same-signature ops that
    still co-reside stack into one kernel; ops a resource boundary separated
    fall back to per-branch steps in the capturer automatically.

    ``group=False`` skips the per-wave fusion grouping and emits empty
    ``fusion_groups`` — for callers that only rank candidate packings by
    ``flat_order()`` (autotune's repack leg, ``scheduler.refine``'s
    rebalance ladder) and regroup just the winner via
    :func:`regroup_waves`.
    """
    cfg = cfg or SimConfig()
    if max_lanes is None:
        max_lanes = max(plan.n_streams, 1)
    cap = cfg.resource_cap
    indeg = graph.indegree_map()
    succ = graph.unique_successors_map()

    # hot-loop precompute on dense op-id-indexed lists: the autotuner repacks
    # the same graph once per order candidate, so per-op attribute chases and
    # dict hashing add up on large graphs
    n = len(graph.nodes)
    pos = [0] * n
    for k, op in enumerate(order):
        pos[op] = k
    demand = [0.0] * n
    is_mem = [False] * n
    for op, p in profiles.items():
        demand[op] = p.cost.resource_demand()
        is_mem[op] = p.intensity is IntensityClass.MEMORY
    pool_mem: list[tuple[int, int]] = []
    pool_comp: list[tuple[int, int]] = []

    def push(op: int) -> None:
        heapq.heappush(pool_mem if is_mem[op] else pool_comp, (pos[op], op))

    for op, d in indeg.items():
        if d == 0:
            push(op)

    waves: list[Wave] = []
    while pool_mem or pool_comp:
        # fast path: a one-op ready frontier (the common case in chain-like
        # regions, where most waves come out singleton) — the general loop
        # below would reach the identical wave through pool selection,
        # skipped-list bookkeeping and a sort
        if len(pool_mem) + len(pool_comp) == 1:
            op = (pool_mem or pool_comp).pop()[1]
            waves.append(Wave(
                index=len(waves), op_ids=[op],
                fusion_groups=[[op]] if group else []))
            for s in succ[op]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    push(s)
            continue
        wave_ops: list[int] = []
        used = 0.0
        n_mem = n_comp = 0
        skipped_mem: list[tuple[int, int]] = []
        skipped_comp: list[tuple[int, int]] = []
        while len(wave_ops) < max_lanes:
            # complementary fill: draw from the class the wave has fewer of
            if n_mem <= n_comp:
                pool = pool_mem if pool_mem else pool_comp
            else:
                pool = pool_comp if pool_comp else pool_mem
            if not pool:
                break
            item = heapq.heappop(pool)
            op = item[1]
            mem = is_mem[op]
            if wave_ops and used + demand[op] > cap:
                # does not fit — defer to the next wave
                (skipped_mem if mem else skipped_comp).append(item)
                continue
            wave_ops.append(op)
            used += demand[op]
            if mem:
                n_mem += 1
            else:
                n_comp += 1
        for item in skipped_mem:
            heapq.heappush(pool_mem, item)
        for item in skipped_comp:
            heapq.heappush(pool_comp, item)
        # close the wave: successors of its ops become ready for the next
        wave_ops.sort(key=pos.__getitem__)   # list.__getitem__: op -> rank
        waves.append(Wave(index=len(waves), op_ids=wave_ops,
                          fusion_groups=_group(graph, wave_ops) if group
                          else []))
        for op in wave_ops:
            for s in succ[op]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    push(s)
    return WaveSchedule(waves=waves)


def _group(graph: OpGraph, ops: list[int]) -> list[list[int]]:
    groups: dict[object, list[int]] = {}
    singles: list[list[int]] = []
    for op in ops:
        sig = graph.nodes[op].fuse_sig
        if sig is None:
            singles.append([op])
        else:
            groups.setdefault(sig, []).append(op)
    return list(groups.values()) + singles


def regroup_waves(graph: OpGraph, sched: WaveSchedule) -> WaveSchedule:
    """Recompute fusion groups for every wave — the companion of
    ``repack_waves(..., group=False)``: rank candidates groupless, then
    regroup only the adopted winner."""
    return WaveSchedule(waves=[
        Wave(index=k, op_ids=list(w.op_ids),
             fusion_groups=_group(graph, w.op_ids))
        for k, w in enumerate(sched.waves)
    ])


class WaveEditor:
    """Incremental wave-schedule editing for ``scheduler.refine``.

    Holds a wave schedule as mutable per-wave op lists plus O(1)-updatable
    aggregates (op→wave map, per-wave summed ``resource_demand()`` and
    intensity-class counts), so dependency / resource-cap / lane feasibility
    of a local edit is checked in O(degree) instead of re-running a packer.

    Edits are *local*: every candidate replaces a contiguous slice of waves
    ``lists[start : start + n_replaced]`` with replacement lists, leaving
    everything before ``start`` untouched — which is exactly what lets the
    refiner re-estimate only the suffix via ``simulator.SweepState``
    checkpoints.  Candidates are **proposed** as plain data (no mutation);
    only an accepted edit is applied, after which the op→wave map and
    aggregates are rebuilt for the suffix.

    Fusion groups are cached per wave and recomputed only for waves an
    accepted edit touched (``schedule()`` emits the final
    :class:`WaveSchedule`).
    """

    def __init__(
        self,
        graph: OpGraph,
        waves: WaveSchedule,
        profiles: dict[int, OpProfile],
        cfg: SimConfig | None = None,
        max_lanes: int | None = None,
    ):
        cfg = cfg or SimConfig()
        self.graph = graph
        self.cap = cfg.resource_cap
        self.max_lanes = max_lanes          # None → unbounded lanes
        self.lists: list[list[int]] = [list(w.op_ids) for w in waves.waves
                                       if w.op_ids]
        self._groups: list[list[list[int]] | None] = [
            [list(grp) for grp in w.fusion_groups] for w in waves.waves
            if w.op_ids]
        self.succ = graph.unique_successors_map()
        n = len(graph.nodes)
        self.demand = [0.0] * n
        self.is_mem = [False] * n
        for op, p in profiles.items():
            self.demand[op] = p.cost.resource_demand()
            self.is_mem[op] = p.intensity is IntensityClass.MEMORY
        # rank in the seed wave-major order: the stable in-wave sort key
        self.pos = [0] * n
        for k, op in enumerate(op for w in self.lists for op in w):
            self.pos[op] = k
        self.wave_of = [0] * n
        self.wdemand: list[float] = []
        self.wmem: list[int] = []
        self.wcomp: list[int] = []
        self._reindex(0)
        self.n_edits = 0

    # -- state ---------------------------------------------------------------
    @property
    def n_waves(self) -> int:
        return len(self.lists)

    def flat_order(self) -> list[int]:
        return [op for w in self.lists for op in w]

    def _reindex(self, start: int) -> None:
        del self.wdemand[start:]
        del self.wmem[start:]
        del self.wcomp[start:]
        for k in range(start, len(self.lists)):
            d, m, c = 0.0, 0, 0
            for op in self.lists[k]:
                self.wave_of[op] = k
                d += self.demand[op]
                if self.is_mem[op]:
                    m += 1
                else:
                    c += 1
            self.wdemand.append(d)
            self.wmem.append(m)
            self.wcomp.append(c)

    def apply(self, start: int, n_replaced: int,
              replacement: list[list[int]]) -> None:
        """Commit an accepted edit: splice ``replacement`` (empty waves are
        dropped) over ``lists[start:start+n_replaced]`` and rebuild the
        op→wave map and aggregates for the suffix."""
        repl = [list(w) for w in replacement if w]
        self.lists[start:start + n_replaced] = repl
        self._groups[start:start + n_replaced] = [None] * len(repl)
        self._reindex(start)
        self.n_edits += 1

    # -- candidate edits (pure proposals, no mutation) -----------------------
    def _fits_lanes(self, n_ops: int) -> bool:
        return self.max_lanes is None or n_ops <= self.max_lanes

    def _fits_cap(self, total_demand: float, n_ops: int) -> bool:
        # a lone op larger than the cap runs alone (simulate()'s
        # empty-device admission), so singleton waves are always legal
        return n_ops <= 1 or total_demand <= self.cap

    def _interleave(self, ops: list[int]) -> list[int]:
        """Class-alternating in-wave order (the repacker's complementary
        fill): under head-of-line dispatch, neighbors in the launch order
        are the ops most likely to overlap, so alternating classes is what
        keeps the same-class interference penalty from firing."""
        mem = sorted((o for o in ops if self.is_mem[o]), key=self.pos.__getitem__)
        comp = sorted((o for o in ops if not self.is_mem[o]), key=self.pos.__getitem__)
        out: list[int] = []
        take_mem = bool(mem) and (not comp or self.pos[mem[0]] <= self.pos[comp[0]])
        while mem and comp:
            out.append(mem.pop(0) if take_mem else comp.pop(0))
            take_mem = not take_mem
        return out + mem + comp

    def merge_candidate(self, j: int) -> list[list[int]] | None:
        """Merge wave ``j+1`` into wave ``j`` (class-interleaved)."""
        a, b = self.lists[j], self.lists[j + 1]
        if not self._fits_lanes(len(a) + len(b)):
            return None
        if not self._fits_cap(self.wdemand[j] + self.wdemand[j + 1],
                              len(a) + len(b)):
            return None
        nodes = self.graph.nodes
        for op in b:            # no edge may cross the vanished boundary
            for p in nodes[op].inputs:
                if self.wave_of[p] == j:
                    return None
        return [self._interleave(a + b)]

    def migrate_candidates(self, j: int, limit: int = 2) -> list[list[list[int]]]:
        """Pull ops of wave ``j+1`` forward into wave ``j``, minority
        intensity class first (each proposal moves ONE op)."""
        a, b = self.lists[j], self.lists[j + 1]
        if not self._fits_lanes(len(a) + 1) or len(b) <= 1:
            return []
        nodes = self.graph.nodes
        prefer_mem = self.wmem[j] <= self.wcomp[j]
        movable = [
            op for op in b
            if self._fits_cap(self.wdemand[j] + self.demand[op], len(a) + 1)
            and not any(self.wave_of[p] == j for p in nodes[op].inputs)
        ]
        movable.sort(key=lambda o: (self.is_mem[o] != prefer_mem, self.pos[o]))
        key = self.pos.__getitem__
        return [[sorted(a + [op], key=key), [o for o in b if o != op]]
                for op in movable[:limit]]

    def push_candidates(self, j: int, limit: int = 1) -> list[list[list[int]]]:
        """Defer ops of wave ``j`` into wave ``j+1`` (class rebalancing in
        the other direction — e.g. to break up a same-class pile-up)."""
        a, b = self.lists[j], self.lists[j + 1]
        if not self._fits_lanes(len(b) + 1) or len(a) <= 1:
            return []
        prefer_mem = self.wmem[j + 1] <= self.wcomp[j + 1]
        movable = [
            op for op in a
            if self._fits_cap(self.wdemand[j + 1] + self.demand[op], len(b) + 1)
            and not any(self.wave_of[s] == j + 1 for s in self.succ[op])
        ]
        movable.sort(key=lambda o: (self.is_mem[o] != prefer_mem, self.pos[o]))
        key = self.pos.__getitem__
        return [[[o for o in a if o != op], sorted(b + [op], key=key)]
                for op in movable[:limit]]

    def exchange_candidate(self, j: int) -> list[list[int]] | None:
        """Exchange waves ``j`` and ``j+1`` wholesale — a pure reordering of
        independent schedule segments (no membership change, so caps and
        lanes are untouched); legal iff no edge crosses the boundary.  This
        is the move that works inside singleton-wave chain regions, where
        membership edits are dependency-blocked."""
        a, b = self.lists[j], self.lists[j + 1]
        nodes = self.graph.nodes
        for op in b:
            for p in nodes[op].inputs:
                if self.wave_of[p] == j:
                    return None
        return [list(b), list(a)]

    def swap_candidate(self, j: int) -> list[list[int]] | None:
        """Exchange a cross-class pair between waves ``j`` and ``j+1`` —
        the intensity-class rebalancing move."""
        a, b = self.lists[j], self.lists[j + 1]
        nodes = self.graph.nodes
        for x in a:
            if any(self.wave_of[s] == j + 1 for s in self.succ[x]):
                continue
            for y in b:
                if self.is_mem[x] == self.is_mem[y]:
                    continue
                if any(self.wave_of[p] == j for p in nodes[y].inputs):
                    continue
                da = self.wdemand[j] - self.demand[x] + self.demand[y]
                db = self.wdemand[j + 1] - self.demand[y] + self.demand[x]
                if not (self._fits_cap(da, len(a)) and self._fits_cap(db, len(b))):
                    continue
                key = self.pos.__getitem__
                return [sorted([o for o in a if o != x] + [y], key=key),
                        sorted([o for o in b if o != y] + [x], key=key)]
        return None

    def split_candidate(self, j: int) -> list[list[int]] | None:
        """Split wave ``j`` at a class boundary (or halve an over-cap wave
        that an earlier packer admitted)."""
        ops = self.lists[j]
        if len(ops) < 2:
            return None
        key = self.pos.__getitem__
        mem = sorted((o for o in ops if self.is_mem[o]), key=key)
        comp = sorted((o for o in ops if not self.is_mem[o]), key=key)
        if mem and comp:
            return [mem, comp]
        if self.wdemand[j] > self.cap:
            mid = len(ops) // 2
            both = sorted(ops, key=key)
            return [both[:mid], both[mid:]]
        return None

    def reorder_candidate(self, j: int) -> list[list[int]] | None:
        """Class-alternating re-order *within* wave ``j`` (waves unchanged —
        only the launch order the sweep sees)."""
        ops = self.lists[j]
        if len(ops) < 2:
            return None
        mixed = self._interleave(ops)
        return [mixed] if mixed != ops else None

    # -- emit ----------------------------------------------------------------
    def schedule(self) -> WaveSchedule:
        waves = [
            Wave(index=k, op_ids=list(ops),
                 fusion_groups=(self._groups[k] if self._groups[k] is not None
                                else _group(self.graph, ops)))
            for k, ops in enumerate(self.lists)
        ]
        return WaveSchedule(waves=waves)


def fusion_stats(
    sched: WaveSchedule,
    profiles: dict[int, OpProfile] | None = None,
    resource_cap: float | None = None,
) -> dict[str, float]:
    """Packing statistics; with ``profiles`` also repack-efficacy metrics.

    ``mean/max_wave_resource_util`` — per-wave summed ``resource_demand()``
    over ``resource_cap`` (how full the pool is packed; >1 on a single-op
    wave means an op that alone exceeds the cap).  ``same_class_overlap_frac``
    — fraction of ops in multi-op waves that share the wave with another op
    of their own intensity class, i.e. how often the simulator's same-class
    interference penalty fires; the repacker's complementary fill drives it
    down.
    """
    n_ops = sum(len(w.op_ids) for w in sched.waves)
    out = {
        "n_ops": float(n_ops),
        "n_waves": float(sched.n_waves),
        "n_kernels_after_fusion": float(sched.n_fused_kernels),
        "mean_wave_width": n_ops / max(sched.n_waves, 1),
        "fusion_ratio": n_ops / max(sched.n_fused_kernels, 1),
    }
    if profiles is None:
        return out
    if resource_cap is None:
        resource_cap = SimConfig().resource_cap
    utils: list[float] = []
    n_overlapped = 0
    n_in_multi = 0
    for w in sched.waves:
        utils.append(
            sum(profiles[o].cost.resource_demand() for o in w.op_ids)
            / max(resource_cap, 1e-9))
        if len(w.op_ids) < 2:
            continue
        n_in_multi += len(w.op_ids)
        per_class = {}
        for o in w.op_ids:
            c = profiles[o].intensity
            per_class[c] = per_class.get(c, 0) + 1
        n_overlapped += sum(k for k in per_class.values() if k >= 2)
    out.update(
        mean_wave_resource_util=sum(utils) / max(len(utils), 1),
        max_wave_resource_util=max(utils, default=0.0),
        same_class_overlap_frac=n_overlapped / max(n_in_multi, 1),
    )
    return out
