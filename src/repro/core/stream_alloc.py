"""Stream Allocator — faithful implementation of the paper's Algorithm 1.

Key idea (paper §3.1): allocate parallelizable operators to as many streams
as possible (minimize ``h(A)``) while chaining each operator onto the stream
of a predecessor whenever it is that predecessor's *first* successor, so the
number of cross-stream synchronizations stays low (minimize ``g(A)``).

Complexity: O(|V| · max_width) ≈ O(n) since DAG width is small (paper §5.3).

On TPU a "stream" is an execution lane (DESIGN.md §2): ops in one lane are
totally ordered; ops in different lanes may be packed into the same wave by
the capturer.  Cross-lane edges are exactly the events/waits the paper counts
as synchronization overhead, so we expose :func:`count_syncs` for the
``g(A)`` proxy used in benchmarks.
"""
from __future__ import annotations

import dataclasses

from .graph import OpGraph


@dataclasses.dataclass
class StreamPlan:
    """Result of stream allocation.

    stream_of: op_id -> stream index (0-based).
    n_streams: total streams launched.
    """

    stream_of: dict[int, int]
    n_streams: int

    def ops_in_stream(self, s: int) -> list[int]:
        return sorted(i for i, v in self.stream_of.items() if v == s)


def allocate_streams(graph: OpGraph) -> StreamPlan:
    """Algorithm 1, line-by-line.

    Iterate operators in topological (insertion) order; for each operator v,
    scan its predecessors p: if v is p's first successor, inherit p's stream;
    otherwise open a fresh stream.
    """
    # first_successor[p] = the successor of p with the smallest topological
    # position (the paper's "first successor" — first in enumeration order).
    first_successor: dict[int, int] = {}
    order = graph.topological_order()
    pos = {i: k for k, i in enumerate(order)}
    for i in order:
        for p in graph.nodes[i].inputs:
            cur = first_successor.get(p)
            if cur is None or pos[i] < pos[cur]:
                first_successor[p] = i

    stream_of: dict[int, int] = {}
    n_streams = 0
    for v in order:  # line 2: enumerate in topological sorting order
        node = graph.nodes[v]
        assigned = False
        for p in node.inputs:  # line 3: iterate predecessors
            if first_successor.get(p) == v:  # line 4: v is first successor
                stream_of[v] = stream_of[p]  # line 5: same stream as p
                assigned = True
                break  # line 6
        if not assigned:  # lines 9-11: new stream
            stream_of[v] = n_streams
            n_streams += 1
    return StreamPlan(stream_of=stream_of, n_streams=n_streams)


def count_syncs(graph: OpGraph, plan: StreamPlan) -> int:
    """Number of cross-stream dependency edges = event/wait pairs that the
    Graph Capturer must insert (the paper's g(A) proxy)."""
    syncs = 0
    for node in graph:
        for p in set(node.inputs):
            if plan.stream_of[p] != plan.stream_of[node.op_id]:
                syncs += 1
    return syncs


def validate_plan(graph: OpGraph, plan: StreamPlan) -> None:
    """Invariants under test (hypothesis):
    * every op is assigned to exactly one stream (paper constraint Eq. 5);
    * ops sharing a stream are totally ordered by dependencies OR by
      topological position (streams are FIFO queues — no reordering);
    * stream count never exceeds |V| and is >= max antichain that uses roots.
    """
    assert set(plan.stream_of) == set(graph.nodes), "every op exactly one stream"
    assert 0 < plan.n_streams <= max(1, len(graph))
    for s in range(plan.n_streams):
        ops = plan.ops_in_stream(s)
        assert ops == sorted(ops)
