"""Configuration-scoped compilation sessions.

``Session`` is the one object a user hands a model graph to::

    from repro.core import Session, SessionConfig

    sess = Session(SessionConfig(autotune=True))
    model = sess.compile(graph, inputs=profiling_inputs)
    outs = model({"tokens": x})
    model.explain()          # per-stage timings + cache provenance

A session bundles every knob that used to travel as a kwarg cross-product
through ``api.plan`` / ``api.optimize`` / ``api.calibrate`` (hardware,
policies, simulator config, autotune, calibration and cache sizing) into one
frozen :class:`SessionConfig`, and owns ALL cache state: the plan,
executable and calibration LRUs plus the calibration disk tier live on the
session, not in module globals.  Two sessions never share entries; serving
fleets, benchmarks and tests each get an isolated, composable entry point,
and new configuration axes (multi-device lanes, IOS-style refinement
schedules) extend ``SessionConfig`` instead of widening three function
signatures.

The legacy module functions in :mod:`repro.core.api` remain as thin shims
that delegate to a process-wide :func:`default_session` (so existing callers
keep their amortization behavior) and emit ``DeprecationWarning`` when
passed the superseded configuration kwargs.

Cache semantics are unchanged from the module-global era — see the table in
``docs/api.md``:

* **plan** — keyed by the structural :func:`graph_signature` (policies, hw,
  lanes, sim_cfg and the hydrated calibration fingerprint); a hit on a
  different graph object is rebound (op_ids are structural).
* **executable** — plan key + a weights fingerprint (``identity`` or
  ``content``) + output ids + kernel route.
* **calibration** — (node_signature, input_signature, hw.name), memory LRU
  over a JSON disk tier under ``SessionConfig.calib_dir`` (default
  ``$REPRO_CALIB_DIR`` or ``~/.cache/repro/calib``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
from collections import OrderedDict
from typing import Any, Mapping

import numpy as np

from ..runtime.faults import FaultPlan, get_active as _active_faults
from ..runtime.guard import DegradationLog, retry_with_backoff
from .capture import CapturedGraph
from .graph import OpGraph
from .launch_order import ORDER_POLICIES
from .profiler import (
    HardwareSpec,
    ModelProfiler,
    ProfileTable,
    V5E,
    apply_profile,
)
from .scheduler import (
    ALLOC_POLICIES,
    RefineConfig,
    SchedulePlan,
    _normalize_refine,
    compile_plan,
    schedule,
)
from .scheduler import autotune as autotune_schedule
from .simulator import SimConfig

_CACHE_SIZE = 64          # default LRU bound (``SessionConfig.cache_size``)
_CALIB_DIR_ENV = "REPRO_CALIB_DIR"
_DISK_CACHE_MAX = 512     # default disk-tier bound

_STAT_KEYS = ("plan_hits", "plan_misses", "exec_hits", "exec_misses",
              "calib_hits", "calib_misses", "calib_disk_hits",
              # graceful-degradation provenance (docs/robustness.md):
              "calib_retries",             # measure re-attempts that happened
              "calib_degraded_analytic",   # measured→analytic degradations
              "calib_disk_errors",         # disk tier read/write failures
              "degraded_routes")           # capture/plan fallback edges taken

# fault-proof sentinel for ladder-floor paths: an empty plan fires nothing
# AND suppresses the process-wide/env plan (passing None would re-resolve it)
_NO_FAULTS = FaultPlan()


# =========================================================================
# Configuration
# =========================================================================

@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Everything a compilation pipeline reads, bundled and immutable.

    Frozen + hashable: a config can serve as a cache-key component and two
    sessions built from equal configs behave identically (but still never
    share cache state — isolation is per ``Session`` instance).
    """

    # -- scheduling ---------------------------------------------------------
    hw: HardwareSpec = V5E
    alloc_policy: str = "opara"
    order_policy: str = "opara"
    max_lanes: int | None = None
    autotune: bool = False                # simulator-guided {alloc}×{order}×{repack}
    refine: bool | RefineConfig = False   # IOS-style iterative refinement of
                                          # the autotune winner (needs autotune)
    sim_cfg: SimConfig | None = None      # cost model for autotune / repack
    # -- capture / executable ----------------------------------------------
    gemm_kernel: str = "auto"             # auto | pallas | vmap
    weights_key: str = "identity"         # identity | content
    # -- measured-profile calibration --------------------------------------
    calibration_repeats: int = 3
    load_calibration: bool = True         # consult the disk tier
    calib_dir: str | None = None          # None → $REPRO_CALIB_DIR / default
    # -- graceful degradation (docs/robustness.md) --------------------------
    calib_retries: int = 2                # measure re-attempts before the
                                          # analytic-profile degrade
    calib_backoff_s: float = 0.0          # base retry backoff (doubles per
                                          # attempt; clock is injectable via
                                          # Session._sleep, 0 = no waiting)
    fault_plan: FaultPlan | None = None   # per-session injection plan (None
                                          # → $REPRO_FAULT_PLAN, if set)
    # -- cache sizing -------------------------------------------------------
    cache_size: int = _CACHE_SIZE         # per-session LRU bound (each tier)
    disk_cache_entries: int = _DISK_CACHE_MAX

    def __post_init__(self) -> None:
        if self.alloc_policy not in ALLOC_POLICIES:
            raise ValueError(f"unknown alloc_policy {self.alloc_policy!r}")
        if self.order_policy not in ORDER_POLICIES:
            raise ValueError(f"unknown order_policy {self.order_policy!r}")
        if self.weights_key not in ("identity", "content"):
            raise ValueError(f"unknown weights_key {self.weights_key!r}")
        if self.gemm_kernel not in ("auto", "pallas", "vmap"):
            raise ValueError(f"unknown gemm_kernel {self.gemm_kernel!r}")
        if self.cache_size < 1:
            raise ValueError("cache_size must be >= 1")
        if self.calib_retries < 0:
            raise ValueError("calib_retries must be >= 0")
        if self.calib_backoff_s < 0:
            raise ValueError("calib_backoff_s must be >= 0")
        # raises TypeError on junk values; None means refinement is off
        if _normalize_refine(self.refine) is not None and not self.autotune:
            raise ValueError("refine requires autotune=True (refinement "
                             "starts from the autotune winner)")


# =========================================================================
# Cache keys (pure functions of graph + config — shared with api shims)
# =========================================================================

def graph_signature(
    graph: OpGraph,
    alloc_policy: str = "opara",
    order_policy: str = "opara",
    hw: HardwareSpec = V5E,
    max_lanes: int | None = None,
    sim_cfg: SimConfig | None = None,
) -> tuple:
    """Structural cache key: everything scheduling reads, nothing it doesn't.

    Per node: kind, edges, output shape/dtype, fusion signature, analytic
    cost fields (including the derived ``resource_demand()`` the repacker
    admits on), payload marker and const shapes (capture's stackability
    inputs) — see :meth:`OpGraph.node_signature`, which memoizes the node
    part per graph version.  The hydrated calibration fingerprint (if any)
    is a separate component: measured timings change schedules, but they are
    not part of the graph's structural identity.  ``sim_cfg`` (a frozen,
    hashable :class:`SimConfig`) joins the key for autotuned plans — the
    cost model's resource cap and penalties steer the search, so two
    configs must never share a tuned plan.  Weight *values* and payload
    identities are deliberately excluded — they cannot change a schedule.

    The per-node part enters as :meth:`OpGraph.signature_digest` (memoized
    sha1 of the full node tuple) so cache probes stay O(1) in graph size.
    """
    return (graph.signature_digest(), graph.calibration_fp,
            alloc_policy, order_policy, hw, max_lanes, sim_cfg)


def calibration_key(graph: OpGraph, inputs: Mapping[int, Any],
                    hw: HardwareSpec = V5E) -> tuple:
    """Calibration-cache key: structure × input geometry × hardware."""
    return (graph.node_signature(), graph.input_signature(inputs), hw.name)


def _content_digest(a: Any) -> tuple:
    arr = np.asarray(a)
    return (str(arr.dtype), arr.shape,
            hashlib.sha1(arr.tobytes()).hexdigest())


def _weights_fingerprint(graph: OpGraph, weights_key: str = "identity") -> tuple:
    """Fingerprint of every payload + const array (executable cache key part).

    ``identity`` — ``id()`` of callables and arrays (fast; live-object safe
    because cached executables pin their graph).  ``content`` — code-object
    identity for callables (stable across re-created lambdas from the same
    source) + a byte digest of each const, so recreated-but-equal arrays
    (checkpoint reload) share the executable.
    """
    if weights_key == "identity":
        return tuple(
            (id(n.fn), tuple(id(c) for c in n.meta.get("consts", ())))
            for n in graph
        )
    if weights_key == "content":
        return tuple(
            (id(getattr(n.fn, "__code__", n.fn)),
             tuple(_content_digest(c) for c in n.meta.get("consts", ())))
            for n in graph
        )
    raise ValueError(f"unknown weights_key {weights_key!r}")


def _autotune_key_parts(sim_cfg: SimConfig | None) -> tuple[str, str, SimConfig]:
    """The autotuned-plan cache-key normalization, shared by the plan and
    executable paths so their keys can never drift: policy slots carry a
    sentinel (the tuner picks the real policies) and sim_cfg defaults the
    same way :func:`repro.core.scheduler.autotune` does, so an explicit
    default ``SimConfig()`` shares the implicit-``None`` entry."""
    return "__autotune__", "__autotune__", sim_cfg or SimConfig()


def _policy_parts(cfg: SessionConfig) -> tuple[str, str, SimConfig | None]:
    """(alloc, order, sim_cfg) as they enter cache keys and the scheduler —
    normalized through :func:`_autotune_key_parts` under autotune.  The ONE
    source for both the plan-cache and executable-cache keys, so they stay
    byte-identical by construction."""
    if cfg.autotune:
        return _autotune_key_parts(cfg.sim_cfg)
    return cfg.alloc_policy, cfg.order_policy, cfg.sim_cfg


def _plan_key(graph: OpGraph, cfg: SessionConfig) -> tuple:
    alloc, order, sim_cfg = _policy_parts(cfg)
    # Refinement changes the plan an autotune search returns, so the
    # normalized RefineConfig (frozen + hashable; ``True`` and an explicit
    # default config normalize identically) joins the key.  Off — or
    # single-policy scheduling, which never refines — contributes ``None``.
    refine = _normalize_refine(cfg.refine) if cfg.autotune else None
    return graph_signature(graph, alloc, order, cfg.hw,
                           cfg.max_lanes, sim_cfg) + (refine,)


# =========================================================================
# LRU + calibration disk tier primitives
# =========================================================================

def _lru_get(cache: OrderedDict, key: tuple) -> Any | None:
    if key in cache:
        cache.move_to_end(key)
        return cache[key]
    return None


def _lru_put(cache: OrderedDict, key: tuple, value: Any,
             max_entries: int = _CACHE_SIZE) -> None:
    cache[key] = value
    cache.move_to_end(key)
    while len(cache) > max_entries:
        cache.popitem(last=False)


def _calib_dir(override: str | None = None) -> str:
    return override or os.environ.get(_CALIB_DIR_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "calib")


def _calib_path(key: tuple, dirpath: str | None = None) -> str:
    digest = hashlib.sha1(repr(key).encode()).hexdigest()
    return os.path.join(_calib_dir(dirpath), f"{digest}.json")


def _calib_disk_load(key: tuple, dirpath: str | None = None,
                     faults: FaultPlan | None = None) -> ProfileTable | None:
    """Read one disk-tier entry.  Corruption-safe by construction: torn or
    mangled JSON (real, or injected via the ``calib_disk_read`` corrupt
    mode) parses to ``None`` → the caller treats it as a miss.  A
    raise-mode fault propagates (the session's guard counts it and degrades
    to the memory tier)."""
    try:
        with open(_calib_path(key, dirpath)) as f:
            raw = f.read()
        if faults is not None:
            raw = faults.fire("calib_disk_read", payload=raw)
        doc = json.loads(raw)
    except (OSError, ValueError):
        return None
    if not isinstance(doc, dict) or doc.get("key") != repr(key):
        return None               # sha1 collision / stale format / corrupt
    try:
        return ProfileTable(
            hw_name=doc["hw_name"],
            measured_us=tuple((int(i), float(us))
                              for i, us in doc["measured_us"]))
    except (KeyError, TypeError, ValueError):
        return None               # structurally corrupt entry → miss


def _calib_disk_store(key: tuple, table: ProfileTable,
                      dirpath: str | None = None,
                      max_entries: int = _DISK_CACHE_MAX,
                      faults: FaultPlan | None = None) -> None:
    """Best-effort atomic write; serving must never fail on a full disk.

    The write is tmp-file + ``os.replace``, so a crash mid-write (including
    an injected ``calib_disk_write`` raise) never publishes a partial entry
    and never strands the temp file.  Corrupt-mode injection mangles the
    payload *content* — the published entry is then atomically whole but
    unparseable, which the read path survives as a miss."""
    d = _calib_dir(dirpath)
    tmp = None
    try:
        payload = json.dumps({"key": repr(key), "hw_name": table.hw_name,
                              "measured_us": [list(m)
                                              for m in table.measured_us]})
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        if faults is not None:
            payload = faults.fire("calib_disk_write", payload=payload)
        with os.fdopen(fd, "w") as f:
            f.write(payload)
        os.replace(tmp, _calib_path(key, dirpath))
        tmp = None
        _calib_disk_evict(d, max_entries)
    except OSError:
        pass                      # full disk / permissions: memory tier only
    finally:                      # injected faults reach the session's guard
        if tmp is not None:       # never strand the temp file
            try:
                os.unlink(tmp)
            except OSError:
                pass


def _calib_disk_evict(d: str, max_entries: int = _DISK_CACHE_MAX) -> None:
    """Drop oldest-mtime entries beyond ``max_entries`` (runs per store —
    rare: stores happen only on full cache misses)."""
    try:
        entries = [e for e in os.scandir(d) if e.name.endswith(".json")]
        if len(entries) <= max_entries:
            return
        entries.sort(key=lambda e: e.stat().st_mtime)
        for e in entries[:len(entries) - max_entries]:
            try:
                os.unlink(e.path)
            except OSError:
                pass
    except OSError:
        pass


# =========================================================================
# CompiledModel
# =========================================================================

@dataclasses.dataclass
class CompiledModel:
    """Handle returned by :meth:`Session.compile`: plan + executable +
    build provenance.  Calling it runs the fused program.

    Holds the (immutable) :class:`SessionConfig` it was built under — NOT
    the session itself, so a long-lived model handle never pins a discarded
    session's caches alive."""

    config: SessionConfig
    graph: OpGraph
    plan: SchedulePlan
    executable: CapturedGraph
    # "calibration": measured | memory | disk | analytic (degraded) | off
    # "plan" / "executable": hit | miss | degraded
    provenance: dict[str, str]
    timings_ms: dict[str, float]          # calibrate / plan / compile / total
    # structured fallback events recorded while THIS model was built
    # (site / action / reason dicts — see docs/robustness.md)
    degradations: list[dict[str, str]] = dataclasses.field(
        default_factory=list)

    def __call__(self, inputs: Mapping[str | int, Any]) -> list:
        return self.executable(inputs)

    @property
    def stats(self) -> dict[str, float]:
        """Packing/scheduling efficacy of the underlying plan."""
        return self.plan.stats()

    def explain(self) -> dict[str, Any]:
        """Where this executable came from: per-stage wall times and, for
        each cache tier, whether the build hit or missed (and for
        calibration, whether the hit came from memory or disk)."""
        cfg = self.config
        p = self.plan
        return {
            "graph": {"name": self.graph.name, "n_ops": len(self.graph)},
            "config": {
                "hw": cfg.hw.name,
                "alloc_policy": p.alloc_policy,   # tuned value under autotune
                "order_policy": p.order_policy,
                "autotune": cfg.autotune,
                "refine": _normalize_refine(cfg.refine) is not None,
                "gemm_kernel": cfg.gemm_kernel,
                "weights_key": cfg.weights_key,
            },
            "cache": dict(self.provenance),
            # build-time fallbacks PLUS any call-time jitted→sequential
            # rescue the executable recorded since (live view)
            "degraded": (list(self.degradations)
                         + [e.as_dict()
                            for e in self.executable.degradations.events
                            if e.site == "execute"]),
            "stages_ms": dict(
                self.timings_ms,
                alloc=p.alloc_time_ms,
                order=p.order_time_ms,
                profile=p.profile_time_ms,
                waves=p.wave_time_ms,
                autotune=p.autotune_ms,
                refine=p.refine_ms,
            ),
            "schedule": {
                "n_streams": p.n_streams,
                "n_waves": p.waves.n_waves,
                "repacked": p.repacked,
                "refined": p.refined,
                "refine_iters": p.refine_iters,
                "refine_delta_us": p.refine_delta_us,
                "est_makespan_us": p.est_makespan_us,
            },
        }


# =========================================================================
# Session
# =========================================================================

class Session:
    """Configuration-scoped compiler with isolated cache state.

    ``Session(cfg)`` or ``Session(autotune=True, ...)`` (kwargs build /
    override a :class:`SessionConfig`).  All methods read configuration from
    ``self.config`` only; per-call data (graphs, profiling inputs, output
    ids) stays in the call.
    """

    def __init__(self, config: SessionConfig | None = None, **overrides: Any):
        base = config if config is not None else SessionConfig()
        self.config = (dataclasses.replace(base, **overrides)
                       if overrides else base)
        self._plan_cache: OrderedDict[tuple, SchedulePlan] = OrderedDict()
        self._exec_cache: OrderedDict[tuple, CapturedGraph] = OrderedDict()
        self._calib_cache: OrderedDict[tuple, ProfileTable] = OrderedDict()
        self._stats = {k: 0 for k in _STAT_KEYS}
        # structured record of every fallback this session took
        self.guard_log = DegradationLog()
        # injectable clock for calibration retry backoff (tests swap it)
        self._sleep = time.sleep

    @property
    def faults(self) -> FaultPlan | None:
        """The armed injection plan: per-session config wins, else the
        process-wide/env plan (resolved lazily so chaos harnesses can arm
        ``$REPRO_FAULT_PLAN`` around an existing session)."""
        return (self.config.fault_plan if self.config.fault_plan is not None
                else _active_faults())

    def note_degradation(self, site: str, action: str, reason: str,
                         warn: bool = True) -> None:
        """Record an externally detected degradation against this session
        (e.g. the serving engine's measured→analytic calibration fallback)
        so ``cache_stats()`` provenance stays complete."""
        self.guard_log.note(site, action, reason, warn=warn)
        if site == "calibration_measure":
            self._stats["calib_degraded_analytic"] += 1
        elif site in ("calib_disk_read", "calib_disk_write"):
            self._stats["calib_disk_errors"] += 1
        else:
            self._stats["degraded_routes"] += 1

    # -- calibration --------------------------------------------------------
    def calibrate(self, graph: OpGraph, inputs: Mapping[int, Any],
                  repeats: int | None = None,
                  load: bool | None = None) -> ProfileTable | None:
        """Hydrate ``graph`` with a measured profile, timing at most once.

        Memory-cache hit → the stored table is re-applied (zero re-timing);
        memory miss → the disk tier is consulted (``load=False`` — or
        ``SessionConfig.load_calibration=False`` — skips it, e.g. after a
        runtime upgrade invalidates persisted timings); full miss → one
        profiling inference (the paper's "profile each DNN inference only
        once"), stored to both tiers for every structurally identical graph
        — including one built by a later process — that follows.

        If measurement keeps failing after ``SessionConfig.calib_retries``
        re-attempts, the session degrades to the analytic cost model:
        ``None`` is returned, one :class:`DegradationWarning` is emitted and
        ``cache_stats()["calib_degraded_analytic"]`` increments — scheduling
        proceeds on analytic costs instead of crashing the build.
        """
        table, _ = self._calibrate(graph, inputs, self.config,
                                   repeats=repeats, load=load)
        return table

    def _calibrate(self, graph: OpGraph, inputs: Mapping[int, Any],
                   cfg: SessionConfig, repeats: int | None = None,
                   load: bool | None = None) -> tuple[ProfileTable | None, str]:
        repeats = cfg.calibration_repeats if repeats is None else repeats
        load = cfg.load_calibration if load is None else load
        key = calibration_key(graph, inputs, cfg.hw)
        faults = self.faults
        provenance = "memory"
        table = _lru_get(self._calib_cache, key)
        if table is not None:
            self._stats["calib_hits"] += 1            # memory-tier hit
        else:
            disk = None
            if load:
                try:
                    disk = _calib_disk_load(key, cfg.calib_dir, faults=faults)
                except Exception as exc:              # injected / exotic I/O
                    self._stats["calib_disk_errors"] += 1
                    self.guard_log.note("calib_disk_read",
                                        "disk->memory-tier", repr(exc))
            if disk is not None:
                self._stats["calib_disk_hits"] += 1   # disk-tier hit
                provenance = "disk"
                table = disk
                _lru_put(self._calib_cache, key, table, cfg.cache_size)
            else:
                table, provenance = self._measure_or_degrade(
                    graph, inputs, cfg, key, repeats, faults)
        if table is not None and graph.calibration_fp != table.fingerprint:
            apply_profile(graph, table)
        return table, provenance

    def _measure_or_degrade(self, graph: OpGraph, inputs: Mapping[int, Any],
                            cfg: SessionConfig, key: tuple, repeats: int,
                            faults: FaultPlan | None,
                            ) -> tuple[ProfileTable | None, str]:
        """Full-miss rung of the calibration ladder: measure (with bounded
        retry + backoff), then — only if every attempt failed — degrade to
        the analytic cost model rather than fail the build."""
        self._stats["calib_misses"] += 1

        def _measure() -> ProfileTable:
            if faults is not None:
                faults.fire("calibration_measure")
            return ModelProfiler(cfg.hw).measure(graph, inputs,
                                                 repeats=repeats)

        def _on_retry(attempt: int, exc: BaseException) -> None:
            self._stats["calib_retries"] += 1
            self.guard_log.note("calibration_measure",
                                f"retry#{attempt + 1}", repr(exc))

        try:
            table = retry_with_backoff(_measure, retries=cfg.calib_retries,
                                       base_delay_s=cfg.calib_backoff_s,
                                       sleep=self._sleep, on_retry=_on_retry)
        except Exception as exc:
            self._stats["calib_degraded_analytic"] += 1
            self.guard_log.note("calibration_measure", "measured->analytic",
                                repr(exc), warn=True)
            return None, "analytic (degraded)"
        _lru_put(self._calib_cache, key, table, cfg.cache_size)
        try:
            _calib_disk_store(key, table, cfg.calib_dir,
                              cfg.disk_cache_entries, faults=faults)
        except Exception as exc:                      # injected write fault
            self._stats["calib_disk_errors"] += 1
            self.guard_log.note("calib_disk_write", "disk->memory-tier",
                                repr(exc))
        return table, "measured"

    # -- planning -----------------------------------------------------------
    def plan(self, graph: OpGraph,
             measured_inputs: Mapping[int, Any] | None = None,
             cache: bool = True) -> SchedulePlan:
        """Cached scheduling under this session's config.  With
        ``config.autotune`` the single-policy pipeline is replaced by the
        simulator-guided search (``alloc_policy``/``order_policy`` are then
        ignored — the tuner picks them); the search result lands in the same
        plan cache, so the warm path costs the same either way.
        ``measured_inputs`` routes through :meth:`calibrate` first."""
        p, _ = self._plan(graph, self.config,
                          measured_inputs=measured_inputs, cache=cache)
        return p

    def _plan(self, graph: OpGraph, cfg: SessionConfig,
              measured_inputs: Mapping[int, Any] | None = None,
              cache: bool = True) -> tuple[SchedulePlan, str]:
        alloc, order, sim_cfg = _policy_parts(cfg)
        if not cache:
            if cfg.autotune:
                return autotune_schedule(
                    graph, hw=cfg.hw, cfg=sim_cfg, max_lanes=cfg.max_lanes,
                    measured_inputs=measured_inputs,
                    refine=cfg.refine), "uncached"
            return schedule(
                graph, alloc, order, cfg.hw, max_lanes=cfg.max_lanes,
                measured_inputs=measured_inputs, sim_cfg=sim_cfg), "uncached"
        if measured_inputs is not None:
            self._calibrate(graph, measured_inputs, cfg)
        key = _plan_key(graph, cfg)
        hit = _lru_get(self._plan_cache, key)
        if hit is not None:
            self._stats["plan_hits"] += 1
            if hit.graph is graph:
                return hit, "hit"
            # same structure, different graph object: rebind (op_ids match)
            return dataclasses.replace(hit, graph=graph), "hit"
        self._stats["plan_misses"] += 1
        # measured timings (if any) are already hydrated onto node costs, so
        # the plain pipeline schedules with them — no re-timing here.
        if cfg.autotune:
            p = autotune_schedule(graph, hw=cfg.hw, cfg=sim_cfg,
                                  max_lanes=cfg.max_lanes, refine=cfg.refine)
        else:
            p = schedule(graph, alloc, order, cfg.hw,
                         max_lanes=cfg.max_lanes, sim_cfg=sim_cfg)
        _lru_put(self._plan_cache, key, p, cfg.cache_size)
        return p, "miss"

    # -- capture ------------------------------------------------------------
    def optimize(self, graph: OpGraph, output_ids=None,
                 cache: bool = True) -> CapturedGraph:
        """Full pipeline → cached executable (plan + capture)."""
        p, _ = self._plan(graph, self.config, cache=cache)
        exe, _ = self._capture(graph, self.config, p,
                               output_ids=output_ids, cache=cache)
        return exe

    def _capture(self, graph: OpGraph, cfg: SessionConfig, p: SchedulePlan,
                 output_ids=None, cache: bool = True) -> tuple[CapturedGraph, str]:
        if not cache:
            return compile_plan(p, output_ids=output_ids,
                                gemm_kernel=cfg.gemm_kernel,
                                faults=self.faults), "uncached"
        key = (
            _plan_key(graph, cfg),   # byte-identical to the plan-cache key
            cfg.weights_key,
            _weights_fingerprint(graph, cfg.weights_key),
            tuple(output_ids) if output_ids is not None else None,
            cfg.gemm_kernel,
        )
        hit = _lru_get(self._exec_cache, key)
        if hit is not None:
            self._stats["exec_hits"] += 1
            return hit, "hit"
        self._stats["exec_misses"] += 1
        try:
            exe = compile_plan(p, output_ids=output_ids,
                               gemm_kernel=cfg.gemm_kernel,
                               faults=self.faults)
        except Exception as exc:
            # Plan-level failure (e.g. injected/real validation error): the
            # ladder floor is a fresh single-stream sequential schedule
            # compiled with the portable vmap route and no injection — the
            # same ops in dependency order, so outputs are identical.
            self._stats["degraded_routes"] += 1
            self.guard_log.note("plan_validate", "schedule->sequential",
                                repr(exc), warn=True)
            safe = schedule(graph, "sequential", "topo", cfg.hw)
            exe = compile_plan(safe, output_ids=output_ids,
                               gemm_kernel="vmap", faults=_NO_FAULTS)
            return exe, "degraded"   # never cached: fault may be transient
        if len(exe.degradations):
            # Route-level fallbacks inside capture (branch_gemm→vmap,
            # grouped_gemm→sequential, ...): correct but slower — surface
            # them and keep the degraded executable OUT of the LRU so a
            # transient fault cannot pin the slow path for future builds.
            self._stats["degraded_routes"] += len(exe.degradations)
            self.guard_log.extend(exe.degradations)
            return exe, "degraded"
        _lru_put(self._exec_cache, key, exe, cfg.cache_size)
        return exe, "miss"

    # -- the one-call entry point -------------------------------------------
    def compile(self, graph: OpGraph,
                inputs: Mapping[int, Any] | None = None,
                output_ids=None) -> CompiledModel:
        """Run the whole pipeline and return a :class:`CompiledModel`.

        ``inputs`` (optional) are profiling inputs: when given, the graph is
        calibrated with measured timings first (cache-amortized).  The
        returned handle exposes ``.plan``, ``.executable``, ``.stats`` and
        ``.explain()`` — per-stage wall times plus, for every cache tier,
        whether this build hit or missed.
        """
        cfg = self.config
        t_total0 = time.perf_counter()
        mark = len(self.guard_log)        # events from THIS build start here
        timings = {"calibrate": 0.0, "plan": 0.0, "compile": 0.0}
        provenance = {"calibration": "off"}
        if inputs is not None:
            t0 = time.perf_counter()
            _, provenance["calibration"] = self._calibrate(graph, inputs, cfg)
            timings["calibrate"] = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        p, provenance["plan"] = self._plan(graph, cfg)
        timings["plan"] = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        exe, provenance["executable"] = self._capture(graph, cfg, p,
                                                      output_ids=output_ids)
        timings["compile"] = (time.perf_counter() - t0) * 1e3
        timings["total"] = (time.perf_counter() - t_total0) * 1e3
        return CompiledModel(config=cfg, graph=graph, plan=p,
                             executable=exe, provenance=provenance,
                             timings_ms=timings,
                             degradations=[e.as_dict() for e
                                           in self.guard_log.events[mark:]])

    # -- introspection / lifecycle ------------------------------------------
    def cache_stats(self) -> dict[str, int]:
        return dict(self._stats, plan_entries=len(self._plan_cache),
                    exec_entries=len(self._exec_cache),
                    calib_entries=len(self._calib_cache))

    def clear_caches(self) -> None:
        """Reset memory tiers + counters (the disk tier stays in place)."""
        self._plan_cache.clear()
        self._exec_cache.clear()
        self._calib_cache.clear()
        for k in self._stats:
            self._stats[k] = 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        c = self.config
        return (f"Session(hw={c.hw.name!r}, alloc={c.alloc_policy!r}, "
                f"order={c.order_policy!r}, autotune={c.autotune}, "
                f"entries={len(self._plan_cache)}p/"
                f"{len(self._exec_cache)}e/{len(self._calib_cache)}c)")


# =========================================================================
# Process-wide default session (backs the legacy api shims)
# =========================================================================

_default_session: Session | None = None
_default_session_lock = threading.Lock()


def default_session() -> Session:
    """The process-wide session the legacy :mod:`repro.core.api` functions
    delegate to.  Created lazily with a default :class:`SessionConfig`.
    Creation is locked: concurrent first callers (a serving fleet's engines
    all defaulting to the shared session) must never observe two distinct
    defaults with split cache state."""
    global _default_session
    if _default_session is None:
        with _default_session_lock:
            if _default_session is None:
                _default_session = Session()
    return _default_session


def reset_default_session(config: SessionConfig | None = None) -> Session:
    """Replace the default session with a fresh one (empty caches, zeroed
    counters).  Tests use this to guarantee cross-test isolation."""
    global _default_session
    with _default_session_lock:
        _default_session = Session(config)
    return _default_session
