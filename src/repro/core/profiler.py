"""Model Profiler (paper §3.2), adapted to TPU.

GPU Opara profiles per-block (threads, registers, shared memory) with
``torch.profiler``.  On TPU the analogous per-operator resource demands are
(FLOPs, HBM bytes moved, VMEM working set) — see DESIGN.md §2.  Two modes:

* **analytic** — models fill :class:`OpCost` at graph-build time from shapes
  (always available; used for dry-runs at production scale);
* **measured** — one profiling inference per model (the paper's "profile each
  DNN inference only once"): every op payload is timed on the host device and
  ``measured_us`` recorded.  Used by the CPU wall-clock benchmarks.

Measurement / mutation split (the calibration lifecycle)
--------------------------------------------------------
Timing and graph mutation are separate steps so measured profiles can be
cached and re-used ("profile once", then amortize):

* :meth:`ModelProfiler.measure` runs the single profiling inference and
  returns a detachable :class:`ProfileTable` — it never touches the graph;
* :func:`apply_profile` hydrates ``node.cost.measured_us`` from a table and
  stamps the table's fingerprint on the graph (``graph.calibration_fp``), so
  cache keys can distinguish calibrated from uncalibrated graphs without the
  raw timings leaking into the *structural* signature;
* :func:`detach_profile` reverses it, returning the graph to the analytic
  state (and handing back the table).

The calibration cache on :class:`repro.core.Session` keys tables by
``(graph.node_signature(), graph.input_signature(inputs), hw.name)``: the
structural graph shape, the input shapes/dtypes the profiling run saw, and
the hardware the timings are valid for.  A structurally identical graph
(e.g. a reloaded checkpoint) hydrates from the cache instead of re-timing.
``profile_measured`` remains as the one-call convenience (measure + apply).

The intensity classification (compute- vs memory-intensive, paper §3.3 /
Fig. 3) is kind-aware: the paper classifies operators *offline by profiled
metrics*, which at framework granularity separates MXU-engaging kinds
(GEMM / conv / attention / scan) from HBM-streaming ones (element-wise,
norm, gather).  A pure arithmetic-intensity-vs-ridge-point test misfires at
inference scale — the v5e ridge is ~240 FLOP/byte, which no batch-1
operator reaches, so every op would land in one class and Algorithm 2's
alternation (and the wave repacker's complementary fill) would have nothing
to mix.  MXU kinds therefore classify COMPUTE once their analytic intensity
clears :data:`COMPUTE_AI_FLOOR` (degenerate skinny GEMMs stay memory-bound);
everything else falls back to the roofline test.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Mapping

import jax

from .graph import IntensityClass, OpCost, OpGraph, OpKind, OpNode


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Roofline constants. Defaults = TPU v5e (per instructions)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    vmem_bytes: float = 128 * 2**20   # ~128 MiB VMEM per core (v5e ~128MB)
    hbm_bytes: float = 16 * 2**30     # 16 GiB HBM
    # execution-time floor for one kernel (setup/drain of the systolic array,
    # DMA latency): small ops never hit the roofline — this is exactly the
    # under-utilization the paper's Fig. 1 measures on GPUs.  0 in unit
    # tests; benchmarks use ~2 µs.
    min_kernel_us: float = 0.0

    @property
    def machine_balance(self) -> float:
        """FLOP/byte at the roofline ridge point (~240 for v5e)."""
        return self.peak_flops / self.hbm_bw


V5E = HardwareSpec()


@dataclasses.dataclass
class OpProfile:
    """Profiler output for one op."""

    cost: OpCost
    intensity: IntensityClass
    est_us: float  # roofline-model execution time estimate


@dataclasses.dataclass(frozen=True)
class ProfileTable:
    """Detachable measured-timing table — the calibration artifact.

    One profiling inference produces one table; :func:`apply_profile` hydrates
    a (structurally identical) graph from it, :func:`detach_profile` strips it
    back off.  Hashable, so the table doubles as its own cache value and its
    ``fingerprint`` as a plan-cache key component.
    """

    hw_name: str
    measured_us: tuple[tuple[int, float], ...]  # (op_id, wall µs), sorted

    @functools.cached_property
    def fingerprint(self) -> tuple:
        """Compact identity: (hw_name, sha1-of-timings, n).  Plan/executable
        cache keys embed this for every calibrated graph, so it must stay
        O(1) to hash — a raw per-op timing tuple would put O(n) floats back
        into every warm-path cache probe."""
        import hashlib

        digest = hashlib.sha1(repr(self.measured_us).encode()).hexdigest()
        return (self.hw_name, digest, len(self.measured_us))

    def as_dict(self) -> dict[int, float]:
        return dict(self.measured_us)


def apply_profile(graph: OpGraph, table: ProfileTable) -> None:
    """Hydrate ``measured_us`` on every timed node and stamp the graph with
    the table's fingerprint (read by the plan/executable cache keys)."""
    for op_id, us in table.measured_us:
        graph.nodes[op_id].cost.measured_us = us
    graph.calibration_fp = table.fingerprint


def detach_profile(graph: OpGraph) -> ProfileTable | None:
    """Strip measured timings off the graph, returning them as a table
    (or ``None`` if the graph carries no measurements)."""
    measured = tuple(
        (n.op_id, n.cost.measured_us)
        for n in graph if n.cost.measured_us is not None
    )
    fp = graph.calibration_fp
    for n in graph:
        n.cost.measured_us = None
    graph.calibration_fp = None
    if not measured:
        return None
    hw_name = fp[0] if fp else ""
    return ProfileTable(hw_name=hw_name, measured_us=measured)


# Operator kinds that engage the MXU / systolic pipeline — the paper's
# compute-intensive population at framework granularity.
_COMPUTE_KINDS = frozenset(
    {OpKind.GEMM, OpKind.CONV, OpKind.ATTENTION, OpKind.SCAN})
# Analytic FLOP/byte below which even an MXU kind is bandwidth-bound
# (skinny batch-1 GEMMs, tiny score matmuls).
COMPUTE_AI_FLOOR = 16.0


class ModelProfiler:
    """Computes per-op profiles for an :class:`OpGraph`."""

    def __init__(self, hw: HardwareSpec = V5E):
        self.hw = hw

    # -- analytic ------------------------------------------------------------
    def roofline_us(self, cost: OpCost) -> float:
        """max(compute time, memory time, kernel floor) — roofline estimate."""
        t_c = cost.flops / self.hw.peak_flops
        t_m = cost.bytes_total / self.hw.hbm_bw
        return max(max(t_c, t_m) * 1e6, self.hw.min_kernel_us)

    def classify(self, node: OpNode) -> IntensityClass:
        """Kind-aware intensity classification (paper §3.3, see module doc)."""
        if (node.kind in _COMPUTE_KINDS
                and node.cost.arithmetic_intensity() >= COMPUTE_AI_FLOOR):
            return IntensityClass.COMPUTE
        return node.cost.intensity(self.hw.machine_balance)

    def profile(self, graph: OpGraph) -> dict[int, OpProfile]:
        out: dict[int, OpProfile] = {}
        for node in graph:
            est = node.cost.measured_us
            if est is None:
                est = self.roofline_us(node.cost)
            out[node.op_id] = OpProfile(
                cost=node.cost,
                intensity=self.classify(node),
                est_us=max(est, 1e-3),
            )
        return out

    # -- measured (one inference pass, paper §3.2) ----------------------------
    def measure(
        self,
        graph: OpGraph,
        inputs: Mapping[int, Any],
        repeats: int = 3,
    ) -> ProfileTable:
        """Execute the graph once op-by-op, timing each payload.

        ``inputs`` maps INPUT-node op_ids to concrete arrays.  The paper's
        single profiling run; we keep ``repeats`` tiny because kernel launch
        noise on CPU is high.  Pure: the graph is NOT mutated — hydrate the
        returned table with :func:`apply_profile` (or let the calibration
        cache on :class:`repro.core.Session` do it).
        """
        values: dict[int, Any] = dict(inputs)
        measured: list[tuple[int, float]] = []
        for i in graph.topological_order():
            node = graph.nodes[i]
            if node.fn is None:
                if i not in values:
                    raise ValueError(f"input op {node.name} has no value bound")
                continue
            args = [values[p] for p in node.inputs]
            args += list(node.meta.get("consts", ()))
            # compile/once then time
            values[i] = node.fn(*args)
            values[i] = jax.block_until_ready(values[i])
            t0 = time.perf_counter()
            for _ in range(repeats):
                out = jax.block_until_ready(node.fn(*args))
            dt = (time.perf_counter() - t0) / repeats * 1e6
            measured.append((i, dt))
            values[i] = out
        return ProfileTable(hw_name=self.hw.name, measured_us=tuple(measured))

    def profile_measured(
        self,
        graph: OpGraph,
        inputs: Mapping[int, Any],
        repeats: int = 3,
    ) -> dict[int, OpProfile]:
        """One-call convenience: measure, hydrate the graph, return profiles
        (measured ops carry ``est_us = measured_us``; inputs stay analytic)."""
        apply_profile(graph, self.measure(graph, inputs, repeats=repeats))
        return self.profile(graph)


# -- analytic cost constructors (used by models when emitting graphs) --------

def gemm_cost(m: int, k: int, n: int, dtype_bytes: int = 2, batch: int = 1) -> OpCost:
    flops = 2.0 * batch * m * k * n
    br = batch * (m * k + k * n) * dtype_bytes
    bw = batch * m * n * dtype_bytes
    # VMEM working set: one MXU tile pass — bounded by operand tiles, not the
    # whole tensor; approximate with min(whole operands, 3 × 128-wide tiles).
    tile = 128
    vmem = dtype_bytes * min(
        batch * (m * k + k * n + m * n),
        (m * tile + tile * n + m * n) if k > tile else batch * (m * k + k * n + m * n),
    )
    # occupancy: output parallelism vs the device's lane budget (~512k)
    occ = min(1.0, batch * m * n / float(1 << 19))
    return OpCost(flops=flops, bytes_read=br, bytes_written=bw,
                  vmem_bytes=float(vmem), occupancy=occ)


def elementwise_cost(numel: int, dtype_bytes: int = 2, n_in: int = 1, flops_per_elem: float = 1.0) -> OpCost:
    return OpCost(
        flops=flops_per_elem * numel,
        bytes_read=float(n_in * numel * dtype_bytes),
        bytes_written=float(numel * dtype_bytes),
        vmem_bytes=float(min((n_in + 1) * numel * dtype_bytes, 8 * 2**20)),
        occupancy=min(1.0, numel / float(1 << 21)),
    )


def norm_cost(numel: int, dtype_bytes: int = 2) -> OpCost:
    return OpCost(
        flops=5.0 * numel,
        bytes_read=float(numel * dtype_bytes),
        bytes_written=float(numel * dtype_bytes),
        vmem_bytes=float(min(2 * numel * dtype_bytes, 4 * 2**20)),
        occupancy=min(1.0, numel / float(1 << 21)),
    )


def gather_cost(rows: int, width: int, dtype_bytes: int = 2) -> OpCost:
    n = rows * width
    return OpCost(
        flops=0.0,
        bytes_read=float(n * dtype_bytes + rows * 4),
        bytes_written=float(n * dtype_bytes),
        vmem_bytes=float(min(n * dtype_bytes, 4 * 2**20)),
        occupancy=min(1.0, n / float(1 << 21)),
    )


def attention_cost(b: int, q: int, kv: int, h: int, d: int, kvh: int, dtype_bytes: int = 2) -> OpCost:
    flops = 4.0 * b * h * q * kv * d  # QK^T + PV
    br = float(dtype_bytes * b * (q * h * d + 2 * kv * kvh * d))
    bw = float(dtype_bytes * b * q * h * d)
    vmem = float(dtype_bytes * (128 * d + 2 * 512 * d + 128 * 512))  # flash tiles
    occ = min(1.0, b * h * q * d / float(1 << 19))
    return OpCost(flops=flops, bytes_read=br, bytes_written=bw, vmem_bytes=vmem,
                  occupancy=occ)


def scan_cost(b: int, t: int, d: int, state: int, dtype_bytes: int = 2) -> OpCost:
    """Linear recurrence (RWKV/Mamba): ~10 flops/elem/state, streaming reads."""
    flops = 10.0 * b * t * d * max(state, 1)
    br = float(dtype_bytes * b * t * d * 4)
    bw = float(dtype_bytes * b * t * d)
    return OpCost(flops=flops, bytes_read=br, bytes_written=bw,
                  vmem_bytes=float(dtype_bytes * min(b, 8) * d * max(state, 1) * 4),
                  occupancy=min(1.0, b * d / float(1 << 19)))


def summarize(graph: OpGraph, profiles: dict[int, OpProfile]) -> dict[str, float]:
    n_c = sum(1 for p in profiles.values() if p.intensity is IntensityClass.COMPUTE)
    return {
        "ops": float(len(graph)),
        "compute_ops": float(n_c),
        "memory_ops": float(len(graph) - n_c),
        "total_flops": float(sum(p.cost.flops for p in profiles.values())),
        "total_bytes": float(sum(p.cost.bytes_total for p in profiles.values())),
        "sum_est_us": float(sum(p.est_us for p in profiles.values())),
    }
