"""Operator Launcher — faithful implementation of the paper's Algorithm 2.

Resource- and interference-aware launch ordering:

* keep two ready lists: memory-intensive ``L_mem`` and compute-intensive
  ``L_comp`` (classification from the Model Profiler);
* **alternate** between the two non-empty lists (interference-awareness —
  overlap compute-bound and memory-bound operators, paper Fig. 3);
* from the chosen list always launch the operator with the **least resource
  demand** (resource-awareness — avoid GPU blocking/fragmentation, Fig. 2);
* launching an op decrements successors' indegrees; newly-ready ops join the
  list matching their intensity class.

Baselines for the paper's figures:
* :func:`topo_order`       — stock framework order (paper's "CUDA Graph").
* :func:`depth_first_order`— Fig. 2 "order 1".
* :func:`resource_only_order` — ablation: smallest-first without alternation.
"""
from __future__ import annotations

import heapq

from .graph import IntensityClass, OpGraph
from .profiler import OpProfile


def opara_launch_order(graph: OpGraph, profiles: dict[int, OpProfile]) -> list[int]:
    """Algorithm 2, line-by-line (heaps instead of lists for O(n log n))."""
    indeg = graph.indegree_map()
    succ = graph.unique_successors_map()

    l_mem: list[tuple[float, int]] = []   # line 1: L_mem
    l_comp: list[tuple[float, int]] = []  # line 1: L_comp
    queue: list[int] = []                 # line 1: Q

    def push(i: int) -> None:
        demand = profiles[i].cost.resource_demand()
        if profiles[i].intensity is IntensityClass.MEMORY:
            heapq.heappush(l_mem, (demand, i))
        else:
            heapq.heappush(l_comp, (demand, i))

    for i, d in indeg.items():  # line 2: indegree-0 ops into L_mem / L_comp
        if d == 0:
            push(i)

    take_mem = True  # alternation state (line 4)
    while l_mem or l_comp:  # line 3
        # line 4: alternately choose a non-empty list
        if take_mem:
            lst = l_mem if l_mem else l_comp
        else:
            lst = l_comp if l_comp else l_mem
        take_mem = not take_mem
        _, v_min = heapq.heappop(lst)  # lines 5-6: least-resource op
        queue.append(v_min)
        for s in succ[v_min]:  # lines 7-16: update indegrees
            indeg[s] -= 1
            if indeg[s] == 0:
                push(s)
    assert len(queue) == len(graph), "launch order must cover every op"
    return queue


def topo_order(graph: OpGraph, profiles: dict[int, OpProfile] | None = None) -> list[int]:
    return graph.topological_order()


def depth_first_order(graph: OpGraph, profiles: dict[int, OpProfile] | None = None) -> list[int]:
    return graph.depth_first_order()


def resource_only_order(graph: OpGraph, profiles: dict[int, OpProfile]) -> list[int]:
    """Ablation: smallest-resource-first globally, ignoring intensity class."""
    indeg = graph.indegree_map()
    succ = graph.unique_successors_map()
    heap: list[tuple[float, int]] = []
    for i, d in indeg.items():
        if d == 0:
            heapq.heappush(heap, (profiles[i].cost.resource_demand(), i))
    out: list[int] = []
    while heap:
        _, i = heapq.heappop(heap)
        out.append(i)
        for s in succ[i]:
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (profiles[s].cost.resource_demand(), s))
    return out


def largest_first_order(graph: OpGraph, profiles: dict[int, OpProfile]) -> list[int]:
    """Adversarial baseline: largest-resource-first (the GPU-blocking worst
    case the paper's Fig. 2 'inadequate order' represents)."""
    indeg = graph.indegree_map()
    succ = graph.unique_successors_map()
    heap: list[tuple[float, int]] = []
    for i, d in indeg.items():
        if d == 0:
            heapq.heappush(heap, (-profiles[i].cost.resource_demand(), i))
    out: list[int] = []
    while heap:
        _, i = heapq.heappop(heap)
        out.append(i)
        for s in succ[i]:
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (-profiles[s].cost.resource_demand(), s))
    return out


def critical_path_order(graph: OpGraph, profiles: dict[int, OpProfile]) -> list[int]:
    """HEFT-style upward-rank order: among ready ops, launch the one with the
    longest remaining critical path (by ``est_us``) first.  A classic
    list-scheduling baseline the autotuner searches alongside Alg. 2 — it
    wins when the makespan is chain-dominated rather than interference- or
    resource-dominated."""
    succ = graph.unique_successors_map()
    rank: dict[int, float] = {}
    for i in reversed(graph.topological_order()):
        rank[i] = profiles[i].est_us + max(
            (rank[s] for s in succ[i]), default=0.0)
    indeg = graph.indegree_map()
    heap: list[tuple[float, int]] = []
    for i, d in indeg.items():
        if d == 0:
            heapq.heappush(heap, (-rank[i], i))
    out: list[int] = []
    while heap:
        _, i = heapq.heappop(heap)
        out.append(i)
        for s in succ[i]:
            indeg[s] -= 1
            if indeg[s] == 0:
                heapq.heappush(heap, (-rank[s], s))
    return out


ORDER_POLICIES = {
    "opara": opara_launch_order,
    "topo": topo_order,
    "depth_first": depth_first_order,
    "resource_only": resource_only_order,
    "largest_first": largest_first_order,
    "critical_path": critical_path_order,
}


def validate_order(graph: OpGraph, order: list[int]) -> None:
    """Invariant: the order is a topological linearization covering all ops."""
    assert sorted(order) == sorted(graph.nodes), "order must be a permutation"
    pos = {i: k for k, i in enumerate(order)}
    for node in graph:
        for p in node.inputs:
            assert pos[p] < pos[node.op_id], (
                f"dependency violated: {p} after {node.op_id}")
