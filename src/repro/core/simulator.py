"""Multi-lane execution simulator with an interference model.

The container has no GPU (and a TPU runs one fused region at a time), so the
paper's *wall-clock* stream-concurrency experiments are reproduced on a
calibrated discrete-event simulator, the same methodology the paper's own
analytical model (Eq. 1–4) implies:

* the device executes operators on ``n_lanes`` concurrent lanes (streams);
* each op occupies its stream for ``est_us`` (roofline or measured);
* a *resource cap* models the SM/VMEM pool: the sum of ``resource_demand()``
  of concurrently-executing ops may not exceed ``resource_cap`` — an op whose
  demand does not fit BLOCKS the stream head (the paper's "GPU blocking",
  non-preemptive, Fig. 2);
* *interference* (paper Fig. 3): while >=2 ops of the same intensity class
  run concurrently, each runs slower by ``interference_penalty`` (default
  13% — the paper measures 12.7–13.6%); mixed-class overlap is free;
* cross-stream dependencies cost ``sync_us`` each (the paper's t_overhead).

The simulator consumes exactly the artifacts the real backends consume: a
:class:`StreamPlan` (Alg. 1 / Nimble) and a launch order (Alg. 2 /
baselines), so scheduler comparisons (Fig. 2/5/8, Table 1) are apples to
apples.
"""
from __future__ import annotations

import dataclasses
import heapq

from .graph import IntensityClass, OpGraph
from .profiler import OpProfile
from .stream_alloc import StreamPlan


@dataclasses.dataclass(frozen=True)
class SimConfig:
    resource_cap: float = 128 * 2**20   # VMEM pool (SM-pool analogue)
    interference_penalty: float = 0.13  # paper Fig. 3: ~13%
    sync_us: float = 1.0                # t_overhead per cross-stream event
    launch_us: float = 5.0              # per-op launch cost WITHOUT graph capture
    graph_capture: bool = True          # CUDA-Graph analogue: no launch cost
    # non-preemptive dispatch (paper §2.3 / [11]): kernels dispatch in launch
    # order; one waiting on resources blocks every later launch.  THE
    # mechanism that makes the operator launch order matter (Fig. 2).
    head_of_line: bool = False


@dataclasses.dataclass
class SimResult:
    makespan_us: float
    per_op_start: dict[int, float]
    per_op_end: dict[int, float]
    busy_us: float                      # sum of op durations (utilization numer.)
    n_syncs: int

    def utilization(self, n_lanes: int) -> float:
        return self.busy_us / max(self.makespan_us * n_lanes, 1e-9)


def simulate(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    profiles: dict[int, OpProfile],
    cfg: SimConfig | None = None,
) -> SimResult:
    """Event-driven simulation.

    Streams are FIFO: each stream executes its ops in `order`-induced
    sequence.  An op starts when (1) its stream head reaches it, (2) all
    predecessors finished (+sync_us if cross-stream), (3) resource fits.
    Interference: an op's duration is stretched by the fraction of its
    lifetime it shares the device with another op of the same class; we apply
    the penalty if any same-class op overlaps (conservative, matches the
    paper's pairwise measurements).
    """
    cfg = cfg or SimConfig()
    pos_in_order = {op: k for k, op in enumerate(order)}
    stream_queues: dict[int, list[int]] = {}
    for op in order:
        stream_queues.setdefault(plan.stream_of[op], []).append(op)

    end: dict[int, float] = {}
    start: dict[int, float] = {}
    stream_free: dict[int, float] = {s: 0.0 for s in stream_queues}
    # running set for resource/interference accounting: (end_t, demand, class, id)
    running: list[tuple[float, float, IntensityClass, int]] = []
    n_syncs = 0
    busy = 0.0

    # process ops in launch order, but an op can only start after its stream
    # predecessor — emulate per-stream program order with stream_free times.
    stream_pos: dict[int, int] = {s: 0 for s in stream_queues}
    remaining = len(order)
    launched: set[int] = set()
    t_cursor = 0.0
    last_start = 0.0   # head-of-line: dispatch times are monotone in order

    def _gc(now: float) -> None:
        nonlocal running
        running = [r for r in running if r[0] > now]

    n_launched_total = 0
    while remaining:
        progressed = False
        # try streams in launch-order priority: pick the op with the smallest
        # global order index whose stream-head it is and whose deps resolved
        candidates: list[tuple[int, int, int]] = []  # (order_pos, stream, op)
        if cfg.head_of_line:
            # non-preemptive dispatch: only the NEXT op in launch order may
            # be placed; if it cannot run yet, everything behind it waits.
            op = order[n_launched_total]
            if all(p in end for p in graph.nodes[op].inputs):
                candidates.append((pos_in_order[op], plan.stream_of[op], op))
        else:
            for s, q in stream_queues.items():
                k = stream_pos[s]
                if k < len(q):
                    op = q[k]
                    if all(p in end for p in graph.nodes[op].inputs):
                        candidates.append((pos_in_order[op], s, op))
        if not candidates:
            # advance time to the earliest running end to unblock deps
            if running:
                t_cursor = min(r[0] for r in running)
                _gc(t_cursor)
                # mark ended ops (they are already in `end`)
                progressed = True
                continue
            raise RuntimeError("deadlock in simulation — invalid schedule")

        candidates.sort()
        scheduled_any = False
        for _, s, op in candidates:
            node = graph.nodes[op]
            prof = profiles[op]
            demand = prof.cost.resource_demand()
            # dependency ready time (+ sync for cross-stream edges)
            dep_t = 0.0
            for p in set(node.inputs):
                t = end[p]
                if plan.stream_of[p] != s:
                    t += cfg.sync_us
                    if op not in launched:
                        n_syncs += 1
                dep_t = max(dep_t, t)
            t0 = max(stream_free[s], dep_t, t_cursor if not running else 0.0)
            if cfg.head_of_line:
                t0 = max(t0, last_start)
            if not cfg.graph_capture:
                t0 += cfg.launch_us
            # resource cap: find earliest time >= t0 when it fits
            horizon = sorted({t0} | {r[0] for r in running if r[0] > t0})
            placed = False
            for t_try in horizon:
                concurrent = [r for r in running if r[0] > t_try]
                used = sum(r[1] for r in concurrent)
                if used + demand <= cfg.resource_cap or not concurrent:
                    # interference check
                    same = any(r[2] is prof.intensity for r in concurrent)
                    dur = prof.est_us * (1.0 + (cfg.interference_penalty if same else 0.0))
                    start[op] = t_try
                    end[op] = t_try + dur
                    running.append((end[op], demand, prof.intensity, op))
                    stream_free[s] = end[op]  # FIFO stream: serializes lane
                    stream_pos[s] += 1
                    launched.add(op)
                    n_launched_total += 1
                    last_start = max(last_start, t_try)
                    busy += dur
                    remaining -= 1
                    placed = True
                    scheduled_any = True
                    break
            if placed:
                break  # re-evaluate candidates after each placement
        if not scheduled_any and not progressed:
            # everything blocked on resources: jump time forward
            if not running:
                raise RuntimeError("resource deadlock — op demand exceeds cap")
            t_cursor = min(r[0] for r in running)
            _gc(t_cursor)

    makespan = max(end.values(), default=0.0)
    return SimResult(
        makespan_us=makespan,
        per_op_start=start,
        per_op_end=end,
        busy_us=busy,
        n_syncs=n_syncs,
    )


def estimate_makespan(
    graph: OpGraph,
    plan: StreamPlan,
    order: list[int],
    profiles: dict[int, OpProfile],
    cfg: SimConfig | None = None,
) -> float:
    """Fast-path cost model: one monotone sweep over the launch order.

    The autotuner's inner loop (``scheduler.autotune``) evaluates dozens of
    candidate (streams, order, packing) triples per graph, so it cannot
    afford :func:`simulate`'s per-op horizon rescans.  This estimator keeps
    the same mechanics — FIFO streams, cross-stream sync cost, the shared
    resource pool, the same-class interference penalty, head-of-line
    dispatch — but places each op exactly once, tracking the running set in
    a single min-heap popped monotonically (O(n log n) total, ≥10× faster
    than :func:`simulate` on multi-thousand-op graphs).

    For ``head_of_line=True`` (dispatch times monotone in launch order) the
    sweep is a faithful reduction of :func:`simulate`; without it the sweep
    processes ops in launch order rather than re-arbitrating stream heads
    per event, so it is an *estimate* — accurate enough to rank candidate
    schedules, which is all the autotuner needs.
    """
    return _sweep(op_tables(graph, plan, profiles), order, cfg or SimConfig())


def op_tables(
    graph: OpGraph,
    plan: StreamPlan,
    profiles: dict[int, OpProfile],
) -> tuple:
    """Dense per-op arrays (op ids are 0..n-1 by construction) feeding
    :func:`_sweep`.  Order-independent, so the autotuner prefetches once per
    stream plan and sweeps every candidate order against the same tables."""
    n = len(graph.nodes)
    stream = [0] * n
    demand = [0.0] * n
    est = [0.0] * n
    is_comp = [False] * n
    inputs: list[tuple[int, ...]] = [()] * n
    stream_of = plan.stream_of
    for op, node in graph.nodes.items():
        p = profiles[op]
        stream[op] = stream_of[op]
        demand[op] = p.cost.resource_demand()
        est[op] = p.est_us
        is_comp[op] = p.intensity is IntensityClass.COMPUTE
        inputs[op] = node.inputs
    return stream, demand, est, is_comp, inputs


class SweepState:
    """Resumable :func:`_sweep` state — the delta re-estimation primitive.

    The sweep places ops strictly in launch-order sequence, so its state
    after a prefix is a pure function of that prefix.  ``scheduler.refine``
    exploits this: it checkpoints (``clone``) the state at wave boundaries
    and re-estimates a perturbed schedule by re-sweeping only the suffix
    behind the edit (``sweep_extend``) instead of the whole order.
    """

    __slots__ = ("end", "stream_free", "active", "used", "n_comp", "n_mem",
                 "last_start", "makespan")

    def __init__(self, n_ops: int):
        self.end = [0.0] * n_ops
        self.stream_free: dict[int, float] = {}
        # running set: min-heap of (end_t, op, demand, is_comp) + aggregates
        self.active: list[tuple[float, int, float, bool]] = []
        self.used = 0.0
        self.n_comp = 0
        self.n_mem = 0
        self.last_start = 0.0
        self.makespan = 0.0

    def clone(self) -> "SweepState":
        s = SweepState.__new__(SweepState)
        s.end = self.end.copy()
        s.stream_free = dict(self.stream_free)
        s.active = list(self.active)   # a copied heap keeps its invariant
        s.used = self.used
        s.n_comp = self.n_comp
        s.n_mem = self.n_mem
        s.last_start = self.last_start
        s.makespan = self.makespan
        return s

    def fork(self) -> "SweepState":
        """Like :meth:`clone` but SHARING the per-op ``end`` array.

        Valid because the sweep only reads ``end[p]`` for producers ``p``
        of the op being placed — which a dependency-valid order has already
        placed *in the same walk* or before the fork point — so entries at
        or beyond the fork point are always rewritten before they are read.
        Forks from one base state may interleave freely under that rule;
        ``clone`` (which copies) is the safe choice when in doubt.  This is
        what makes a refinement candidate's suffix re-estimate O(suffix)
        instead of O(n) per evaluation.
        """
        s = SweepState.__new__(SweepState)
        s.end = self.end                # shared, write-before-read
        s.stream_free = dict(self.stream_free)
        s.active = list(self.active)
        s.used = self.used
        s.n_comp = self.n_comp
        s.n_mem = self.n_mem
        s.last_start = self.last_start
        s.makespan = self.makespan
        return s


def sweep_extend(tables: tuple, ops, cfg: SimConfig,
                 state: SweepState) -> float:
    """Place ``ops`` (the next slice of a launch order) onto ``state``.

    Mutates ``state`` and returns the running makespan.  Chaining
    ``sweep_extend`` calls over consecutive slices of an order is exactly
    equivalent to one :func:`_sweep` over the whole order; every op's
    producers must have been placed by an earlier slice (or this one).
    """
    sync = cfg.sync_us
    launch = 0.0 if cfg.graph_capture else cfg.launch_us
    cap = cfg.resource_cap
    penalty = 1.0 + cfg.interference_penalty
    head_of_line = cfg.head_of_line
    heappush, heappop = heapq.heappush, heapq.heappop

    stream, demand, est, is_comp, inputs = tables
    end = state.end
    stream_free = state.stream_free
    active = state.active
    used = state.used
    n_comp = state.n_comp
    n_mem = state.n_mem
    last_start = state.last_start
    makespan = state.makespan

    for op in ops:
        s = stream[op]
        t0 = stream_free.get(s, 0.0)
        for p in inputs[op]:    # duplicate edges: same max, no dedup cost
            t = end[p]
            if stream[p] != s:
                t += sync
            if t > t0:
                t0 = t
        if head_of_line and last_start > t0:
            t0 = last_start
        t0 += launch
        # retire everything finished by t0 (monotone pop)
        while active and active[0][0] <= t0:
            _, _, d, c = heappop(active)
            used -= d
            if c:
                n_comp -= 1
            else:
                n_mem -= 1
        dem = demand[op]
        # resource admission: advance start to successive completion times
        # until the op fits (an op larger than the cap runs alone, matching
        # simulate()'s empty-device admission).
        while active and used + dem > cap:
            e, _, d, c = heappop(active)
            used -= d
            if c:
                n_comp -= 1
            else:
                n_mem -= 1
            if e > t0:
                t0 = e
        comp = is_comp[op]
        dur = est[op]
        if (n_comp if comp else n_mem) > 0:
            dur *= penalty
        t1 = t0 + dur
        end[op] = t1
        stream_free[s] = t1
        if t0 > last_start:
            last_start = t0
        heappush(active, (t1, op, dem, comp))
        used += dem
        if comp:
            n_comp += 1
        else:
            n_mem += 1
        if t1 > makespan:
            makespan = t1

    state.used = used
    state.n_comp = n_comp
    state.n_mem = n_mem
    state.last_start = last_start
    state.makespan = makespan
    return makespan


def _sweep(tables: tuple, order: list[int], cfg: SimConfig) -> float:
    return sweep_extend(tables, order, cfg, SweepState(len(tables[0])))


def sequential_makespan(
    graph: OpGraph, profiles: dict[int, OpProfile],
    cfg: SimConfig | None = None,
) -> float:
    """T_seq of the paper — one stream, topological order."""
    cfg = cfg or SimConfig()
    total = sum(profiles[i].est_us for i in graph.nodes)
    if not cfg.graph_capture:
        total += cfg.launch_us * len(graph)
    return total
