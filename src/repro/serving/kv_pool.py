"""Deterministic on-device KV page pool: fixed pages, ref counts, prefix COW.

The pool manages *page identities only* — the tensors live in the engine's
paged cache leaves ``[L, P, ps, ...]``; the pool decides which physical page
each logical page of each request maps to.  Invariants:

* Page 0 is the reserved **null page**: never allocated, never freed; block
  tables of inactive slots (and positions past a request's length) point at
  it so decode kernels always have a valid gather target.
* Allocation order is deterministic: the free list is a min-heap, so the
  lowest-numbered free page is always handed out next.  Replaying the same
  request trace reproduces the same page map bit-for-bit (tested).
* ``ensure`` is all-or-nothing: if the pool cannot cover the requested
  length, nothing is allocated and :class:`PageExhausted` is raised — the
  engine turns that into admission pressure (requeue/shed), never a
  half-mapped request.
* Pages are ref-counted for prefix sharing.  ``adopt_shared`` maps a prompt
  prefix onto already-resident pages by content key; a writer into a page
  with refcount > 1 gets a private copy first (copy-on-write) via
  ``writable_page``.  Double-free is a hard ``RuntimeError``, not a counter.

Content keys chain a sha1 over the exact position stream (meta sentinels +
prompt tokens), so equal keys imply byte-identical page contents for a
deterministic model.  A shared *partial* page may physically contain stale
positions beyond the shorter prompt's length — safe because decode masks by
length and the first writer copies before extending.
"""
from __future__ import annotations

import hashlib
import heapq
from dataclasses import dataclass
from typing import Optional


class PageExhausted(RuntimeError):
    """The pool cannot cover a request; nothing was allocated."""


@dataclass(frozen=True)
class KVPoolConfig:
    num_pages: int          # total physical pages, including null page 0
    page_size: int = 16     # positions per page

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")


def page_content_keys(model_name: str, page_size: int, prompt: list[int],
                      meta_tokens: int = 0) -> list[str]:
    """Chained content keys for the pages a prompt's KV occupies.

    Position ``p`` of the cache holds a meta sentinel (p < meta_tokens) or
    the KV of prompt token ``p - meta_tokens`` — prefill writes every
    prompt position; only the first *sampled* token's KV is pending.  Each
    key hashes its page's tokens chained onto the previous key, so key
    equality implies *full-prefix* equality — page i can only be adopted if
    pages 0..i-1 matched too (KV at position p depends on the whole prefix
    through attention mixing, not on token p alone).  The final partial
    page (if any) also gets a key, tagged with its fill level, so two
    prompts share it only when their written prefixes agree exactly.
    """
    stream = [("meta", i) for i in range(meta_tokens)]
    stream += [("tok", int(t)) for t in prompt]
    keys: list[str] = []
    hasher = hashlib.sha1(f"{model_name}:{page_size}".encode())
    for start in range(0, len(stream), page_size):
        chunk = stream[start:start + page_size]
        hasher = hasher.copy()
        hasher.update(repr(chunk).encode())
        if len(chunk) == page_size:
            keys.append(hasher.hexdigest())
        else:
            partial = hasher.copy()
            partial.update(f":partial:{len(chunk)}".encode())
            keys.append(partial.hexdigest())
    return keys


class KVPagePool:
    """Deterministic ref-counted page allocator with per-tenant accounting."""

    def __init__(self, config: KVPoolConfig):
        self.config = config
        self._free: list[int] = list(range(1, config.num_pages))
        heapq.heapify(self._free)
        self._refs: dict[int, int] = {}            # page -> refcount
        self._tables: dict[str, list[int]] = {}    # rid -> physical pages
        self._tenants: dict[str, str] = {}         # rid -> tenant
        self._tenant_pages: dict[str, int] = {}    # tenant -> held pages
        self._shared_index: dict[str, int] = {}    # content key -> page
        self._page_keys: dict[int, str] = {}       # page -> published key
        self.stats = {
            "allocs": 0, "frees": 0, "cow_copies": 0, "shared_hits": 0,
            "leaked_pages": 0, "exhaustions": 0,
        }

    # -- introspection ----------------------------------------------------
    @property
    def page_size(self) -> int:
        return self.config.page_size

    def holds(self, rid: str) -> bool:
        return rid in self._tables

    def holders(self) -> list[str]:
        return list(self._tables)

    def table(self, rid: str) -> list[int]:
        return list(self._tables[rid])

    def pages_for(self, n_pos: int) -> int:
        return -(-max(n_pos, 0) // self.config.page_size)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.config.num_pages - 1 - len(self._free)

    def tenant_pages(self, tenant: str) -> int:
        return self._tenant_pages.get(tenant, 0)

    def health(self) -> dict:
        return {
            "num_pages": self.config.num_pages,
            "page_size": self.config.page_size,
            "free_pages": self.free_pages,
            "used_pages": self.used_pages,
            "holders": len(self._tables),
            "shared_keys": len(self._shared_index),
            "tenant_pages": dict(self._tenant_pages),
            **self.stats,
        }

    # -- allocation -------------------------------------------------------
    def ensure(self, rid: str, n_pos: int, tenant: str = "default") -> list[int]:
        """Grow ``rid``'s table to cover ``n_pos`` positions; all-or-nothing."""
        table = self._tables.setdefault(rid, [])
        if rid not in self._tenants:
            self._tenants[rid] = tenant
        need = self.pages_for(n_pos) - len(table)
        if need > len(self._free):
            self.stats["exhaustions"] += 1
            if not table:
                del self._tables[rid]
                self._tenants.pop(rid, None)
            raise PageExhausted(
                f"request {rid} needs {need} pages, {len(self._free)} free")
        for _ in range(max(need, 0)):
            page = heapq.heappop(self._free)
            self._refs[page] = 1
            table.append(page)
            self.stats["allocs"] += 1
            t = self._tenants[rid]
            self._tenant_pages[t] = self._tenant_pages.get(t, 0) + 1
        return list(table)

    def adopt_shared(self, rid: str, keys: list[str],
                     tenant: str = "default") -> int:
        """Map a fresh request onto resident pages by content key.

        Adoption is prefix-greedy: it stops at the first key miss (chained
        keys make any later hit impossible anyway).  Returns the number of
        pages adopted.  Must be called before ``ensure`` for the same rid.
        """
        if self._tables.get(rid):
            raise RuntimeError(f"adopt_shared: {rid} already holds pages")
        table: list[int] = []
        for key in keys:
            page = self._shared_index.get(key)
            if page is None:
                break
            self._refs[page] += 1
            table.append(page)
        if table:
            self._tables[rid] = table
            self._tenants[rid] = tenant
            self._tenant_pages[tenant] = self._tenant_pages.get(tenant, 0) + len(table)
            self.stats["shared_hits"] += len(table)
        return len(table)

    def publish_keys(self, rid: str, keys: list[str]) -> None:
        """Register content keys for ``rid``'s leading pages (first writer
        wins; a stale entry for a since-mutated page is safe — see module
        docstring)."""
        table = self._tables.get(rid, [])
        for page, key in zip(table, keys):
            if key not in self._shared_index:
                self._shared_index[key] = page
                self._page_keys.setdefault(page, key)

    def writable_page(self, rid: str, position: int) -> tuple[int, Optional[int]]:
        """Physical page for writing at ``position``; COW when shared.

        Returns ``(page, copy_src)`` — ``copy_src`` is the page whose
        contents must be copied into ``page`` first (None when exclusive).
        """
        table = self._tables[rid]
        idx = position // self.config.page_size
        page = table[idx]
        if self._refs[page] <= 1:
            return page, None
        if not self._free:
            self.stats["exhaustions"] += 1
            raise PageExhausted(f"COW for {rid} position {position}: no free pages")
        fresh = heapq.heappop(self._free)
        self._refs[fresh] = 1
        self._refs[page] -= 1          # shared page keeps its other holders
        table[idx] = fresh
        self.stats["allocs"] += 1
        self.stats["cow_copies"] += 1
        return fresh, page

    # -- release ----------------------------------------------------------
    def _decref(self, page: int) -> bool:
        refs = self._refs.get(page, 0)
        if refs <= 0:
            raise RuntimeError(f"double free of page {page}")
        if refs == 1:
            del self._refs[page]
            key = self._page_keys.pop(page, None)
            if key is not None and self._shared_index.get(key) == page:
                del self._shared_index[key]
            heapq.heappush(self._free, page)
            self.stats["frees"] += 1
            return True
        self._refs[page] = refs - 1
        return False

    def release(self, rid: str) -> int:
        """Drop all of ``rid``'s pages; returns pages actually freed."""
        table = self._tables.pop(rid, None)
        if table is None:
            return 0
        tenant = self._tenants.pop(rid)
        self._tenant_pages[tenant] -= len(table)
        if not self._tenant_pages[tenant]:
            del self._tenant_pages[tenant]
        return sum(self._decref(page) for page in table)

    def leak(self, rid: str) -> int:
        """Drop ``rid``'s table WITHOUT freeing — models a failed release.

        The pages stay resident (held by no one) and are counted in
        ``leaked_pages``; chaos tests assert the counter and the capacity
        loss it implies.
        """
        table = self._tables.pop(rid, None)
        if table is None:
            return 0
        tenant = self._tenants.pop(rid)
        self._tenant_pages[tenant] -= len(table)
        if not self._tenant_pages[tenant]:
            del self._tenant_pages[tenant]
        self.stats["leaked_pages"] += len(table)
        return len(table)
