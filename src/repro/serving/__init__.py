from .engine import InferenceEngine, Request, RequestState
from .sampler import sample_token

__all__ = ["InferenceEngine", "Request", "RequestState", "sample_token"]
