from .admission import (AdmissionConfig, AdmissionQueue, Request,
                        RequestState, TERMINAL_STATES)
from .engine import InferenceEngine
from .kv_pool import (KVPagePool, KVPoolConfig, PageExhausted,
                      page_content_keys)
from .sampler import sample_token

__all__ = ["InferenceEngine", "Request", "RequestState", "AdmissionConfig",
           "AdmissionQueue", "TERMINAL_STATES", "sample_token",
           "KVPagePool", "KVPoolConfig", "PageExhausted",
           "page_content_keys"]
