"""Overload-robust admission tier for the continuous-batching engine.

The engine's original request queue was an unbounded FIFO list: under any
sustained overload (arrival rate > slot capacity) it either grows without
bound or delivers useless late tokens.  This module is the admission tier
in front of the slot scheduler:

  * :class:`Request` carries multi-tenant serving metadata — ``tenant``,
    ``priority`` (higher = more important) and a ``deadline`` (absolute
    engine tick) or ``ttl`` (ticks from submission, resolved at submit);
  * :class:`AdmissionQueue` is a *bounded* queue with per-tenant quotas.
    A request that does not fit is **shed** (terminal
    :attr:`RequestState.SHED` with structured ``Request.error``
    provenance) instead of queued forever — under EDF policy an incoming
    urgent request displaces the least-urgent queued one rather than
    being dropped itself;
  * batch assembly is **EDF with priority classes**: the next admitted
    request is the highest-priority one with the earliest deadline
    (arrival order breaks ties, so a deadline-free, single-priority
    workload degenerates to exactly the legacy FIFO behavior);
  * :func:`deadline_critical` is the preemption trigger the engine uses
    to decide when a queued request must start *now* to have any chance
    of finishing inside its deadline.

Everything here is driven by the engine's deterministic **tick clock**
(one tick = one prefill or one batched decode step) — no wall-clock
anywhere, so shed/preempt/expire decisions replay identically in tests
and chaos runs.  See ``docs/robustness.md`` ("Serving tier under
overload") for the state machine and the shed/preempt/expire ladder.
"""
from __future__ import annotations

import dataclasses
import enum


class RequestState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    # terminal: this request was poisoned (non-finite logits, prefill
    # failure, oversized prompt) and was evicted WITHOUT killing
    # co-batched requests
    FAILED = "failed"
    # terminal: refused at admission (queue bound, tenant quota, draining
    # engine, or displaced by a more urgent request)
    SHED = "shed"
    # terminal: deadline (or the run's tick budget) passed before
    # completion — queued or running, the request is evicted
    EXPIRED = "expired"


#: states a request can never leave; ``InferenceEngine.run`` guarantees
#: every submitted request ends in one of these
TERMINAL_STATES = frozenset(
    {RequestState.DONE, RequestState.FAILED, RequestState.SHED,
     RequestState.EXPIRED})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    # -- multi-tenant admission metadata ----------------------------------
    tenant: str = "default"
    priority: int = 0                 # higher = more important
    deadline: int | None = None       # absolute engine tick; None = never
    ttl: int | None = None            # ticks from submit; resolved into
                                      # ``deadline`` by ``submit()``
    # -- lifecycle --------------------------------------------------------
    state: RequestState = RequestState.PENDING
    output: list[int] = dataclasses.field(default_factory=list)
    error: str | None = None          # diagnosis for FAILED/SHED/EXPIRED
    submit_tick: int = -1             # set by ``submit()``
    finish_tick: int = -1             # tick the request went terminal
    preemptions: int = 0              # times evicted for a more urgent one

    def ticks_needed(self) -> int:
        """Engine ticks to finish from a cold start: one prefill tick
        (emits the first token) plus one decode tick per remaining token.
        An upper bound — EOS may end it earlier."""
        return max(1, self.max_tokens - len(self.output))


_INF = float("inf")


def _deadline_key(req: Request) -> float:
    return _INF if req.deadline is None else float(req.deadline)


def urgency_key(req: Request, seq: int) -> tuple[float, float, int]:
    """EDF-within-priority-class ordering: smaller sorts first.  Arrival
    sequence breaks ties so equal-priority deadline-free traffic is FIFO."""
    return (-float(req.priority), _deadline_key(req), seq)


def feasible(req: Request, now: int) -> bool:
    """Can ``req`` still meet its deadline if admitted on the *next* tick?

    A request admitted at tick ``A`` (its prefill tick, emitting one
    token) finishes — absent EOS — at ``A + ticks_needed() - 1``; the
    earliest a queued request can be admitted is ``now + 1``, so it is
    feasible iff ``now + ticks_needed() <= deadline``.  Infeasible
    (doomed) requests are expired by the deadline sweep instead of
    burning slot time on tokens that can only arrive late."""
    if req.deadline is None:
        return True
    return now + req.ticks_needed() <= req.deadline


def deadline_critical(req: Request, now: int) -> bool:
    """True when a still-feasible ``req`` is nearly out of slack: unless
    it is admitted within the next tick or two it will miss its deadline.
    This is the engine's preemption trigger — preempting earlier wastes a
    victim a naturally freed slot would have avoided; later is too late."""
    if req.deadline is None or not feasible(req, now):
        return False
    return req.deadline - now <= req.ticks_needed() + 1


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-tier policy knobs.  The defaults (unbounded queue, EDF
    with no deadlines/priorities in play) reproduce the legacy FIFO
    engine bit-for-bit, so existing single-tenant callers see no change.

    ``policy="fifo"`` disables *all* overload machinery (ordering,
    shedding-by-displacement, expiry, preemption still honor the other
    flags) — it exists as the measurable baseline for
    ``benchmarks/bench_serving.py``.
    """

    max_queue: int | None = None     # bound on queued requests; None = ∞
    tenant_quota: int | None = None  # max queued per tenant; None = ∞
    policy: str = "edf"              # "edf" | "fifo"
    preemption: bool = True          # evict a lower-priority running
                                     # request for a deadline-critical one
    expire_queued: bool = True       # expire queued requests past deadline
    expire_running: bool = True      # evict running requests past deadline

    def __post_init__(self) -> None:
        if self.policy not in ("edf", "fifo"):
            raise ValueError(f"unknown admission policy {self.policy!r}; "
                             "policies: edf, fifo")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")
        if self.tenant_quota is not None and self.tenant_quota < 1:
            raise ValueError("tenant_quota must be >= 1 (or None)")


class AdmissionQueue:
    """Bounded, quota'd, urgency-ordered queue of PENDING requests.

    Pure data structure + policy: it never mutates ``Request.state`` — the
    engine owns state transitions (and their provenance counters).  All
    decisions are deterministic functions of (config, arrival order,
    request metadata, tick).
    """

    def __init__(self, cfg: AdmissionConfig | None = None):
        self.cfg = cfg if cfg is not None else AdmissionConfig()
        self._items: list[tuple[int, Request]] = []   # (arrival seq, req)
        self._seq = 0

    def __len__(self) -> int:
        return len(self._items)

    def __iter__(self):
        return (req for _, req in self._items)

    def depth_by_tenant(self) -> dict[str, int]:
        depths: dict[str, int] = {}
        for _, req in self._items:
            depths[req.tenant] = depths.get(req.tenant, 0) + 1
        return depths

    # -- enqueue -----------------------------------------------------------
    def offer(self, req: Request, now: int) -> tuple[bool, list[Request],
                                                     str]:
        """Try to enqueue ``req``.  Returns ``(admitted, shed, reason)``:
        ``shed`` lists requests pushed out to make the decision hold —
        either ``[req]`` itself (quota / bound / FIFO overflow) or the
        displaced least-urgent queued request (EDF overflow where ``req``
        is more urgent).  ``reason`` diagnoses the shed, if any."""
        cfg = self.cfg
        if cfg.tenant_quota is not None:
            depth = sum(1 for _, r in self._items if r.tenant == req.tenant)
            if depth >= cfg.tenant_quota:
                return False, [req], (
                    f"tenant {req.tenant!r} over quota "
                    f"({depth}/{cfg.tenant_quota} queued)")
        if cfg.max_queue is not None and len(self._items) >= cfg.max_queue:
            if cfg.policy == "edf":
                worst_i = max(
                    range(len(self._items)),
                    key=lambda i: urgency_key(self._items[i][1],
                                              self._items[i][0]))
                worst_seq, worst = self._items[worst_i]
                # displace only a strictly less urgent request — the
                # incoming one inherits the *next* arrival seq, so an
                # equal-metadata newcomer never bumps an older request
                if urgency_key(req, self._seq) < urgency_key(worst,
                                                             worst_seq):
                    del self._items[worst_i]
                    self._push(req)
                    return True, [worst], (
                        f"queue full (max_queue={cfg.max_queue}); displaced "
                        f"by more urgent rid={req.rid}")
            return False, [req], f"queue full (max_queue={cfg.max_queue})"
        self._push(req)
        return True, [], ""

    def _push(self, req: Request) -> None:
        self._items.append((self._seq, req))
        self._seq += 1

    # -- selection ---------------------------------------------------------
    def _best_index(self) -> int | None:
        if not self._items:
            return None
        if self.cfg.policy == "fifo":
            return 0
        return min(range(len(self._items)),
                   key=lambda i: urgency_key(self._items[i][1],
                                             self._items[i][0]))

    def peek(self) -> Request | None:
        """Most urgent queued request (None when empty)."""
        i = self._best_index()
        return None if i is None else self._items[i][1]

    def pop_next(self) -> Request | None:
        """Remove and return the most urgent queued request."""
        i = self._best_index()
        if i is None:
            return None
        _, req = self._items.pop(i)
        return req

    # -- expiry / teardown ---------------------------------------------------
    def expire(self, now: int) -> list[tuple[Request, str]]:
        """Remove queued requests that can no longer meet their deadline —
        either the deadline has already passed, or the remaining slack is
        smaller than the ticks they still need (doomed: every token they
        would produce is guaranteed late).  Returns ``(request, reason)``
        pairs; the engine marks them EXPIRED."""
        if not self.cfg.expire_queued:
            return []
        expired: list[tuple[Request, str]] = []
        for _, req in self._items:
            if req.deadline is None:
                continue
            if now > req.deadline:
                expired.append((req, f"deadline {req.deadline} passed in "
                                     f"queue at tick {now}"))
            elif not feasible(req, now):
                expired.append((req, (
                    f"infeasible in queue: needs {req.ticks_needed()} ticks "
                    f"but deadline {req.deadline} is "
                    f"{req.deadline - now} ticks away")))
        if expired:
            gone = set(id(r) for r, _ in expired)
            self._items = [(s, r) for s, r in self._items
                           if id(r) not in gone]
        return expired

    def clear(self) -> list[Request]:
        """Remove and return everything still queued (run-teardown path)."""
        out = [req for _, req in self._items]
        self._items = []
        return out
