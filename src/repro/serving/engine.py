"""Continuous-batching inference engine.

vLLM-style slot scheduler shrunk to the essentials, built on the Model
facade's prefill/decode step functions (which are exactly what the dry-run
lowers at production scale):

  * fixed pool of decode slots sharing one stacked KV cache;
  * prefill admission when a slot frees (prefill and decode interleave —
    one engine tick is either one prefill or one batched decode step);
  * per-request sampling params; EOS / max-token completion;
  * deterministic given (seed, arrival order).

Batched decode across slots is itself operator parallelism — every slot's
decode operators fuse into one wave, so the engine's throughput benefits
from the same horizontal batching Opara applies inside a graph.

``calibrate_schedule()`` ties the engine into the measured-profile
calibration cache of its :class:`repro.core.Session`: the engine's step
graph is profiled once (real timings), and every subsequent engine instance
/ re-schedule sharing that session with the same model structure, batch
geometry and hardware hydrates from the cache instead of re-timing (paper
§3.2, "profile each DNN inference only once").  Engines default to the
process-wide :func:`repro.core.default_session`; a serving fleet that wants
isolated (or differently configured) schedule state passes its own
``session=Session(SessionConfig(...))``.
"""
from __future__ import annotations

import dataclasses
import enum
import warnings
import weakref
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import Model
from ..runtime.faults import FaultPlan, get_active as _active_faults
from ..runtime.guard import DegradationWarning
from .sampler import sample_token

# Executable reuse across engine instances (the serving-side analogue of the
# core compiled-plan cache): a jax.jit wrapper created per-engine would
# retrace the decode program for every new engine even when the model is
# unchanged.  Keyed weakly by the model instance so traces die with it.
_DECODE_JIT_CACHE: "weakref.WeakKeyDictionary[Any, Any]" = weakref.WeakKeyDictionary()


def _cached_decode_fn(model: Model):
    fn = _DECODE_JIT_CACHE.get(model)
    if fn is None:
        # close over a weakref, not the model: a strong ref from the cached
        # value would pin the weak key forever and the entry could never be
        # evicted.  At trace time the model is alive (the engine holds it).
        ref = weakref.ref(model)

        def _step(p, c, t, pos):
            m = ref()
            if m is None:
                # a stale cached fn outliving its model used to surface as
                # an opaque AttributeError on None — diagnose it instead
                raise RuntimeError(
                    "decode step: model was garbage-collected; the cached "
                    "decode fn outlived the model it was traced for — "
                    "rebuild the InferenceEngine with a live model")
            return m.decode(p, t, c, pos)

        fn = jax.jit(_step)
        _DECODE_JIT_CACHE[model] = fn
    return fn


class RequestState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    # terminal: this request was poisoned (non-finite logits, prefill
    # failure) and was evicted WITHOUT killing co-batched requests
    FAILED = "failed"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int = 32
    temperature: float = 0.0
    eos_id: int | None = None
    state: RequestState = RequestState.PENDING
    output: list[int] = dataclasses.field(default_factory=list)
    error: str | None = None          # diagnosis when state is FAILED


class InferenceEngine:
    def __init__(self, model: Model, params, max_slots: int = 4,
                 max_len: int = 512, seed: int = 0, calibrate: bool = False,
                 session=None, fault_plan: FaultPlan | None = None):
        self.model = model
        self.params = params
        # repro.core.Session owning this engine's schedule/calibration cache
        # state (None → the process-wide default session, so engines share
        # measured profiles the way the module-global caches used to).
        self.session = session
        # per-engine injection plan (None → $REPRO_FAULT_PLAN, if armed)
        self.fault_plan = fault_plan
        # watchdog latch: once the jitted decode step fails, every later
        # tick runs the eager (uncompiled, sequential-semantics) step —
        # slower, but the batch keeps draining
        self._use_compiled = True
        self.fault_stats = {"decode_faults": 0, "failed_requests": 0,
                            "watchdog_fallbacks": 0}
        self.cfg: ModelConfig = model.cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.rng = jax.random.key(seed)
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.last_token = np.zeros(max_slots, np.int32)
        from ..models.transformer import init_decode_caches
        cache_len = max_len + self.cfg.meta_tokens
        self.caches = init_decode_caches(self.cfg, max_slots, cache_len)
        self._decode = _cached_decode_fn(model)
        # Measured-mode Opara schedule of this engine's step graph, filled by
        # calibrate_schedule().  Engines for the same (model structure, batch
        # geometry, hardware) share one measured profile via the core
        # calibration cache — the first engine times once, later engines and
        # re-schedules hydrate and hit the warm plan-cache path.
        self.schedule_plan = None
        if calibrate:
            self.calibrate_schedule()

    def calibrate_schedule(self, seq: int = 1, n_layers: int | None = None,
                           repeats: int = 1):
        """(Re-)schedule this engine's step graph with measured timings.

        Exports the model's operator DAG at this engine's decode geometry
        (batch = ``max_slots``), binds zero tokens as profiling inputs, and
        plans through this engine's :class:`repro.core.Session` — so the
        single profiling inference is amortized across every engine sharing
        the session with an identical signature (the paper's "profile each
        DNN inference only once").

        The returned plan (also kept on ``self.schedule_plan``) is
        introspection/analysis state — stream assignment, launch order and
        waves over REAL timings for this engine's step, feeding the
        simulator and benchmarks.  The decode hot path itself keeps
        executing through the jitted step function (XLA already fuses the
        batched decode); the calibration's runtime win is that re-planning
        costs a cache lookup instead of a profiling inference.
        """
        from ..core.session import default_session
        from ..models.opgraph_export import build_lm_opgraph

        sess = self.session if self.session is not None else default_session()
        g = build_lm_opgraph(self.cfg, batch=self.max_slots, seq=seq,
                             params=self.params, n_layers=n_layers)
        # measured calibration replays the graph, so every non-input node
        # needs a payload.  Dense and MoE exports (routed ragged fan-out)
        # are fully payload-backed; cost-only operators without shapes
        # (hybrid mamba, rwkv scan) cannot be bound as profiling inputs —
        # degrade to the analytic cost model (one structured warning +
        # ``cache_stats()["calib_degraded_analytic"]``) instead of failing
        # the serve launch with a shape error.
        unbindable = [n.name for n in g
                      if n.fn is None and n.out_shape is None]
        if unbindable:
            sess.note_degradation(
                "calibration_measure", "measured->analytic",
                f"{self.cfg.name!r} exports {len(unbindable)} cost-only "
                f"operators without payloads (e.g. {unbindable[0]!r}); "
                "scheduling on analytic costs")
            self.schedule_plan = sess.plan(g)
            return self.schedule_plan
        inputs = {n.op_id: jnp.zeros(n.out_shape, jnp.int32)
                  for n in g if n.fn is None}
        sess.calibrate(g, inputs, repeats=repeats)
        self.schedule_plan = sess.plan(g)
        return self.schedule_plan

    # -- API ---------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run(self, max_ticks: int = 1000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_ticks):
            if not self.queue and all(s is None for s in self.slots):
                break
            done.extend(self.step())
        return done

    # -- one tick -----------------------------------------------------------------
    def step(self) -> list[Request]:
        free = [i for i, s in enumerate(self.slots) if s is None]
        if free and self.queue:
            return self._admit(free[0], self.queue.pop(0))
        return self._decode_tick()

    def _fail(self, req: Request, reason: str) -> Request:
        """Terminal eviction of ONE poisoned request; co-batched requests
        are untouched (their slots, caches and positions stay live)."""
        req.state = RequestState.FAILED
        req.error = reason
        self.fault_stats["failed_requests"] += 1
        return req

    def _admit(self, slot: int, req: Request) -> list[Request]:
        req.state = RequestState.RUNNING
        if not req.prompt:
            return [self._fail(req, "empty prompt")]
        tokens = jnp.asarray([req.prompt], jnp.int32)
        try:
            logits, cache = self.model.prefill(
                self.params, {"tokens": tokens},
                cache_len=self.max_len + self.cfg.meta_tokens)
        except Exception as exc:
            # a poisoned prompt must not take the engine down — the queue
            # keeps draining and the decode batch never saw this request
            return [self._fail(req, f"prefill failed: {exc!r}")]
        if not bool(np.isfinite(np.asarray(logits)).all()):
            return [self._fail(req, "prefill produced non-finite logits")]
        self.rng, sub = jax.random.split(self.rng)
        first = int(sample_token(logits, sub, req.temperature)[0])
        req.output.append(first)
        if (req.eos_id is not None and first == req.eos_id) \
                or len(req.output) >= req.max_tokens:
            req.state = RequestState.DONE
            return [req]
        # splice the single-request cache into the shared slot cache
        self.caches = jax.tree_util.tree_map(
            lambda big, small: _splice(big, small, slot), self.caches, cache)
        self.slots[slot] = req
        self.pos[slot] = len(req.prompt)
        self.last_token[slot] = first
        return []

    def _decode_tick(self) -> list[Request]:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        token = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits = None
        faults = (self.fault_plan if self.fault_plan is not None
                  else _active_faults())
        if self._use_compiled:
            try:
                logits, caches = self._decode(self.params, self.caches,
                                              token, pos)
                if faults is not None:
                    # raise mode → watchdog; corrupt mode → one poisoned
                    # slot (NaN row), caught per-slot below.  Fired only on
                    # the compiled path so the eager rescue never re-injects.
                    logits = faults.fire("decode_step", payload=logits)
                self.caches = caches
            except Exception as exc:
                # step watchdog: latch onto the eager (uncompiled) step for
                # the rest of this engine's life — the batch keeps draining
                self.fault_stats["decode_faults"] += 1
                self.fault_stats["watchdog_fallbacks"] += 1
                self._use_compiled = False
                warnings.warn(
                    f"decode watchdog: jitted step failed ({exc!r}); "
                    "falling back to the eager decode step",
                    DegradationWarning, stacklevel=2)
                if self.session is not None:
                    self.session.note_degradation(
                        "decode_step", "jitted->eager", repr(exc), warn=False)
                logits = None
        if logits is None:
            try:
                logits, self.caches = self.model.decode(
                    self.params, token, self.caches, pos)
            except Exception as exc:
                # both rungs failed: fail the co-batch explicitly rather
                # than crash mid-tick with slots in limbo
                failed = []
                for i in active:
                    req = self.slots[i]
                    self.slots[i] = None
                    self.pos[i] = 0
                    self.last_token[i] = 0
                    failed.append(self._fail(
                        req, f"decode failed on both rungs: {exc!r}"))
                return failed
        finite_rows = np.isfinite(np.asarray(logits)).all(axis=-1)
        self.rng, sub = jax.random.split(self.rng)
        finished: list[Request] = []
        for i in active:
            req = self.slots[i]
            if not bool(finite_rows[i]):
                # poisoned request: evict THIS slot only; the other slots'
                # logits and cache rows are intact and keep decoding
                self.fault_stats["decode_faults"] += 1
                finished.append(self._fail(
                    req, "decode produced non-finite logits"))
                self.slots[i] = None
                self.pos[i] = 0
                self.last_token[i] = 0
                continue
            t = int(sample_token(logits[i:i + 1], jax.random.fold_in(sub, i),
                                 req.temperature)[0])
            req.output.append(t)
            self.pos[i] += 1
            self.last_token[i] = t
            hit_eos = req.eos_id is not None and t == req.eos_id
            if hit_eos or len(req.output) >= req.max_tokens \
                    or self.pos[i] >= self.max_len - 1:
                req.state = RequestState.DONE
                finished.append(req)
                self.slots[i] = None
                self.pos[i] = 0
                self.last_token[i] = 0
        return finished


def _splice(big, small, slot: int):
    """Insert a batch-1 cache leaf into the shared cache at `slot`.

    Leaves are [L, B, ...] (stacked per layer); `small` comes from a batch-1
    prefill whose sequence axis may be shorter than the slot cache (padded
    by Model.prefill to the engine's max_len).
    """
    if big.ndim != small.ndim:
        raise ValueError(f"cache rank mismatch {big.shape} vs {small.shape}")
    return jax.lax.dynamic_update_index_in_dim(
        big, small[:, 0].astype(big.dtype), slot, axis=1)
