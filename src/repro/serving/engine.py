"""Continuous-batching inference engine with an overload-robust admission tier.

vLLM-style slot scheduler shrunk to the essentials, built on the Model
facade's prefill/decode step functions (which are exactly what the dry-run
lowers at production scale):

  * fixed pool of decode slots sharing one stacked KV cache;
  * prefill admission when a slot frees (prefill and decode interleave —
    one engine tick is either one prefill or one batched decode step);
  * per-request sampling params; EOS / max-token completion;
  * deterministic given (seed, arrival order, deadlines).

In front of the slots sits the admission tier (:mod:`.admission`): a
bounded, per-tenant-quota queue with EDF/priority batch assembly,
load-shedding (terminal ``SHED``), deadline expiry of queued *and* running
requests, and priority preemption of a running request when a
higher-priority one would otherwise miss its deadline.  All of it runs on
the engine's deterministic **tick clock** — no wall time — and every
decision is recorded in ``fault_stats`` (global + per-tenant) and on
``Request.error``.  ``run()`` guarantees every submitted request ends in a
terminal state: leftovers at tick-budget exhaustion are expired, never
silently stranded.

Batched decode across slots is itself operator parallelism — every slot's
decode operators fuse into one wave, so the engine's throughput benefits
from the same horizontal batching Opara applies inside a graph.

``calibrate_schedule()`` ties the engine into the measured-profile
calibration cache of its :class:`repro.core.Session`: the engine's step
graph is profiled once (real timings), and every subsequent engine instance
/ re-schedule sharing that session with the same model structure, batch
geometry and hardware hydrates from the cache instead of re-timing (paper
§3.2, "profile each DNN inference only once").  Engines default to the
process-wide :func:`repro.core.default_session`; a serving fleet that wants
isolated (or differently configured) schedule state passes its own
``session=Session(SessionConfig(...))`` — and per-*tenant* Sessions via
``tenant_sessions=`` so each tenant's shed/expire/preempt provenance lands
in its own ``guard_log``.
"""
from __future__ import annotations

import copy
import warnings
import weakref
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import Model
from ..runtime.faults import FaultInjected, FaultPlan
from ..runtime.faults import get_active as _active_faults
from ..runtime.guard import DegradationWarning
from .admission import (AdmissionConfig, AdmissionQueue, Request,
                        RequestState, TERMINAL_STATES, deadline_critical)
from .kv_pool import (KVPagePool, KVPoolConfig, PageExhausted,
                      page_content_keys)
from .sampler import sample_token

__all__ = ["InferenceEngine", "Request", "RequestState", "AdmissionConfig",
           "TERMINAL_STATES"]

# Executable reuse across engine instances (the serving-side analogue of the
# core compiled-plan cache): a jax.jit wrapper created per-engine would
# retrace the decode program for every new engine even when the model is
# unchanged.  Keyed weakly by the model instance so traces die with it.
_DECODE_JIT_CACHE: "weakref.WeakKeyDictionary[Any, Any]" = weakref.WeakKeyDictionary()


def _cached_decode_fn(model: Model):
    fn = _DECODE_JIT_CACHE.get(model)
    if fn is None:
        # close over a weakref, not the model: a strong ref from the cached
        # value would pin the weak key forever and the entry could never be
        # evicted.  At trace time the model is alive (the engine holds it).
        ref = weakref.ref(model)

        def _step(p, c, t, pos):
            m = ref()
            if m is None:
                # a stale cached fn outliving its model used to surface as
                # an opaque AttributeError on None — diagnose it instead
                raise RuntimeError(
                    "decode step: model was garbage-collected; the cached "
                    "decode fn outlived the model it was traced for — "
                    "rebuild the InferenceEngine with a live model")
            return m.decode(p, t, c, pos)

        fn = jax.jit(_step)
        _DECODE_JIT_CACHE[model] = fn
    return fn


_PAGED_JIT_CACHE: "weakref.WeakKeyDictionary[Any, Any]" = weakref.WeakKeyDictionary()


def _cached_paged_decode_fn(model: Model):
    fn = _PAGED_JIT_CACHE.get(model)
    if fn is None:
        ref = weakref.ref(model)        # same weakref discipline as above

        def _step(p, c, t, bt, pos):
            m = ref()
            if m is None:
                raise RuntimeError(
                    "paged decode step: model was garbage-collected; rebuild "
                    "the InferenceEngine with a live model")
            return m.paged_decode(p, t, c, bt, pos)

        fn = jax.jit(_step)
        _PAGED_JIT_CACHE[model] = fn
    return fn


def _empty_tenant_stats() -> dict[str, int]:
    return {"submitted": 0, "done": 0, "failed": 0, "shed": 0,
            "expired": 0, "preempted": 0}


class InferenceEngine:
    def __init__(self, model: Model, params, max_slots: int = 4,
                 max_len: int = 512, seed: int = 0, calibrate: bool = False,
                 session=None, fault_plan: FaultPlan | None = None,
                 admission: AdmissionConfig | None = None,
                 watchdog_probation: int = 8,
                 tenant_sessions: Mapping[str, Any] | None = None,
                 paged_kv: bool = False, page_size: int = 16,
                 num_pages: int | None = None, prefix_sharing: bool = False,
                 page_bounce_limit: int = 8):
        self.model = model
        self.params = params
        # repro.core.Session owning this engine's schedule/calibration cache
        # state (None → the process-wide default session, so engines share
        # measured profiles the way the module-global caches used to).
        self.session = session
        # per-tenant Sessions (PR 4 isolation): shed/expire/preempt events
        # for a tenant's requests are noted on that tenant's guard_log, so
        # fleets can surface per-tenant degradation provenance
        self.tenant_sessions = dict(tenant_sessions or {})
        # per-engine injection plan (None → $REPRO_FAULT_PLAN, if armed)
        self.fault_plan = fault_plan
        # watchdog latch: once the jitted decode step fails, ticks run the
        # eager (uncompiled, sequential-semantics) step.  After
        # ``watchdog_probation`` clean eager ticks the jitted step is
        # retried ONCE (probation rung); 0 disables probation — the PR 6
        # latch-forever behavior.
        self._use_compiled = True
        self.watchdog_probation = watchdog_probation
        self._eager_clean_ticks = 0
        self.fault_stats = {"decode_faults": 0, "failed_requests": 0,
                            "watchdog_fallbacks": 0, "watchdog_probations": 0,
                            "shed_requests": 0, "expired_requests": 0,
                            "preemptions": 0, "admission_faults": 0,
                            "preempt_faults": 0, "deadline_faults": 0,
                            "page_exhaustions": 0, "page_alloc_faults": 0,
                            "block_table_faults": 0, "page_release_faults": 0,
                            "paged_decode_fallbacks": 0, "page_resumes": 0,
                            "resumed_tokens": 0, "reprefilled_tokens": 0,
                            "by_tenant": {}}
        self.cfg: ModelConfig = model.cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.rng = jax.random.key(seed)
        # deterministic tick clock: one step() == one tick.  Deadlines/TTLs
        # are expressed in ticks — nothing in the overload machinery reads
        # wall time, so every shed/preempt/expire decision replays.
        self.tick = 0
        # admission tier (defaults reproduce the legacy unbounded FIFO for
        # deadline-free single-priority traffic)
        self.admission_cfg = admission if admission is not None \
            else AdmissionConfig()
        self.admission = AdmissionQueue(self.admission_cfg)
        self.accepting = True            # drain() closes admission
        self._terminal: list[Request] = []   # terminal before reaching a slot
        self.slots: list[Request | None] = [None] * max_slots
        self.pos = np.zeros(max_slots, np.int32)
        self.last_token = np.zeros(max_slots, np.int32)
        # paged KV tier: fixed pages + block tables instead of a dense slab.
        # Unsupported combinations degrade to the dense slab with provenance
        # rather than erroring — the ladder's usual posture.
        self.paged = False
        self.prefix_sharing = prefix_sharing
        self.page_bounce_limit = page_bounce_limit
        self.pool: KVPagePool | None = None
        if paged_kv:
            reason = None
            if not model.supports_paged():
                reason = (f"family {self.cfg.family!r} carries recurrent or "
                          "cross-attention state; paged KV needs a "
                          "pure-attention decoder stack")
            else:
                from ..flags import kv_quant
                if kv_quant() and self.cfg.mla is not None:
                    reason = ("kv_quant int8 latent cache is dense-only; "
                              "paged MLA pages the bf16 latent")
            if reason is not None:
                warnings.warn(f"paged_kv unavailable: {reason}; "
                              "using the dense slab cache",
                              DegradationWarning, stacklevel=2)
                if self.session is not None:
                    self.session.note_degradation(
                        "paged_kv", "paged->dense", reason, warn=False)
            else:
                self.paged = True
        if self.paged:
            cache_len = max_len + self.cfg.meta_tokens
            self._pages_per_req = -(-cache_len // page_size)
            if num_pages is None:
                # null page + a full allocation per slot (capacity parity
                # with the dense slab; pass a smaller pool to overcommit)
                num_pages = 1 + max_slots * self._pages_per_req
            self.pool = KVPagePool(KVPoolConfig(num_pages, page_size))
            self.caches = model.init_paged_caches(num_pages, page_size)
            self._paged_decode = _cached_paged_decode_fn(model)
            self._page_bounces: dict[str, int] = {}
        else:
            from ..models.transformer import init_decode_caches
            cache_len = max_len + self.cfg.meta_tokens
            self.caches = init_decode_caches(self.cfg, max_slots, cache_len)
        self._decode = _cached_decode_fn(model)
        # Measured-mode Opara schedule of this engine's step graph, filled by
        # calibrate_schedule().  Engines for the same (model structure, batch
        # geometry, hardware) share one measured profile via the core
        # calibration cache — the first engine times once, later engines and
        # re-schedules hydrate and hit the warm plan-cache path.
        self.schedule_plan = None
        if calibrate:
            self.calibrate_schedule()

    @property
    def queue(self) -> list[Request]:
        """Read-only view of the queued (PENDING) requests, in arrival
        order — the legacy attribute, now backed by the admission tier."""
        return list(self.admission)

    def calibrate_schedule(self, seq: int = 1, n_layers: int | None = None,
                           repeats: int = 1):
        """(Re-)schedule this engine's step graph with measured timings.

        Exports the model's operator DAG at this engine's decode geometry
        (batch = ``max_slots``), binds zero tokens as profiling inputs, and
        plans through this engine's :class:`repro.core.Session` — so the
        single profiling inference is amortized across every engine sharing
        the session with an identical signature (the paper's "profile each
        DNN inference only once").

        The returned plan (also kept on ``self.schedule_plan``) is
        introspection/analysis state — stream assignment, launch order and
        waves over REAL timings for this engine's step, feeding the
        simulator and benchmarks.  The decode hot path itself keeps
        executing through the jitted step function (XLA already fuses the
        batched decode); the calibration's runtime win is that re-planning
        costs a cache lookup instead of a profiling inference.
        """
        from ..core.session import default_session
        from ..models.opgraph_export import build_lm_opgraph

        sess = self.session if self.session is not None else default_session()
        g = build_lm_opgraph(self.cfg, batch=self.max_slots, seq=seq,
                             params=self.params, n_layers=n_layers)
        # measured calibration replays the graph, so every non-input node
        # needs a payload.  Dense and MoE exports (routed ragged fan-out)
        # are fully payload-backed; cost-only operators without shapes
        # (hybrid mamba, rwkv scan) cannot be bound as profiling inputs —
        # degrade to the analytic cost model (one structured warning +
        # ``cache_stats()["calib_degraded_analytic"]``) instead of failing
        # the serve launch with a shape error.
        unbindable = [n.name for n in g
                      if n.fn is None and n.out_shape is None]
        if unbindable:
            sess.note_degradation(
                "calibration_measure", "measured->analytic",
                f"{self.cfg.name!r} exports {len(unbindable)} cost-only "
                f"operators without payloads (e.g. {unbindable[0]!r}); "
                "scheduling on analytic costs")
            self.schedule_plan = sess.plan(g)
            return self.schedule_plan
        inputs = {n.op_id: jnp.zeros(n.out_shape, jnp.int32)
                  for n in g if n.fn is None}
        sess.calibrate(g, inputs, repeats=repeats)
        self.schedule_plan = sess.plan(g)
        return self.schedule_plan

    # -- faults / provenance plumbing ---------------------------------------------
    def _faults(self) -> FaultPlan | None:
        return (self.fault_plan if self.fault_plan is not None
                else _active_faults())

    def _tenant_stats(self, tenant: str) -> dict[str, int]:
        stats = self.fault_stats["by_tenant"].get(tenant)
        if stats is None:
            stats = self.fault_stats["by_tenant"][tenant] = \
                _empty_tenant_stats()
        return stats

    def _tenant_note(self, req: Request, site: str, action: str,
                     reason: str) -> None:
        """Per-tenant degradation provenance: the tenant's Session (if the
        fleet registered one) records the event on ITS guard_log, so tenant
        dashboards see their own shed/expire/preempt history in isolation."""
        sess = self.tenant_sessions.get(req.tenant)
        if sess is not None:
            sess.note_degradation(site, action, reason, warn=False)

    # -- terminal transitions -----------------------------------------------------
    def _fail(self, req: Request, reason: str) -> Request:
        """Terminal eviction of ONE poisoned request; co-batched requests
        are untouched (their slots, caches and positions stay live)."""
        req.state = RequestState.FAILED
        req.error = reason
        req.finish_tick = self.tick
        self.fault_stats["failed_requests"] += 1
        self._tenant_stats(req.tenant)["failed"] += 1
        self._release_pages(req)
        return req

    def _shed(self, req: Request, reason: str) -> Request:
        """Terminal refusal at the admission tier (load shedding)."""
        req.state = RequestState.SHED
        req.error = reason
        req.finish_tick = self.tick
        self.fault_stats["shed_requests"] += 1
        self._tenant_stats(req.tenant)["shed"] += 1
        self._tenant_note(req, "admission_enqueue", "admit->shed", reason)
        self._release_pages(req)
        return req

    def _expire(self, req: Request, reason: str) -> Request:
        """Terminal deadline/tick-budget expiry (queued or running)."""
        req.state = RequestState.EXPIRED
        req.error = reason
        req.finish_tick = self.tick
        self.fault_stats["expired_requests"] += 1
        self._tenant_stats(req.tenant)["expired"] += 1
        self._tenant_note(req, "deadline_check", "request->expired", reason)
        self._release_pages(req)
        return req

    def _complete(self, req: Request) -> Request:
        req.state = RequestState.DONE
        req.finish_tick = self.tick
        self._tenant_stats(req.tenant)["done"] += 1
        self._release_pages(req)
        return req

    def _release_pages(self, req: Request) -> None:
        """Free ``req``'s KV pages on ANY terminal transition (preemption is
        not terminal — a preempted request keeps its pages and resumes
        without re-prefill).  An injected ``page_release`` fault models a
        lost free: the pages leak (counted, capacity shrinks) instead of
        corrupting the free list."""
        if not self.paged or not self.pool.holds(req.rid):
            return
        faults = self._faults()
        if faults is not None:
            try:
                faults.fire("page_release")
            except FaultInjected as exc:
                self.fault_stats["page_release_faults"] += 1
                n = self.pool.leak(req.rid)
                reason = f"{exc}: {n} pages leaked"
                self._tenant_note(req, "page_release", "release->leaked", reason)
                if self.session is not None:
                    self.session.note_degradation(
                        "page_release", "release->leaked", reason, warn=False)
                self._page_bounces.pop(req.rid, None)
                return
        self.pool.release(req.rid)
        self._page_bounces.pop(req.rid, None)

    def _clear_slot(self, slot: int) -> None:
        self.slots[slot] = None
        self.pos[slot] = 0
        self.last_token[slot] = 0

    # -- API ---------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        """Offer ``req`` to the admission tier.

        May immediately take the request terminal: SHED (queue bound,
        tenant quota, draining engine, injected admission fault) or FAILED
        (prompt exceeds the KV capacity).  Terminal-at-submit requests are
        still returned by ``run()``/``step()`` — nothing vanishes.
        """
        if req.submit_tick < 0:
            req.submit_tick = self.tick
        if req.deadline is None and req.ttl is not None:
            req.deadline = req.submit_tick + req.ttl
        self._tenant_stats(req.tenant)["submitted"] += 1
        if not self.accepting:
            self._terminal.append(
                self._shed(req, "engine draining: admission closed"))
            return req
        faults = self._faults()
        if faults is not None:
            try:
                faults.fire("admission_enqueue")
            except FaultInjected as exc:
                # overload ladder: an admission-path fault sheds THIS
                # request with provenance instead of crashing the engine
                self.fault_stats["admission_faults"] += 1
                self._terminal.append(self._shed(req, f"{exc}"))
                return req
        # KV-capacity check at admission (not at slot time): a prompt that
        # cannot fit the slot cache used to be spliced anyway — pos[slot]
        # started out of bounds and decode writes silently clamped.  Reject
        # with a diagnosis; need >= 1 decode position after the prompt.
        n_tokens = len(req.prompt) + len(req.output)
        if n_tokens >= self.max_len:
            self._terminal.append(self._fail(req, (
                f"prompt length {n_tokens} exceeds KV capacity "
                f"(max_len={self.max_len} incl. at least one decode "
                "position); rejected at admission")))
            return req
        admitted, shed, reason = self.admission.offer(req, self.tick)
        for victim in shed:
            self._terminal.append(self._shed(victim, reason))
        return req

    def run(self, max_ticks: int = 1000) -> list[Request]:
        """Tick until all work is terminal or ``max_ticks`` is exhausted.

        On tick-budget exhaustion every queued/running leftover is expired
        with ``error="tick budget exhausted"`` — no request ever silently
        vanishes; the returned list covers every submitted request.
        """
        done: list[Request] = []
        for _ in range(max_ticks):
            if not self._work_pending():
                break
            done.extend(self.step())
        done.extend(self._drain_terminal())
        leftovers = self.admission.clear()
        for i, req in enumerate(self.slots):
            if req is not None:
                leftovers.append(req)
                self._clear_slot(i)
        for req in leftovers:
            done.append(self._expire(req, "tick budget exhausted"))
        return done

    def drain(self, max_ticks: int = 1000) -> list[Request]:
        """Engine lifecycle: close admission and finish in-flight work so a
        fleet can rotate this engine out safely.  Requests submitted after
        ``drain()`` begins are shed with a "draining" diagnosis."""
        self.accepting = False
        return self.run(max_ticks)

    def health(self) -> dict[str, Any]:
        """Structured liveness/pressure snapshot for fleet managers."""
        running = sum(1 for s in self.slots if s is not None)
        return {
            "tick": self.tick,
            "accepting": self.accepting,
            "queued": len(self.admission),
            "queued_by_tenant": self.admission.depth_by_tenant(),
            "running": running,
            "free_slots": self.max_slots - running,
            "compiled_decode": self._use_compiled,
            "paged": self.pool.health() if self.paged else None,
            "kv_cache_bytes": self.kv_cache_bytes(),
            "fault_stats": copy.deepcopy(self.fault_stats),
        }

    def kv_cache_bytes(self) -> int:
        """Total bytes held by the KV cache (dense slab or page pool)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.caches))

    # -- one tick -----------------------------------------------------------------
    def step(self) -> list[Request]:
        self.tick += 1
        out = self._drain_terminal()
        out.extend(self._deadline_sweep())
        free = [i for i, s in enumerate(self.slots) if s is None]
        if free and len(self.admission):
            req = self.admission.pop_next()
            out.extend(self._admit(free[0], req))
            return out
        if not free and len(self.admission) and self.admission_cfg.preemption:
            out.extend(self._maybe_preempt())
        out.extend(self._paged_decode_tick() if self.paged
                   else self._decode_tick())
        return out

    def _work_pending(self) -> bool:
        return bool(len(self.admission) or self._terminal
                    or any(s is not None for s in self.slots))

    def _drain_terminal(self) -> list[Request]:
        out, self._terminal = self._terminal, []
        return out

    def _deadline_sweep(self) -> list[Request]:
        """Expire queued requests that can no longer meet their deadline
        and evict running requests whose deadline has passed (reusing the
        per-slot eviction path — co-batched slots stay live)."""
        out: list[Request] = []
        faults = self._faults()
        if faults is not None:
            try:
                faults.fire("deadline_check")
            except FaultInjected:
                # ladder: a faulted sweep skips ONE tick of expiry — every
                # request simply lives one tick longer; nothing crashes
                self.fault_stats["deadline_faults"] += 1
                return out
        for req, reason in self.admission.expire(self.tick):
            out.append(self._expire(req, reason))
        if self.admission_cfg.expire_running:
            for i, req in enumerate(self.slots):
                if req is None or req.deadline is None:
                    continue
                if self.tick > req.deadline:
                    self._clear_slot(i)
                    out.append(self._expire(req, (
                        f"deadline {req.deadline} passed at tick "
                        f"{self.tick} with {len(req.output)} tokens "
                        "generated; slot evicted")))
        return out

    def _maybe_preempt(self) -> list[Request]:
        """Evict the least-important running request when the most urgent
        queued one is deadline-critical and strictly higher priority.  The
        victim returns to the queue PENDING (output retained — it resumes
        by re-prefilling prompt+output on re-admission)."""
        cand = self.admission.peek()
        if cand is None or not deadline_critical(cand, self.tick):
            return []
        running = [(i, req) for i, req in enumerate(self.slots)
                   if req is not None]
        if not running:
            return []
        # least important victim: lowest priority, then most deadline
        # slack (None = infinite), then lowest slot index — deterministic
        slot, victim = min(
            running,
            key=lambda it: (it[1].priority,
                            -(float("inf") if it[1].deadline is None
                              else float(it[1].deadline)), it[0]))
        if victim.priority >= cand.priority:
            return []
        faults = self._faults()
        if faults is not None:
            try:
                faults.fire("slot_preempt")
            except FaultInjected:
                # ladder: a faulted preemption is skipped — the critical
                # request waits (and may expire), the victim keeps running
                self.fault_stats["preempt_faults"] += 1
                return []
        self._clear_slot(slot)
        victim.state = RequestState.PENDING
        victim.preemptions += 1
        self.fault_stats["preemptions"] += 1
        self._tenant_stats(victim.tenant)["preempted"] += 1
        reason = (f"slot {slot} preempted at tick {self.tick} for "
                  f"rid={cand.rid} (priority {cand.priority} > "
                  f"{victim.priority}, deadline {cand.deadline})")
        self._tenant_note(victim, "slot_preempt", "running->requeued", reason)
        admitted, shed, shed_reason = self.admission.offer(victim, self.tick)
        for req in shed:
            self._terminal.append(
                self._shed(req, f"preempted then {shed_reason}"))
        return []

    def _admit(self, slot: int, req: Request) -> list[Request]:
        req.state = RequestState.RUNNING
        if not req.prompt:
            return [self._fail(req, "empty prompt")]
        # a preempted request resumes by replaying prompt + generated
        # tokens as the prefill stream; generation continues where it left
        # off (same math — the KV it lost is rebuilt, not approximated)
        tokens_list = list(req.prompt) + list(req.output)
        if len(tokens_list) >= self.max_len:
            # unreachable for requests that passed the submit-time check
            # (a preempted slot always sits below max_len - 1), but a
            # silent out-of-bounds splice must never come back
            return [self._fail(req, (
                f"token stream length {len(tokens_list)} exceeds KV "
                f"capacity (max_len={self.max_len}) at slot admission"))]
        if self.paged:
            return self._admit_paged(slot, req, tokens_list)
        if req.output:
            # a dense re-admission rebuilds the whole KV from scratch —
            # count the re-prefilled tokens so the paged path's zero here
            # is a measurable win, not an assertion
            self.fault_stats["reprefilled_tokens"] += len(tokens_list)
        tokens = jnp.asarray([tokens_list], jnp.int32)
        try:
            logits, cache = self.model.prefill(
                self.params, {"tokens": tokens},
                cache_len=self.max_len + self.cfg.meta_tokens)
        except Exception as exc:
            # a poisoned prompt must not take the engine down — the queue
            # keeps draining and the decode batch never saw this request
            return [self._fail(req, f"prefill failed: {exc!r}")]
        if not bool(np.isfinite(np.asarray(logits)).all()):
            return [self._fail(req, "prefill produced non-finite logits")]
        self.rng, sub = jax.random.split(self.rng)
        first = int(sample_token(logits, sub, req.temperature)[0])
        req.output.append(first)
        if (req.eos_id is not None and first == req.eos_id) \
                or len(req.output) >= req.max_tokens:
            return [self._complete(req)]
        # splice the single-request cache into the shared slot cache
        self.caches = jax.tree_util.tree_map(
            lambda big, small: _splice(big, small, slot), self.caches, cache)
        self.slots[slot] = req
        self.pos[slot] = len(tokens_list)
        self.last_token[slot] = first
        return []

    # -- paged KV path ------------------------------------------------------------
    def _admit_paged(self, slot: int, req: Request,
                     tokens_list: list[int]) -> list[Request]:
        """Paged admission: allocate pages, prefill, scatter into pages.

        A preempted request that still holds pages takes the resume
        fast-path — no re-prefill, its KV never left the pool."""
        if self.pool.holds(req.rid) and req.output:
            return self._resume_paged(slot, req, tokens_list)
        ps = self.pool.page_size
        meta = self.cfg.meta_tokens
        n_pos = len(tokens_list) + meta
        had_output = bool(req.output)
        faults = self._faults()
        keys = None
        shared = 0
        if self.prefix_sharing and not req.output:
            keys = page_content_keys(self.cfg.name, ps, tokens_list, meta)
            shared = self.pool.adopt_shared(req.rid, keys, req.tenant)
        try:
            if faults is not None:
                faults.fire("page_alloc")
            self.pool.ensure(req.rid, n_pos, req.tenant)
        except FaultInjected as exc:
            self.fault_stats["page_alloc_faults"] += 1
            return self._page_pressure(req, f"{exc}")
        except PageExhausted as exc:
            self.fault_stats["page_exhaustions"] += 1
            return self._page_pressure(req, str(exc))
        tokens = jnp.asarray([tokens_list], jnp.int32)
        try:
            # page-aligned dense intermediate so the scatter below covers
            # every written position without bounds logic
            logits, cache = self.model.prefill(
                self.params, {"tokens": tokens},
                cache_len=self._pages_per_req * ps)
        except Exception as exc:
            return [self._fail(req, f"prefill failed: {exc!r}")]
        if not bool(np.isfinite(np.asarray(logits)).all()):
            return [self._fail(req, "prefill produced non-finite logits")]
        self.rng, sub = jax.random.split(self.rng)
        first = int(sample_token(logits, sub, req.temperature)[0])
        req.output.append(first)
        if had_output:
            self.fault_stats["reprefilled_tokens"] += len(tokens_list)
        if (req.eos_id is not None and first == req.eos_id) \
                or len(req.output) >= req.max_tokens:
            return [self._complete(req)]
        self._scatter_pages(req, cache, n_pos, skip_pages=shared)
        if keys is not None:
            self.pool.publish_keys(req.rid, keys)
        self.slots[slot] = req
        self.pos[slot] = len(tokens_list)
        self.last_token[slot] = first
        return []

    def _resume_paged(self, slot: int, req: Request,
                      tokens_list: list[int]) -> list[Request]:
        """Resume a preempted request from its retained pages: restore slot
        state and decode ONE token (the tick a dense engine would spend
        re-prefilling).  Other slots' page writes during the batched step
        are value-identical to next tick's — idempotent."""
        pos_i = len(tokens_list) - 1
        wp = pos_i + self.cfg.meta_tokens
        faults = self._faults()
        try:
            if faults is not None:
                faults.fire("page_alloc")
            self.pool.ensure(req.rid, wp + 1, req.tenant)
            page, copy_src = self.pool.writable_page(req.rid, wp)
        except FaultInjected as exc:
            self.fault_stats["page_alloc_faults"] += 1
            return self._page_pressure(req, f"{exc}")
        except PageExhausted as exc:
            self.fault_stats["page_exhaustions"] += 1
            return self._page_pressure(req, str(exc))
        if copy_src is not None:
            self._copy_page(page, copy_src)
        self.slots[slot] = req
        self.pos[slot] = pos_i
        self.last_token[slot] = tokens_list[-1]
        token = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits = None
        try:
            if faults is not None:
                faults.fire("block_table_build")
            bt = jnp.asarray(self._block_table_array())
            logits, caches = self._paged_decode(self.params, self.caches,
                                                token, bt, pos)
            self.caches = caches
        except Exception as exc:
            logits = self._paged_fallback(exc)
            if logits is None:
                self._clear_slot(slot)
                return [self._fail(
                    req, f"paged resume decode failed: {exc!r}")]
        if not bool(np.isfinite(np.asarray(logits[slot])).all()):
            self._clear_slot(slot)
            return [self._fail(req, "resume decode produced non-finite logits")]
        self.rng, sub = jax.random.split(self.rng)
        nxt = int(sample_token(logits[slot:slot + 1], sub,
                               req.temperature)[0])
        req.output.append(nxt)
        self.fault_stats["page_resumes"] += 1
        self.fault_stats["resumed_tokens"] += len(tokens_list)
        hit_eos = req.eos_id is not None and nxt == req.eos_id
        if hit_eos or len(req.output) >= req.max_tokens \
                or pos_i + 1 >= self.max_len - 1:
            self._clear_slot(slot)
            return [self._complete(req)]
        self.pos[slot] = pos_i + 1
        self.last_token[slot] = nxt
        return []

    def _page_pressure(self, req: Request, reason: str) -> list[Request]:
        """Page exhaustion / allocation fault: release what the request
        held and feed it back to the admission tier (the queue's shed and
        quota machinery owns the overload decision).  A request that
        bounces past ``page_bounce_limit`` — or that cannot fit even an
        empty pool — is shed."""
        self.pool.release(req.rid)       # direct: pressure, not a fault site
        bounces = self._page_bounces.get(req.rid, 0) + 1
        self._page_bounces[req.rid] = bounces
        if bounces > self.page_bounce_limit or not self.pool.holders():
            self._page_bounces.pop(req.rid, None)
            return [self._shed(req, (
                f"page pressure: {reason} "
                f"(bounced {bounces}x, limit {self.page_bounce_limit})"))]
        req.state = RequestState.PENDING
        self._tenant_note(req, "page_alloc", "running->requeued", reason)
        admitted, shed, shed_reason = self.admission.offer(req, self.tick)
        return [self._shed(victim, f"page pressure requeue: {shed_reason}")
                for victim in shed]

    def _scatter_pages(self, req: Request, cache, n_pos: int,
                       skip_pages: int = 0) -> None:
        """Scatter a batch-1 dense prefill cache into this request's pages
        (skipping pages adopted via prefix sharing — already resident)."""
        ps = self.pool.page_size
        table = np.asarray(self.pool.table(req.rid), np.int32)
        positions = np.arange(skip_pages * ps, n_pos)
        if positions.size == 0:
            return
        pages = table[positions // ps]
        offs = positions % ps

        def scat(paged_leaf, dense_leaf):
            return paged_leaf.at[:, pages, offs].set(
                dense_leaf[:, 0, positions].astype(paged_leaf.dtype))

        self.caches = jax.tree_util.tree_map(scat, self.caches, cache)

    def _copy_page(self, dst: int, src: int) -> None:
        """Copy-on-write materialization: duplicate page ``src`` into the
        freshly allocated ``dst`` across every layer's leaves."""
        self.caches = jax.tree_util.tree_map(
            lambda leaf: leaf.at[:, dst].set(leaf[:, src]), self.caches)

    def _block_table_array(self) -> np.ndarray:
        """[max_slots, pages_per_req] int32; unused entries point at the
        null page 0 (decode masks by length, never by table bounds)."""
        bt = np.zeros((self.max_slots, self._pages_per_req), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            table = self.pool.table(req.rid)
            bt[i, :len(table)] = table[:self._pages_per_req]
        return bt

    def _paged_decode_tick(self) -> list[Request]:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        out: list[Request] = []
        faults = self._faults()
        still = []
        for i in active:
            req = self.slots[i]
            wp = int(self.pos[i]) + self.cfg.meta_tokens
            try:
                if faults is not None:
                    faults.fire("page_alloc")
                self.pool.ensure(req.rid, wp + 1, req.tenant)
                page, copy_src = self.pool.writable_page(req.rid, wp)
            except FaultInjected as exc:
                self.fault_stats["page_alloc_faults"] += 1
                self._clear_slot(i)
                out.extend(self._page_pressure(req, f"{exc}"))
                continue
            except PageExhausted as exc:
                self.fault_stats["page_exhaustions"] += 1
                self._clear_slot(i)
                out.extend(self._page_pressure(req, str(exc)))
                continue
            if copy_src is not None:
                self._copy_page(page, copy_src)
            still.append(i)
        if not still:
            return out
        token = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits = None
        try:
            if faults is not None:
                faults.fire("block_table_build")
            bt = jnp.asarray(self._block_table_array())
            logits, caches = self._paged_decode(self.params, self.caches,
                                                token, bt, pos)
            if faults is not None:
                logits = faults.fire("decode_step", payload=logits)
            self.caches = caches
        except Exception as exc:
            logits = self._paged_fallback(exc)
            if logits is None:
                for i in still:
                    req = self.slots[i]
                    self._clear_slot(i)
                    out.append(self._fail(
                        req, f"paged decode failed on both rungs: {exc!r}"))
                return out
        out.extend(self._advance_slots(still, logits))
        return out

    def _paged_fallback(self, exc: Exception):
        """Ladder rung ``paged_decode → dense-gather``: gather the pages
        into a contiguous slab and run the eager dense decode step.  Returns
        logits, or None when the rescue rung itself failed."""
        if isinstance(exc, FaultInjected):
            self.fault_stats["block_table_faults"] += 1
        self.fault_stats["paged_decode_fallbacks"] += 1
        warnings.warn(
            f"paged decode failed ({exc!r}); falling back to the "
            "dense-gather decode step", DegradationWarning, stacklevel=3)
        if self.session is not None:
            self.session.note_degradation(
                "paged_decode", "paged->dense-gather", repr(exc), warn=False)
        try:
            return self._dense_gather_decode()
        except Exception:
            return None

    def _dense_gather_decode(self):
        """Gather every slot's pages into a dense [L,B,T,...] slab, run the
        eager dense decode, scatter ONLY the newly written position back
        into the pages.  Built without firing fault sites — the rescue rung
        must not re-inject."""
        bt_np = self._block_table_array()
        bt = jnp.asarray(bt_np)
        maxp, ps = self._pages_per_req, self.pool.page_size

        def gather(leaf):
            g = leaf[:, bt]                      # [L, B, MAXP, ps, ...]
            return g.reshape(g.shape[0], g.shape[1], maxp * ps, *g.shape[4:])

        dense = jax.tree_util.tree_map(gather, self.caches)
        logits, new_dense = self.model.decode(
            self.params, jnp.asarray(self.last_token), dense,
            jnp.asarray(self.pos))
        rows = [i for i, r in enumerate(self.slots) if r is not None]
        if rows:
            wp = np.array([int(self.pos[i]) + self.cfg.meta_tokens
                           for i in rows], np.int32)
            pages = bt_np[rows, wp // ps]
            offs = wp % ps
            rows_a = np.array(rows, np.int32)

            def scat(paged_leaf, dense_leaf):
                return paged_leaf.at[:, pages, offs].set(
                    dense_leaf[:, rows_a, wp].astype(paged_leaf.dtype))

            self.caches = jax.tree_util.tree_map(scat, self.caches, new_dense)
        return logits

    def _decode_tick(self) -> list[Request]:
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        token = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits = None
        faults = self._faults()
        if self._use_compiled:
            try:
                logits, caches = self._decode(self.params, self.caches,
                                              token, pos)
                if faults is not None:
                    # raise mode → watchdog; corrupt mode → one poisoned
                    # slot (NaN row), caught per-slot below.  Fired only on
                    # the compiled path so the eager rescue never re-injects.
                    logits = faults.fire("decode_step", payload=logits)
                self.caches = caches
            except Exception as exc:
                # step watchdog: latch onto the eager (uncompiled) step —
                # the batch keeps draining.  The probation rung below may
                # retry the jitted step after enough clean eager ticks.
                self.fault_stats["decode_faults"] += 1
                self.fault_stats["watchdog_fallbacks"] += 1
                self._use_compiled = False
                self._eager_clean_ticks = 0
                warnings.warn(
                    f"decode watchdog: jitted step failed ({exc!r}); "
                    "falling back to the eager decode step",
                    DegradationWarning, stacklevel=2)
                if self.session is not None:
                    self.session.note_degradation(
                        "decode_step", "jitted->eager", repr(exc), warn=False)
                logits = None
        if logits is None:
            try:
                logits, self.caches = self.model.decode(
                    self.params, token, self.caches, pos)
            except Exception as exc:
                # both rungs failed: fail the co-batch explicitly rather
                # than crash mid-tick with slots in limbo
                failed = []
                for i in active:
                    req = self.slots[i]
                    self._clear_slot(i)
                    failed.append(self._fail(
                        req, f"decode failed on both rungs: {exc!r}"))
                return failed
            # probation rung: after N clean eager ticks, un-latch and retry
            # the jitted step once next tick instead of staying eager
            # forever.  If it fails again the watchdog re-latches (counters
            # keep the history); 0 disables probation.
            if not self._use_compiled and self.watchdog_probation > 0:
                self._eager_clean_ticks += 1
                if self._eager_clean_ticks >= self.watchdog_probation:
                    self._use_compiled = True
                    self._eager_clean_ticks = 0
                    self.fault_stats["watchdog_probations"] += 1
                    if self.session is not None:
                        self.session.note_degradation(
                            "decode_step", "eager->jitted (probation)",
                            f"{self.watchdog_probation} clean eager ticks; "
                            "retrying the jitted decode step", warn=False)
        return self._advance_slots(active, logits)

    def _advance_slots(self, active: list[int], logits) -> list[Request]:
        """Per-slot sampling/completion tail shared by the dense and paged
        decode ticks (identical rng discipline → identical token streams)."""
        finite_rows = np.isfinite(np.asarray(logits)).all(axis=-1)
        self.rng, sub = jax.random.split(self.rng)
        finished: list[Request] = []
        for i in active:
            req = self.slots[i]
            if not bool(finite_rows[i]):
                # poisoned request: evict THIS slot only; the other slots'
                # logits and cache rows are intact and keep decoding
                self.fault_stats["decode_faults"] += 1
                finished.append(self._fail(
                    req, "decode produced non-finite logits"))
                self._clear_slot(i)
                continue
            t = int(sample_token(logits[i:i + 1], jax.random.fold_in(sub, i),
                                 req.temperature)[0])
            req.output.append(t)
            self.pos[i] += 1
            self.last_token[i] = t
            hit_eos = req.eos_id is not None and t == req.eos_id
            if hit_eos or len(req.output) >= req.max_tokens \
                    or self.pos[i] >= self.max_len - 1:
                finished.append(self._complete(req))
                self._clear_slot(i)
        return finished


def _splice(big, small, slot: int):
    """Insert a batch-1 cache leaf into the shared cache at `slot`.

    Leaves are [L, B, ...] (stacked per layer); `small` comes from a batch-1
    prefill whose sequence axis may be shorter than the slot cache (padded
    by Model.prefill to the engine's max_len).
    """
    if big.ndim != small.ndim:
        raise ValueError(f"cache rank mismatch {big.shape} vs {small.shape}")
    return jax.lax.dynamic_update_index_in_dim(
        big, small[:, 0].astype(big.dtype), slot, axis=1)
