"""Quickstart: the whole Opara pipeline in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a branchy operator graph and hands it to a ``Session`` — Stream
Allocation (Alg. 1) + resource/interference-aware launch ordering (Alg. 2) +
capture into ONE fused executable (the CUDA-Graph analogue) behind a single
``compile()`` call — then verifies it against eager op-by-op execution and
shows the cache provenance ``explain()`` reports on the cold vs warm path.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp
import numpy as np

from benchmarks.conftest_shim import build_payload_graph
from repro.core import Session, run_sequential_uncompiled

g = build_payload_graph(n_blocks=4, width=4, d=64, tokens=8)
print(f"graph: {len(g)} operators, max width {g.max_width()}")

sess = Session()                              # config-scoped caches
model = sess.compile(g)                       # plan + capture → executable
plan = model.plan
print(f"streams: {plan.n_streams}   waves: {plan.waves.n_waves}   "
      f"kernels after fusion: {plan.waves.n_fused_kernels}")

x = jnp.ones((8, 64), jnp.float32)
out = model({"x": x})[0]
ref = run_sequential_uncompiled(g, {"x": x})[0]
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
print("fused executable matches eager execution ✓")

warm = sess.compile(g)                        # second compile: all cache hits
for m, label in ((model, "cold"), (warm, "warm")):
    rep = m.explain()
    print(f"{label}: cache={rep['cache']}  "
          f"total={rep['stages_ms']['total']:.2f} ms")
assert warm.explain()["cache"] == {"calibration": "off", "plan": "hit",
                                   "executable": "hit"}
