"""Quickstart: the whole Opara pipeline in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a branchy operator graph, runs Stream Allocation (Alg. 1) +
resource/interference-aware launch ordering (Alg. 2), captures ONE fused
executable (the CUDA-Graph analogue), and verifies it against eager
op-by-op execution.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax.numpy as jnp
import numpy as np

from benchmarks.conftest_shim import build_payload_graph
from repro.core import api as opara
from repro.core import run_sequential_uncompiled

g = build_payload_graph(n_blocks=4, width=4, d=64, tokens=8)
print(f"graph: {len(g)} operators, max width {g.max_width()}")

plan = opara.plan(g)
print(f"streams: {plan.n_streams}   waves: {plan.waves.n_waves}   "
      f"kernels after fusion: {plan.waves.n_fused_kernels}")

exe = opara.optimize(g)                       # capture → single executable
x = jnp.ones((8, 64), jnp.float32)
out = exe({"x": x})[0]
ref = run_sequential_uncompiled(g, {"x": x})[0]
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)
print("fused executable matches eager execution ✓")
