"""Serving example: continuous-batching engine over a smoke llama model.

    PYTHONPATH=src python examples/serve_llm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve

res = serve("llama3.2-1b", n_requests=8, max_tokens=12, slots=4)
print(f"\nthroughput: {res['tok_per_s']:.1f} tok/s "
      f"({res['completed']} requests, {res['total_tokens']} tokens)")
