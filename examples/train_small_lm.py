"""End-to-end training driver example: train a ~tiny llama-family model for
a few hundred steps with checkpointing, then resume.

    PYTHONPATH=src python examples/train_small_lm.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train

ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
res = train("llama3.2-1b", smoke=True, steps=200, batch=8, seq=64,
            ckpt_dir=ckpt, resume=False, ckpt_every=50, log_every=25)
print(f"\nloss {res['first_loss']:.3f} → {res['last_loss']:.3f} "
      f"in {res['steps']} steps ({res['wall_s']:.1f}s)")

print("\n-- simulated restart (picks up from the latest checkpoint) --")
res2 = train("llama3.2-1b", smoke=True, steps=220, batch=8, seq=64,
             ckpt_dir=ckpt, resume=True, ckpt_every=50, log_every=10)
print(f"resumed and reached loss {res2['last_loss']:.3f}")
