"""Schedule anatomy on REAL architectures: how Opara sees Kimi-K2's expert
fan-out, Hymba's parallel attn∥SSM heads, and RWKV6's 5-projection blocks.

    PYTHONPATH=src python examples/opara_schedule_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_inference import BENCH_HW as HW, BENCH_SIM
from repro.configs import get_config
from repro.core import Session, compare_policies
from repro.models.opgraph_export import build_lm_opgraph

# one session for the whole demo: every (arch, seq) schedule lands in its
# plan cache, so re-running a geometry would be a cache hit
sess = Session(hw=HW, sim_cfg=BENCH_SIM)

for arch in ("kimi-k2-1t-a32b", "hymba-1.5b", "rwkv6-1.6b", "qwen2-0.5b"):
    cfg = get_config(arch)
    for seq_len, regime in ((32, "decode/small-op regime"),
                            (4096, "prefill/saturated regime")):
        g = build_lm_opgraph(cfg, batch=1, seq=seq_len, n_layers=2)
        plan = sess.plan(g)
        s = plan.stats()
        print(f"\n=== {arch} @ seq={seq_len} ({regime}; {len(g)} ops) ===")
        print(f"  streams={int(s['n_streams'])}  waves={int(s['n_waves'])}  "
              f"fusion {int(s['n_ops'])}→{int(s['n_kernels_after_fusion'])} kernels")
        res = compare_policies(g, hw=HW, cfg=BENCH_SIM)
        base = res["cuda_graph_sequential"]["makespan_us"]
        for policy in ("cuda_graph_sequential", "nimble", "opara"):
            r = res[policy]
            print(f"  {policy:24s} {r['makespan_us']:9.1f} us   "
                  f"{base / r['makespan_us']:.2f}x vs sequential")

print("\nNOTE: operator parallelism pays in the small-op regime (the paper's"
      "\nFig. 1 under-utilization); at prefill scale single GEMMs saturate"
      "\nthe device and Opara correctly degrades to the sequential schedule.")
