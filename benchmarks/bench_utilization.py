"""Fig. 5b / Fig. 1: device-utilization proxy (busy-time / makespan — the
simulator twin of SM efficiency) and peak-concurrency statistics."""
from __future__ import annotations

from repro.core import SimConfig, schedule, simulate_plan
from repro.core.fusion import fusion_stats

from .bench_inference import BENCH_HW, BENCH_SIM
from .workloads import PAPER_WORKLOADS


def run() -> list[str]:
    # avg_concurrency = busy-time / makespan (1.0 = sequential; >1 = parallel
    # lanes active) — the simulator twin of the paper's SM-efficiency gain.
    rows = ["workload,policy,avg_concurrency,n_streams,fusion_ratio"]
    for name, fn in PAPER_WORKLOADS.items():
        g = fn(1)
        for alloc, order, label in (
                ("sequential", "topo", "cuda_graph"),
                ("nimble", "topo", "nimble"),
                ("opara", "opara", "opara")):
            plan = schedule(g, alloc, order, BENCH_HW)
            res = simulate_plan(plan, BENCH_SIM)
            conc = res.busy_us / res.makespan_us
            fr = fusion_stats(plan.waves)["fusion_ratio"]
            rows.append(f"{name},{label},{conc:.2f},{plan.n_streams},{fr:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
