"""Table 1: scheduling-algorithm computation time — Opara Alg. 1 (O(n)) vs
Nimble's bipartite min-path-cover (O(n³) with transitive closure)."""
from __future__ import annotations

import time

from repro.core.nimble import allocate_streams_nimble
from repro.core.stream_alloc import allocate_streams

from .workloads import PAPER_WORKLOADS, arch_workload


def _time_ms(fn, *args, repeats: int = 5) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def run() -> list[str]:
    rows = ["workload,n_ops,opara_ms,nimble_ms,ratio"]
    graphs = {name: fn(1) for name, fn in PAPER_WORKLOADS.items()}
    graphs["kimi-k2 (4L)"] = arch_workload("kimi-k2-1t-a32b")
    graphs["hymba (4L)"] = arch_workload("hymba-1.5b")
    for name, g in graphs.items():
        t_opara = _time_ms(allocate_streams, g)
        t_nimble = _time_ms(allocate_streams_nimble, g)
        rows.append(f"{name},{len(g)},{t_opara:.3f},{t_nimble:.3f},"
                    f"{t_nimble / max(t_opara, 1e-9):.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
