"""Table 1: scheduling-algorithm computation time — Opara Alg. 1 (O(n)) vs
Nimble's bipartite min-path-cover (O(n³) with transitive closure) — plus the
full-pipeline schedule time and the compiled-plan-cache hit time per
workload (second schedule of an identical graph signature).

Also records the measured-mode calibration trajectory: cold schedule time
(one profiling inference + schedule) vs warm (calibration-cache hydration +
plan-cache hit), with the hit/miss counters, on the payload-bearing graph.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import Session, autotune, schedule

from repro.core.nimble import allocate_streams_nimble
from repro.core.stream_alloc import allocate_streams

from .conftest_shim import build_payload_graph
from .workloads import PAPER_WORKLOADS, arch_workload, moe_ragged_workload

# structured records picked up by benchmarks/run.py → BENCH_scheduler.json
RECORDS: list[dict] = []


def _time_ms(fn, *args, repeats: int = 5) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best


def run() -> list[str]:
    RECORDS.clear()
    rows = ["workload,n_ops,opara_ms,nimble_ms,ratio,schedule_ms,plan_cache_hit_ms"]
    graphs = {name: fn(1) for name, fn in PAPER_WORKLOADS.items()}
    graphs["kimi-k2 (4L)"] = arch_workload("kimi-k2-1t-a32b")
    graphs["kimi-moe-ragged (4L)"] = moe_ragged_workload()
    graphs["hymba (4L)"] = arch_workload("hymba-1.5b")
    for name, g in graphs.items():
        t_opara = _time_ms(allocate_streams, g)
        t_nimble = _time_ms(allocate_streams_nimble, g)
        t_sched = _time_ms(lambda: schedule(g, "opara", "opara"), repeats=3)
        sess = Session()                  # fresh caches per workload row
        sess.plan(g)                      # miss: populates the plan cache
        t_hit = _time_ms(lambda: sess.plan(g), repeats=3)
        rows.append(f"{name},{len(g)},{t_opara:.3f},{t_nimble:.3f},"
                    f"{t_nimble / max(t_opara, 1e-9):.1f},"
                    f"{t_sched:.3f},{t_hit:.4f}")
        RECORDS.append({
            "workload": name, "n_ops": len(g),
            "opara_alloc_ms": round(t_opara, 4),
            "nimble_alloc_ms": round(t_nimble, 4),
            "schedule_ms": round(t_sched, 4),
            "plan_cache_hit_ms": round(t_hit, 5),
        })
    rows.extend(_refine_trajectory(graphs))
    rows.extend(_measured_calibration())
    return rows


def _refine_trajectory(graphs: dict) -> list[str]:
    """Static autotune sweep vs sweep+iterative refinement: the predicted
    makespan each returns (deterministic cost-model values) plus the
    refinement pass's wall time and accepted-move count."""
    from .bench_inference import BENCH_SIM
    rows = ["", "autotune refinement,workload,est_static_us,est_refined_us,"
                "refine_ms,refine_iters,refined"]
    for name in ("inception-v3", "kimi-moe-ragged (4L)"):
        g = graphs[name]
        p_static = autotune(g, cfg=BENCH_SIM)
        p_ref = autotune(g, cfg=BENCH_SIM, refine=True)
        rows.append(f"refine,{name},{p_static.est_makespan_us:.3f},"
                    f"{p_ref.est_makespan_us:.3f},{p_ref.refine_ms:.2f},"
                    f"{p_ref.refine_iters},{p_ref.refined}")
        RECORDS.append({
            "workload": f"{name} (autotune+refine)", "n_ops": len(g),
            "est_static_us": round(p_static.est_makespan_us, 3),
            "est_refined_us": round(p_ref.est_makespan_us, 3),
            "refine_ms": round(p_ref.refine_ms, 3),
            "refine_iters": p_ref.refine_iters,
            "refined": bool(p_ref.refined),
        })
    return rows


def _measured_calibration() -> list[str]:
    """Cold vs warm measured-mode scheduling on the payload graph.

    The session's calibration disk tier is pointed at a throwaway directory
    (``SessionConfig.calib_dir``): a table persisted by an earlier local run
    would turn the cold measurement into a disk hit and skew the committed
    trajectory."""
    import tempfile
    gp = build_payload_graph()
    inputs = {n.op_id: jnp.ones(n.out_shape, jnp.float32)
              for n in gp if n.fn is None}
    with tempfile.TemporaryDirectory(prefix="repro-calib-") as tmp:
        return _measured_calibration_inner(Session(calib_dir=tmp), gp, inputs)


def _measured_calibration_inner(sess, gp, inputs) -> list[str]:
    t0 = time.perf_counter()
    sess.plan(gp, measured_inputs=inputs)       # times once + schedules
    t_cold = (time.perf_counter() - t0) * 1e3
    t_warm = _time_ms(lambda: sess.plan(gp, measured_inputs=inputs),
                      repeats=3)
    stats = sess.cache_stats()
    RECORDS.append({
        "workload": "payload-graph (measured)", "n_ops": len(gp),
        "measured_cold_ms": round(t_cold, 3),
        "measured_warm_ms": round(t_warm, 4),
        "calib_hits": stats["calib_hits"],
        "calib_misses": stats["calib_misses"],
    })
    return [
        "",
        "measured-mode calibration (payload graph),cold_ms,warm_ms,hits,misses",
        f"calibration,{t_cold:.3f},{t_warm:.4f},"
        f"{stats['calib_hits']},{stats['calib_misses']}",
    ]


if __name__ == "__main__":
    print("\n".join(run()))
