"""Serving-tier overload benchmark: admission (EDF + shedding) vs FIFO.

A deterministic synthetic trace arrives FASTER than the slot pool can
serve (arrival rate > capacity) with mixed tenants, priorities and tick
deadlines.  The same trace is replayed against

  * the legacy **FIFO** engine (unbounded queue, no deadlines enforced,
    no shedding/preemption — requests just queue and finish late), and
  * the **admission** tier (bounded EDF queue, doomed-request expiry,
    priority preemption),

reporting **goodput** (tokens of requests that finished *inside* their
deadline, per engine tick — the tick clock makes this deterministic and
machine-independent), **shed rate** and **deadline-miss rate**.  Under
overload FIFO burns slot time producing tokens that are guaranteed late;
the admission tier spends the same capacity on requests that can still
meet their deadline, so its goodput is strictly higher on this trace.

    PYTHONPATH=src python -m benchmarks.bench_serving
"""
from __future__ import annotations

import time

#: latest per-config metric rows (for programmatic consumers / tests)
RECORDS: list[dict] = []


def build_trace(n: int = 18, seed: int = 7) -> list[dict]:
    """Deterministic overload trace: ~1 arrival/tick against ~0.4/tick of
    slot capacity.  Two tenants: ``prod`` (priority 2, tight deadlines)
    and ``batch`` (priority 0, loose or no deadlines)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    trace = []
    arrival = 0
    for rid in range(n):
        arrival += int(rng.integers(0, 2))          # 0-1 ticks apart: burst
        prod = rid % 3 != 0                          # 2/3 prod, 1/3 batch
        trace.append({
            "arrival": arrival,
            "rid": rid,
            "prompt": rng.integers(1, 200, size=int(rng.integers(3, 7))).tolist(),
            "max_tokens": 5,
            "tenant": "prod" if prod else "batch",
            "priority": 2 if prod else 0,
            "ttl": int(rng.integers(8, 14)) if prod else None,
        })
    return trace


def build_longctx_trace(n: int = 8, seed: int = 13) -> list[dict]:
    """Long-context variant: prompts of 48/96 tokens (two shapes only, so
    prefill compiles twice, not per request) against max_len=256 engines."""
    import numpy as np

    rng = np.random.default_rng(seed)
    trace = []
    arrival = 0
    for rid in range(n):
        arrival += int(rng.integers(0, 3))
        plen = 96 if rid % 2 else 48
        trace.append({
            "arrival": arrival,
            "rid": rid,
            "prompt": rng.integers(1, 200, size=plen).tolist(),
            "max_tokens": 6,
            "tenant": "prod" if rid % 3 else "batch",
            "priority": 2 if rid % 3 else 0,
            "ttl": None,
        })
    return trace


def _drive(engine, trace, max_ticks: int = 400):
    """Replay the trace against the engine's tick clock: requests are
    submitted when their arrival tick is reached, the engine steps once
    per tick, and the run ends when all work is terminal."""
    from repro.serving import Request

    submitted = []
    idx = 0
    while idx < len(trace) or engine._work_pending():
        if engine.tick >= max_ticks:
            break
        while idx < len(trace) and trace[idx]["arrival"] <= engine.tick:
            spec = trace[idx]
            req = Request(rid=spec["rid"], prompt=list(spec["prompt"]),
                          max_tokens=spec["max_tokens"],
                          tenant=spec["tenant"], priority=spec["priority"],
                          ttl=spec["ttl"])
            engine.submit(req)
            submitted.append(req)
            idx += 1
        engine.step()
    engine.drain(max_ticks=max_ticks)
    return submitted


def measure(engine, trace, label: str, max_ticks: int = 400) -> dict:
    from repro.serving import RequestState, TERMINAL_STATES

    t0 = time.perf_counter()
    submitted = _drive(engine, trace, max_ticks=max_ticks)
    wall = time.perf_counter() - t0
    assert all(r.state in TERMINAL_STATES for r in submitted), \
        f"{label}: non-terminal request after drain"
    with_deadline = [r for r in submitted if r.deadline is not None]
    in_deadline = [r for r in submitted if r.state is RequestState.DONE
                   and (r.deadline is None or r.finish_tick <= r.deadline)]
    good_tokens = sum(len(r.output) for r in in_deadline)
    total_tokens = sum(len(r.output) for r in submitted)
    missed = [r for r in with_deadline
              if not (r.state is RequestState.DONE
                      and r.finish_tick <= r.deadline)]
    n = len(submitted)
    ticks = max(1, engine.tick)
    row = {
        "label": label,
        "requests": n,
        "done": sum(1 for r in submitted if r.state is RequestState.DONE),
        "shed": sum(1 for r in submitted if r.state is RequestState.SHED),
        "expired": sum(1 for r in submitted
                       if r.state is RequestState.EXPIRED),
        "ticks": engine.tick,
        "good_tokens": good_tokens,
        "total_tokens": total_tokens,
        "goodput_tok_per_tick": round(good_tokens / ticks, 4),
        "shed_rate": round(sum(1 for r in submitted
                               if r.state is RequestState.SHED) / n, 4),
        "deadline_miss_rate": round(len(missed) / max(1, len(with_deadline)),
                                    4),
        "preemptions": engine.fault_stats["preemptions"],
        "wall_s": round(wall, 3),
        "good_tok_per_s": round(good_tokens / wall, 2) if wall > 0 else 0.0,
    }
    return row


def run():
    """Benchmark section: FIFO baseline vs admission tier on one trace."""
    import jax

    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import AdmissionConfig, InferenceEngine

    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    trace = build_trace()
    RECORDS.clear()

    fifo_cfg = AdmissionConfig(policy="fifo", preemption=False,
                               expire_queued=False, expire_running=False)
    edf_cfg = AdmissionConfig(max_queue=6, tenant_quota=5)
    configs = [("fifo-baseline", fifo_cfg), ("edf-admission", edf_cfg)]
    rows = {}
    for label, adm in configs:
        engine = InferenceEngine(model, params, max_slots=2, max_len=64,
                                 admission=adm)
        row = measure(engine, trace, label)
        rows[label] = row
        RECORDS.append(row)
        yield (f"{label:<16} done={row['done']:>2} shed={row['shed']:>2} "
               f"expired={row['expired']:>2} ticks={row['ticks']:>4} "
               f"goodput={row['goodput_tok_per_tick']:.3f} tok/tick "
               f"shed_rate={row['shed_rate']:.2f} "
               f"miss_rate={row['deadline_miss_rate']:.2f} "
               f"preempt={row['preemptions']}")
    base = rows["fifo-baseline"]["goodput_tok_per_tick"]
    tuned = rows["edf-admission"]["goodput_tok_per_tick"]
    ratio = tuned / base if base > 0 else float("inf")
    yield (f"admission goodput vs FIFO: {tuned:.3f} vs {base:.3f} tok/tick "
           f"({ratio:.2f}x)")

    # -- long-context: dense slab vs paged KV at equal max_slots ----------
    # The paged pool is deliberately overcommitted (3 requests' worth of
    # pages behind 4 slots): pages are handed out as sequences actually
    # grow, so peak KV memory is strictly below the dense slab, which must
    # reserve max_len for every slot up front.  Exhaustion feeds the
    # admission queue (requeue/shed) instead of failing requests.
    long_trace = build_longctx_trace()
    max_slots, long_len, page_size = 4, 256, 16
    pages_per_req = -(-(long_len + cfg.meta_tokens) // page_size)
    variants = [
        ("dense-longctx", {}),
        ("paged-longctx", dict(paged_kv=True, page_size=page_size,
                               num_pages=1 + 3 * pages_per_req)),
    ]
    kv_bytes = {}
    for label, kw in variants:
        engine = InferenceEngine(
            model, params, max_slots=max_slots, max_len=long_len,
            admission=AdmissionConfig(policy="edf", preemption=True), **kw)
        row = measure(engine, long_trace, label)
        row["kv_cache_mib"] = round(engine.kv_cache_bytes() / 2 ** 20, 3)
        row["page_exhaustions"] = engine.fault_stats["page_exhaustions"]
        kv_bytes[label] = engine.kv_cache_bytes()
        rows[label] = row
        RECORDS.append(row)
        yield (f"{label:<16} done={row['done']:>2} shed={row['shed']:>2} "
               f"ticks={row['ticks']:>4} "
               f"goodput={row['goodput_tok_per_tick']:.3f} tok/tick "
               f"kv={row['kv_cache_mib']:.3f} MiB "
               f"exhaustions={row['page_exhaustions']}")
    saving = 1 - kv_bytes["paged-longctx"] / kv_bytes["dense-longctx"]
    assert kv_bytes["paged-longctx"] < kv_bytes["dense-longctx"], \
        "paged KV must beat the dense slab at equal max_slots"
    yield (f"paged KV memory vs dense slab: "
           f"{kv_bytes['paged-longctx'] / 2 ** 20:.3f} vs "
           f"{kv_bytes['dense-longctx'] / 2 ** 20:.3f} MiB "
           f"({saving:.0%} smaller)")


def main() -> int:
    for row in run():
        print(row)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
