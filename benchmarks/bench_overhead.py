"""§5.3 runtime overhead: one-pass profiling cost + end-to-end schedule
construction time (alloc + order + wave build + capture trace).

Also the acceptance benchmark for the capture-time program compiler: on a
≥2000-op graph (stacked BERT-like layers) it reports schedule()+
compile_plan() wall time cold, and the compiled-plan-cache hit time warm.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ModelProfiler, V5E, compile_plan, schedule
from repro.core import api as opara

from .workloads import bert_like

# structured records picked up by benchmarks/run.py → BENCH JSON
RECORDS: list[dict] = []


def run() -> list[str]:
    RECORDS.clear()
    rows = ["stage,ms"]
    g = bert_like(1)

    t0 = time.perf_counter()
    plan = schedule(g, "opara", "opara")
    rows.append(f"stream_alloc,{plan.alloc_time_ms:.3f}")
    rows.append(f"launch_order,{plan.order_time_ms:.3f}")
    rows.append(f"schedule_total,{(time.perf_counter() - t0) * 1e3:.2f}")

    # measured profiling pass (paper: one inference, ~4.25 ms on GPU)
    from .conftest_shim import build_payload_graph
    gp = build_payload_graph()
    inputs = {n.op_id: jnp.ones(n.out_shape, jnp.float32)
              for n in gp if n.fn is None}
    t0 = time.perf_counter()
    ModelProfiler(V5E).profile_measured(gp, inputs, repeats=1)
    rows.append(f"profiling_pass,{(time.perf_counter() - t0) * 1e3:.2f}")

    t0 = time.perf_counter()
    exe = compile_plan(schedule(gp, "opara", "opara"))
    exe({"x": jnp.ones((8, 64), jnp.float32)})
    t_payload_capture = (time.perf_counter() - t0) * 1e3
    rows.append(f"capture_and_compile,{t_payload_capture:.2f}")
    RECORDS.append({
        "workload": "payload-graph", "n_ops": len(gp),
        # payload-bearing capture: const stacking + kernel routing + XLA
        # compile + first execution (the analytic big-graph row below only
        # times lowering — its nodes carry no payloads)
        "capture_and_compile_ms": round(t_payload_capture, 3),
    })

    # -- ≥2000-op graph: program-compiler overhead + plan-cache hit ----------
    big = bert_like(1, n_layers=180)          # 2165 ops
    opara.clear_caches()
    t0 = time.perf_counter()
    p_big = schedule(big, "opara", "opara")
    t_sched = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    compile_plan(p_big)
    t_lower = (time.perf_counter() - t0) * 1e3
    opara.plan(big)                            # miss (populates the cache)
    t0 = time.perf_counter()
    opara.plan(big)                            # hit
    t_hit = (time.perf_counter() - t0) * 1e3
    rows.append(f"big_graph_n_ops,{len(big)}")
    rows.append(f"big_graph_schedule,{t_sched:.2f}")
    rows.append(f"big_graph_capture_lower,{t_lower:.2f}")
    rows.append(f"big_graph_plan_cache_hit,{t_hit:.3f}")
    RECORDS.append({
        "workload": "bert-180L", "n_ops": len(big),
        "schedule_ms": round(t_sched, 3),
        "capture_lower_ms": round(t_lower, 3),
        "plan_cache_hit_ms": round(t_hit, 4),
    })
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
