"""§5.3 runtime overhead: one-pass profiling cost + end-to-end schedule
construction time (alloc + order + wave build + capture trace).

Also the acceptance benchmark for the capture-time program compiler: on a
≥2000-op graph (stacked BERT-like layers) it reports schedule()+
compile_plan() wall time cold, and the compiled-plan-cache hit time warm.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (ModelProfiler, Session, V5E, autotune, compile_plan,
                        estimate_makespan, schedule, simulate)

from .bench_inference import BENCH_SIM
from .workloads import bert_like

# structured records picked up by benchmarks/run.py → BENCH JSON
RECORDS: list[dict] = []


def run() -> list[str]:
    RECORDS.clear()
    rows = ["stage,ms"]
    g = bert_like(1)

    t0 = time.perf_counter()
    plan = schedule(g, "opara", "opara")
    rows.append(f"stream_alloc,{plan.alloc_time_ms:.3f}")
    rows.append(f"launch_order,{plan.order_time_ms:.3f}")
    rows.append(f"schedule_total,{(time.perf_counter() - t0) * 1e3:.2f}")

    # measured profiling pass (paper: one inference, ~4.25 ms on GPU)
    from .conftest_shim import build_payload_graph
    gp = build_payload_graph()
    inputs = {n.op_id: jnp.ones(n.out_shape, jnp.float32)
              for n in gp if n.fn is None}
    t0 = time.perf_counter()
    ModelProfiler(V5E).profile_measured(gp, inputs, repeats=1)
    rows.append(f"profiling_pass,{(time.perf_counter() - t0) * 1e3:.2f}")

    t0 = time.perf_counter()
    exe = compile_plan(schedule(gp, "opara", "opara"))
    exe({"x": jnp.ones((8, 64), jnp.float32)})
    t_payload_capture = (time.perf_counter() - t0) * 1e3
    rows.append(f"capture_and_compile,{t_payload_capture:.2f}")
    RECORDS.append({
        "workload": "payload-graph", "n_ops": len(gp),
        # payload-bearing capture: const stacking + kernel routing + XLA
        # compile + first execution (the analytic big-graph row below only
        # times lowering — its nodes carry no payloads)
        "capture_and_compile_ms": round(t_payload_capture, 3),
    })

    # -- ≥2000-op graph: program-compiler overhead + plan-cache hit ----------
    big = bert_like(1, n_layers=180)          # ~3.8k ops (21 ops/layer)
    sess = Session()
    p_big = schedule(big, "opara", "opara")
    # best-of-3: these rows feed the regression gate, and a single-shot
    # measurement swallows GC/scheduler pauses whole
    t_sched = min(_timed(lambda: schedule(big, "opara", "opara"))
                  for _ in range(3))
    t_lower = min(_timed(lambda: compile_plan(p_big)) for _ in range(3))
    sess.plan(big)                             # miss (populates the cache)
    t0 = time.perf_counter()
    sess.plan(big)                             # hit
    t_hit = (time.perf_counter() - t0) * 1e3
    rows.append(f"big_graph_n_ops,{len(big)}")
    rows.append(f"big_graph_schedule,{t_sched:.2f}")
    rows.append(f"big_graph_capture_lower,{t_lower:.2f}")
    rows.append(f"big_graph_plan_cache_hit,{t_hit:.3f}")

    # -- autotune acceptance numbers: the cost model must be ≥10× cheaper
    # than the event-driven simulator, and the full {alloc}×{order}×{repack}
    # search must stay within ~2× of the single-policy cold path (the warm
    # path is a plan-cache hit either way) -----------------------------------
    t0 = time.perf_counter()
    simulate(big, p_big.stream_plan, p_big.order, p_big.profiles, BENCH_SIM)
    t_sim = (time.perf_counter() - t0) * 1e3
    t_est = min(_timed(lambda: estimate_makespan(
        big, p_big.stream_plan, p_big.order, p_big.profiles, BENCH_SIM))
        for _ in range(3))
    t_tune = min(_timed(lambda: autotune(big, cfg=BENCH_SIM))
                 for _ in range(3))
    # the IOS-style iterative refinement pass on top of the static sweep:
    # cold wall time and the (deterministic) predicted-makespan trajectory
    # static sweep → refined plan
    t_refine, p_refined = float("inf"), None
    for _ in range(3):
        t0 = time.perf_counter()
        cand = autotune(big, cfg=BENCH_SIM, refine=True)
        t_ms = (time.perf_counter() - t0) * 1e3
        if t_ms < t_refine:
            t_refine, p_refined = t_ms, cand
    p_static = autotune(big, cfg=BENCH_SIM)
    tune_sess = Session(autotune=True, sim_cfg=BENCH_SIM)
    tune_sess.plan(big)                        # miss: tunes once
    t_tune_hit = min(_timed(lambda: tune_sess.plan(big)) for _ in range(3))
    rows.append(f"big_graph_simulate,{t_sim:.2f}")
    rows.append(f"big_graph_estimate,{t_est:.3f}")
    rows.append(f"big_graph_estimate_speedup,{t_sim / max(t_est, 1e-9):.1f}")
    rows.append(f"big_graph_autotune_cold,{t_tune:.2f}")
    rows.append(f"big_graph_autotune_refine_cold,{t_refine:.2f}")
    rows.append(f"big_graph_est_static,{p_static.est_makespan_us:.3f}")
    rows.append(f"big_graph_est_refined,{p_refined.est_makespan_us:.3f}")
    rows.append(f"big_graph_autotune_plan_hit,{t_tune_hit:.4f}")
    RECORDS.append({
        "workload": "bert-180L", "n_ops": len(big),
        "schedule_ms": round(t_sched, 3),
        "capture_lower_ms": round(t_lower, 3),
        "plan_cache_hit_ms": round(t_hit, 4),
        "simulate_ms": round(t_sim, 3),
        "estimate_ms": round(t_est, 4),
        "estimate_speedup": round(t_sim / max(t_est, 1e-9), 1),
        "autotune_cold_ms": round(t_tune, 3),
        "autotune_vs_schedule": round(t_tune / max(t_sched, 1e-9), 2),
        "autotune_plan_hit_ms": round(t_tune_hit, 5),
        # refinement acceptance: est_static/est_refined are deterministic
        # cost-model values (gate-stable); the wall times are best-of-3
        "autotune_refine_cold_ms": round(t_refine, 3),
        "refine_vs_schedule": round(t_refine / max(t_sched, 1e-9), 2),
        "refine_ms": round(p_refined.refine_ms, 3),
        "refine_iters": p_refined.refine_iters,
        "est_static_us": round(p_static.est_makespan_us, 3),
        "est_refined_us": round(p_refined.est_makespan_us, 3),
        "repacked": bool(p_refined.repacked),
        "refined": bool(p_refined.refined),
    })
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e3


if __name__ == "__main__":
    print("\n".join(run()))
