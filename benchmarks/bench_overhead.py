"""§5.3 runtime overhead: one-pass profiling cost + end-to-end schedule
construction time (alloc + order + wave build + capture trace)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ModelProfiler, V5E, compile_plan, schedule

from .workloads import bert_like


def run() -> list[str]:
    rows = ["stage,ms"]
    g = bert_like(1)

    t0 = time.perf_counter()
    plan = schedule(g, "opara", "opara")
    rows.append(f"stream_alloc,{plan.alloc_time_ms:.3f}")
    rows.append(f"launch_order,{plan.order_time_ms:.3f}")
    rows.append(f"schedule_total,{(time.perf_counter() - t0) * 1e3:.2f}")

    # measured profiling pass (paper: one inference, ~4.25 ms on GPU)
    from .conftest_shim import build_payload_graph
    gp = build_payload_graph()
    inputs = {n.op_id: jnp.ones(n.out_shape, jnp.float32)
              for n in gp if n.fn is None}
    t0 = time.perf_counter()
    ModelProfiler(V5E).profile_measured(gp, inputs, repeats=1)
    rows.append(f"profiling_pass,{(time.perf_counter() - t0) * 1e3:.2f}")

    t0 = time.perf_counter()
    exe = compile_plan(schedule(gp, "opara", "opara"))
    exe({"x": jnp.ones((8, 64), jnp.float32)})
    rows.append(f"capture_and_compile,{(time.perf_counter() - t0) * 1e3:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
