"""Fig. 8: inference throughput vs batch size (1..32), Inception-v3 —
Opara's gain over the sequential CUDA Graph shrinks as ops fatten."""
from __future__ import annotations

from repro.core import SimConfig, schedule, sequential_makespan, simulate_plan

from .bench_inference import BENCH_HW, BENCH_SIM
from .workloads import inception_v3_like


def run() -> list[str]:
    rows = ["batch,cuda_graph_ips,opara_ips,speedup"]
    for batch in (1, 2, 4, 8, 16, 32):
        g = inception_v3_like(batch)
        plan = schedule(g, "opara", "opara", BENCH_HW)
        seq_us = sequential_makespan(g, plan.profiles, BENCH_SIM)
        op_us = simulate_plan(plan, BENCH_SIM).makespan_us
        rows.append(f"{batch},{batch / seq_us * 1e6:.1f},"
                    f"{batch / op_us * 1e6:.1f},{seq_us / op_us:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
