"""Fig. 5a: end-to-end inference speedup of {PyTorch-eager, sequential CUDA
Graph, Nimble, Opara} — calibrated simulator over the paper's four workloads
and our ten assigned architectures."""
from __future__ import annotations

import dataclasses
import time

from repro.configs import list_archs
from repro.core import (Session, SimConfig, compare_policies, compile_plan,
                        schedule)
from repro.core.profiler import HardwareSpec

from .workloads import PAPER_WORKLOADS, arch_workload, moe_ragged_workload

# structured records picked up by benchmarks/run.py → BENCH_inference.json
RECORDS: list[dict] = []

# Calibration: (a) small kernels never reach roofline — the 2 µs floor
# models kernel setup/DMA latency (the under-utilization the paper's Fig. 1
# measures); (b) resource_cap models the finite SM/VMEM pool — concurrent
# ops whose working sets exceed it BLOCK (paper §2.3 "GPU blocking"), which
# is what makes launch order matter and large-batch gains shrink (Fig. 8).
BENCH_HW = HardwareSpec(min_kernel_us=2.0)
# sync_us is small: event waits are captured INSIDE the graph (replay cost),
# not host round-trips.  resource_cap = one device's occupancy budget.
# head_of_line: non-preemptive dispatch is THE mechanism that makes the
# operator launch order matter (paper Fig. 2 / §2.3) — on, so order and
# packing policies actually differentiate in the trajectory JSONs.
BENCH_SIM = SimConfig(resource_cap=128e6, sync_us=0.5, launch_us=8.0,
                      interference_penalty=0.13, head_of_line=True)
# the RTX-2080-class device of the paper's Fig. 2: ~40% of the occupancy
# budget and non-preemptive head-of-line dispatch — launch order matters
# most when the pool is tight and a blocked kernel stalls later launches.
SMALL_GPU_SIM = SimConfig(resource_cap=52e6, sync_us=0.5, launch_us=8.0,
                          interference_penalty=0.13, head_of_line=True)


def _time_best(fn, repeats: int = 3):
    """(best_ms, last_result) over ``repeats`` calls — single-shot wall-clock
    numbers swallow GC/scheduler pauses whole and flap the regression gate."""
    best, result = float("inf"), None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best, result


def run(batch: int = 1) -> list[str]:
    RECORDS.clear()
    rows = ["workload,policy,makespan_us,speedup_vs_eager,speedup_vs_cuda_graph"]
    graphs = {name: fn(batch) for name, fn in PAPER_WORKLOADS.items()}
    for arch in list_archs():
        try:
            graphs[arch] = arch_workload(arch, batch=batch)
        except Exception:
            continue
    # the grouped ragged-M fan-out (routed MoE) — the paper's hardest
    # uneven-branch case, gated alongside the uniform kimi topology
    graphs["kimi-moe-ragged"] = moe_ragged_workload(batch=batch)
    # one autotuning session for the whole sweep — each workload's search
    # (static sweep + iterative refinement) runs once and lands in the
    # session's plan cache (the serving pattern)
    tune_sess = Session(hw=BENCH_HW, sim_cfg=BENCH_SIM, autotune=True,
                        refine=True)
    for name, g in graphs.items():
        tuned = tune_sess.plan(g)
        tuned_meta: dict[str, str] = {}
        res = compare_policies(g, hw=BENCH_HW, cfg=BENCH_SIM,
                               opara_plan=tuned, tuned_meta=tuned_meta)
        base = res["cuda_graph_sequential"]["makespan_us"]
        t_sched, plan = _time_best(
            lambda: schedule(g, "opara", "opara", hw=BENCH_HW,
                             sim_cfg=BENCH_SIM))
        t_capture, _ = _time_best(lambda: compile_plan(plan))
        # why the opara makespan moved: the tuned plan's packing efficacy
        # (per-wave resource utilization, same-class overlap) next to the
        # untuned single-policy plan's
        eff_keys = ("mean_wave_resource_util", "max_wave_resource_util",
                    "same_class_overlap_frac", "n_waves")
        tuned_stats = tuned.stats()
        untuned_stats = plan.stats()
        rec = {"workload": name, "n_ops": len(g),
               "schedule_ms": round(t_sched, 3),
               "capture_ms": round(t_capture, 3),
               "autotune": dict(
                   {k: round(tuned_stats[k], 4) for k in eff_keys},
                   autotune_ms=round(tuned.autotune_ms, 3),
                   n_candidates=tuned.n_candidates,
                   alloc=tuned_meta.get("tuned_alloc", tuned.alloc_policy),
                   order=tuned_meta.get("tuned_order", tuned.order_policy),
                   repacked=bool(tuned.repacked),
                   refined=bool(tuned.refined),
                   refine_ms=round(tuned.refine_ms, 3),
                   refine_iters=tuned.refine_iters,
                   refine_delta_us=round(tuned.refine_delta_us, 3),
                   est_makespan_us=round(tuned.est_makespan_us or 0.0, 2)),
               "untuned": {k: round(untuned_stats[k], 4) for k in eff_keys},
               "policies": {}}
        for policy, r in res.items():
            rows.append(
                f"{name},{policy},{r['makespan_us']:.1f},"
                f"{r.get('speedup_vs_eager', 0):.2f},"
                f"{base / r['makespan_us']:.2f}")
            rec["policies"][policy] = {
                "makespan_us": round(r["makespan_us"], 2),
                "speedup_vs_eager": round(r.get("speedup_vs_eager", 0), 3),
                "speedup_vs_cuda_graph": round(base / r["makespan_us"], 3),
            }
        RECORDS.append(rec)
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
