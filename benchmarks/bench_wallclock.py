"""Measured wall-clock (the one REAL timing in the container): eager per-op
dispatch vs jitted-sequential vs jitted-Opara-fused execution of a branchy
payload graph.  The eager→jit gap reproduces the paper's PyTorch→CUDA-Graph
speedup mechanism (launch-overhead elimination); jit-sequential→Opara shows
the horizontal wave fusion win."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import compile_plan, run_sequential_uncompiled, schedule

from .conftest_shim import build_payload_graph


def _time_us(fn, *args, repeats: int = 30) -> float:
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def run() -> list[str]:
    rows = ["variant,us_per_call,speedup_vs_eager"]
    g = build_payload_graph(n_blocks=6, width=6, d=128, tokens=16)
    x = jnp.ones((16, 128), jnp.float32)

    t_eager = _time_us(lambda: run_sequential_uncompiled(g, {"x": x}), repeats=10)

    seq_plan = schedule(g, "sequential", "topo")
    seq_exe = compile_plan(seq_plan)
    t_seq = _time_us(lambda: seq_exe({"x": x}))

    opara_plan = schedule(g, "opara", "opara")
    opara_exe = compile_plan(opara_plan)
    t_opara = _time_us(lambda: opara_exe({"x": x}))

    rows.append(f"eager_per_op,{t_eager:.1f},1.00")
    rows.append(f"jit_sequential,{t_seq:.1f},{t_eager / t_seq:.2f}")
    rows.append(f"jit_opara_fused,{t_opara:.1f},{t_eager / t_opara:.2f}")
    rows.append(f"opara_vs_jit_sequential,,{t_seq / t_opara:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
