"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json-dir DIR]

Sections:
    table1_scheduler     Alg. 1 vs Nimble scheduling cost        (Table 1)
    fig5a_inference      4-policy inference speedups             (Fig. 5a)
    fig5b_utilization    utilization proxy + stream counts       (Fig. 5b/1)
    fig2_launch_order    depth-first vs Opara order              (Fig. 2)
    fig8_throughput      throughput vs batch size                (Fig. 8)
    sec5_3_overhead      profiling + scheduling overhead         (§5.3)
    wallclock            real CPU wall-clock eager/jit/fused     (Fig. 5a mech.)
    serving_overload     admission tier vs FIFO under overload   (serving tier)

Structured output: sections that track the perf trajectory additionally
write machine-diffable JSON (``BENCH_scheduler.json`` — per-workload
scheduling cost + plan-cache hit time; ``BENCH_inference.json`` — makespan
per policy + schedule/capture wall time) so regressions between PRs show
up as a JSON diff.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _read_json(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _write_json(path: str, payload: dict) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow wallclock section")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_*.json trajectory files")
    args = ap.parse_args(argv)

    from . import (bench_inference, bench_launch_order, bench_overhead,
                   bench_scheduler, bench_throughput, bench_utilization,
                   bench_wallclock)

    sections = [
        ("table1_scheduler", bench_scheduler.run),
        ("fig5a_inference", bench_inference.run),
        ("fig5b_utilization", bench_utilization.run),
        ("fig2_launch_order", bench_launch_order.run),
        ("fig8_throughput", bench_throughput.run),
        ("sec5_3_overhead", bench_overhead.run),
    ]
    if not args.quick:
        sections.append(("wallclock", bench_wallclock.run))
        # real model inference on an overload trace — skipped in --quick so
        # the CI bench gate's wall-clock envelope is untouched
        from . import bench_serving
        sections.append(("serving_overload", bench_serving.run))

    from repro.core import reset_default_session

    failures = 0
    ran: set[str] = set()
    for name, fn in sections:
        if args.only and args.only != name:
            continue
        # sections that go through the default session start cold — one
        # section's warm plan cache must not flatter another's timings
        reset_default_session()
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.perf_counter()
        try:
            for row in fn():
                print(row)
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s")
            ran.add(name)
        except Exception as e:                      # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"# {name} FAILED: {e}")
            failures += 1

    # perf-trajectory JSON (diffable across PRs).  Partial runs (--only)
    # merge into an existing file instead of clobbering the other
    # sections' records with empty lists.
    if "table1_scheduler" in ran or "sec5_3_overhead" in ran:
        path = os.path.join(args.json_dir, "BENCH_scheduler.json")
        payload = _read_json(path)
        if "table1_scheduler" in ran:
            payload["workloads"] = list(bench_scheduler.RECORDS)
        if "sec5_3_overhead" in ran:
            payload["overhead"] = list(bench_overhead.RECORDS)
        _write_json(path, payload)
    if "fig5a_inference" in ran:
        _write_json(os.path.join(args.json_dir, "BENCH_inference.json"),
                    {"workloads": bench_inference.RECORDS})
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
