"""Benchmark harness — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Sections:
    table1_scheduler     Alg. 1 vs Nimble scheduling cost        (Table 1)
    fig5a_inference      4-policy inference speedups             (Fig. 5a)
    fig5b_utilization    utilization proxy + stream counts       (Fig. 5b/1)
    fig2_launch_order    depth-first vs Opara order              (Fig. 2)
    fig8_throughput      throughput vs batch size                (Fig. 8)
    sec5_3_overhead      profiling + scheduling overhead         (§5.3)
    wallclock            real CPU wall-clock eager/jit/fused     (Fig. 5a mech.)
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow wallclock section")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from . import (bench_inference, bench_launch_order, bench_overhead,
                   bench_scheduler, bench_throughput, bench_utilization,
                   bench_wallclock)

    sections = [
        ("table1_scheduler", bench_scheduler.run),
        ("fig5a_inference", bench_inference.run),
        ("fig5b_utilization", bench_utilization.run),
        ("fig2_launch_order", bench_launch_order.run),
        ("fig8_throughput", bench_throughput.run),
        ("sec5_3_overhead", bench_overhead.run),
    ]
    if not args.quick:
        sections.append(("wallclock", bench_wallclock.run))

    failures = 0
    for name, fn in sections:
        if args.only and args.only != name:
            continue
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        t0 = time.perf_counter()
        try:
            for row in fn():
                print(row)
            print(f"# {name} done in {time.perf_counter() - t0:.1f}s")
        except Exception as e:                      # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"# {name} FAILED: {e}")
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
