"""Paper workload DAGs with realistic inference-scale operator costs.

The paper evaluates GoogLeNet [14], Inception-v3 [5], BERT [15] and T5 [17]
at batch sizes 1–32.  We rebuild their operator topologies (branch structure
and rough channel/width geometry from the papers) with analytic costs, plus
our ten assigned architectures via the opgraph exporter — all consumed by
the simulator-based benchmarks (Figs. 2/5/8, Table 1).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.graph import OpCost, OpGraph, OpKind
from repro.core.profiler import elementwise_cost, gather_cost, gemm_cost, norm_cost

# the streamed-weight cost vocabulary is shared with the config-arch exporter
# (models/opgraph_export) — one definition, identical pricing everywhere
from repro.models.export_costs import act_gemm_cost, stream_cost, streamed_ff

_streamed_ff = streamed_ff


def conv_cost(h: int, w: int, cin: int, cout: int, k: int, batch: int = 1):
    """im2col-GEMM view of a conv: M=h·w·b, K=cin·k², N=cout."""
    return gemm_cost(h * w * batch, cin * k * k, cout)


def _branch(g, name, inp, specs, h, w, batch):
    """A chain of convs (one inception tower). specs: [(cin,cout,k), ...]."""
    cur = inp
    for i, (cin, cout, k) in enumerate(specs):
        cur = g.add(f"{name}_conv{i}", OpKind.CONV, [cur],
                    cost=conv_cost(h, w, cin, cout, k, batch),
                    fuse_sig=("conv", h, w, cin, cout, k))
        cur = g.add(f"{name}_relu{i}", OpKind.ELEMENTWISE, [cur],
                    cost=elementwise_cost(h * w * cout * batch))
    return cur


def googlenet_like(batch: int = 1) -> OpGraph:
    """9 inception blocks, 4 towers each (1×1 / 3×3 / 5×5 / pool-proj)."""
    g = OpGraph("googlenet")
    x = g.add("image", OpKind.INPUT)
    stem = g.add("stem_conv", OpKind.CONV, [x],
                 cost=conv_cost(112, 112, 3, 64, 7, batch))
    cur = g.add("stem_pool", OpKind.REDUCE, [stem],
                cost=elementwise_cost(56 * 56 * 64 * batch))
    dims = [(28, 192, 256), (28, 256, 480), (14, 480, 512), (14, 512, 512),
            (14, 512, 512), (14, 512, 528), (14, 528, 832), (7, 832, 832),
            (7, 832, 1024)]
    for b_i, (hw, cin, cout) in enumerate(dims):
        c4 = cout // 4
        t1 = _branch(g, f"i{b_i}_1x1", cur, [(cin, c4, 1)], hw, hw, batch)
        t2 = _branch(g, f"i{b_i}_3x3", cur, [(cin, c4, 1), (c4, c4, 3)],
                     hw, hw, batch)
        t3 = _branch(g, f"i{b_i}_5x5", cur, [(cin, c4 // 2, 1),
                                             (c4 // 2, c4, 5)], hw, hw, batch)
        pool = g.add(f"i{b_i}_pool", OpKind.REDUCE, [cur],
                     cost=elementwise_cost(hw * hw * cin * batch, n_in=1))
        t4 = _branch(g, f"i{b_i}_poolproj", pool, [(cin, cout - 3 * c4, 1)],
                     hw, hw, batch)
        cur = g.add(f"i{b_i}_concat", OpKind.ELEMENTWISE, [t1, t2, t3, t4],
                    cost=elementwise_cost(hw * hw * cout * batch, n_in=4))
    g.add("fc", OpKind.GEMM, [cur], cost=gemm_cost(batch, 1024, 1000))
    g.validate()
    return g


def inception_v3_like(batch: int = 1) -> OpGraph:
    """11 blocks with deeper factorized towers (7×1/1×7 chains)."""
    g = OpGraph("inception_v3")
    x = g.add("image", OpKind.INPUT)
    cur = g.add("stem", OpKind.CONV, [x], cost=conv_cost(149, 149, 3, 32, 3, batch))
    dims = [(35, 192, 256)] * 3 + [(17, 768, 768)] * 5 + [(8, 1280, 2048)] * 3
    for b_i, (hw, cin, cout) in enumerate(dims):
        c4 = cout // 4
        towers = [
            _branch(g, f"b{b_i}_t1", cur, [(cin, c4, 1)], hw, hw, batch),
            _branch(g, f"b{b_i}_t2", cur,
                    [(cin, c4, 1), (c4, c4, 3)], hw, hw, batch),
            _branch(g, f"b{b_i}_t3", cur,
                    [(cin, c4 // 2, 1), (c4 // 2, c4, 3), (c4, c4, 3)],
                    hw, hw, batch),
        ]
        pool = g.add(f"b{b_i}_pool", OpKind.REDUCE, [cur],
                     cost=elementwise_cost(hw * hw * cin * batch))
        towers.append(_branch(g, f"b{b_i}_t4", pool, [(cin, cout - 3 * c4, 1)],
                              hw, hw, batch))
        cur = g.add(f"b{b_i}_concat", OpKind.ELEMENTWISE, towers,
                    cost=elementwise_cost(hw * hw * cout * batch, n_in=4))
    g.add("fc", OpKind.GEMM, [cur], cost=gemm_cost(batch, 2048, 1000))
    g.validate()
    return g


def bert_like(batch: int = 1, seq: int = 32, n_layers: int = 12) -> OpGraph:
    """BERT-base at traced-kernel granularity (the graph the paper actually
    schedules: torch.fx sees the score/context bmms, the materializing
    transposes around them, and the mask+softmax chain — not one opaque
    attention node).  Off-critical-path work per layer: the K/V projection
    branches with their layout copies, and the FF weight-stream DMAs
    (:func:`_streamed_ff`) — the small memory-intensive operators the
    paper's Figs. 1/3/7 overlap with compute.

    ``n_layers`` scales depth (overhead benchmarks stack layers to build
    multi-thousand-op graphs — 21 ops per encoder layer)."""
    g = OpGraph("bert")
    d, dff, heads = 768, 3072, 12
    dh = d // heads
    ids = g.add("ids", OpKind.INPUT)
    tok = g.add("tok_embed", OpKind.GATHER, [ids], cost=gather_cost(batch * seq, d))
    pos = g.add("pos_embed", OpKind.GATHER, [ids], cost=gather_cost(batch * seq, d))
    seg = g.add("seg_embed", OpKind.GATHER, [ids], cost=gather_cost(batch * seq, d))
    emb = g.add("embed_sum", OpKind.ELEMENTWISE, [tok, pos, seg],
                cost=elementwise_cost(batch * seq * d, n_in=3))
    cur = g.add("embed_ln", OpKind.NORM, [emb], cost=norm_cost(batch * seq * d))
    # extended attention mask: the ones/to/mul chain (paper Fig. 7 fodder),
    # built once and consumed by every layer's mask add
    mask = g.add("mask_cast", OpKind.ELEMENTWISE, [ids],
                 cost=elementwise_cost(batch * seq))
    extmask = g.add("ext_mask", OpKind.ELEMENTWISE, [mask],
                    cost=elementwise_cost(batch * seq))
    # materializing-transpose cost, built fresh per node: OpCost is mutable
    # (apply_profile writes measured_us in place), so nodes must never share
    # an instance
    copy = lambda: elementwise_cost(batch * seq * d)
    for l in range(n_layers):
        n1 = g.add(f"L{l}_ln1", OpKind.NORM, [cur], cost=norm_cost(batch * seq * d))
        q, k, v = (g.add(f"L{l}_{n}", OpKind.GEMM, [n1],
                         cost=gemm_cost(batch * seq, d, d),
                         fuse_sig=("sgemm", d, d)) for n in ("q", "k", "v"))
        # transpose_for_scores: [b,s,h*dh] → [b,h,s,dh] copies (the bmms
        # need contiguous batched layout)
        qt = g.add(f"L{l}_qt", OpKind.ELEMENTWISE, [q], cost=copy(),
                   fuse_sig=("tps", seq, d))
        kt = g.add(f"L{l}_kt", OpKind.ELEMENTWISE, [k], cost=copy(),
                   fuse_sig=("tps", seq, d))
        vt = g.add(f"L{l}_vt", OpKind.ELEMENTWISE, [v], cost=copy(),
                   fuse_sig=("tps", seq, d))
        scores = g.add(f"L{l}_scores", OpKind.GEMM, [qt, kt],
                       cost=gemm_cost(batch * heads * seq, dh, seq))
        smask = g.add(f"L{l}_scale_mask", OpKind.ELEMENTWISE, [scores, extmask],
                      cost=elementwise_cost(batch * heads * seq * seq, n_in=2))
        probs = g.add(f"L{l}_softmax", OpKind.REDUCE, [smask],
                      cost=elementwise_cost(batch * heads * seq * seq,
                                            flops_per_elem=5))
        ctx = g.add(f"L{l}_ctx", OpKind.GEMM, [probs, vt],
                    cost=gemm_cost(batch * heads * seq, seq, dh))
        ctxt = g.add(f"L{l}_ctxt", OpKind.ELEMENTWISE, [ctx], cost=copy())
        o = g.add(f"L{l}_o", OpKind.GEMM, [ctxt], cost=gemm_cost(batch * seq, d, d))
        r1 = g.add(f"L{l}_res1", OpKind.ELEMENTWISE, [cur, o],
                   cost=elementwise_cost(batch * seq * d, n_in=2))
        n2 = g.add(f"L{l}_ln2", OpKind.NORM, [r1], cost=norm_cost(batch * seq * d))
        up = _streamed_ff(g, f"L{l}_up", n2, ids, batch * seq, d, dff)
        act = g.add(f"L{l}_gelu", OpKind.ELEMENTWISE, [up],
                    cost=elementwise_cost(batch * seq * dff, flops_per_elem=8))
        down = _streamed_ff(g, f"L{l}_down", act, ids, batch * seq, dff, d)
        cur = g.add(f"L{l}_res2", OpKind.ELEMENTWISE, [r1, down],
                    cost=elementwise_cost(batch * seq * d, n_in=2))
    g.validate()
    return g


def t5_like(batch: int = 1, seq: int = 32, n_layers: int = 12) -> OpGraph:
    """T5-base: 12 encoder + 12 decoder layers; the decoder adds a parallel
    cross-attention KV branch and the Arange/To/Ones-style small memory ops
    the paper highlights as overlap fodder (Fig. 7a).  ``n_layers`` trims
    both stacks (differential tests use shallow variants)."""
    g = OpGraph("t5")
    d, dff = 768, 2048
    ids = g.add("ids", OpKind.INPUT)
    enc = g.add("enc_embed", OpKind.GATHER, [ids], cost=gather_cost(batch * seq, d))
    for l in range(n_layers):
        n1 = g.add(f"e{l}_ln1", OpKind.NORM, [enc], cost=norm_cost(batch * seq * d))
        # relative position bias: tiny memory-bound ops (arange/to/ones)
        bias = g.add(f"e{l}_relbias", OpKind.GATHER, [ids],
                     cost=gather_cost(seq * seq, 12))
        qkv = [g.add(f"e{l}_{n}", OpKind.GEMM, [n1],
                     cost=gemm_cost(batch * seq, d, d),
                     fuse_sig=("sgemm", d, d)) for n in ("q", "k", "v")]
        att = g.add(f"e{l}_attn", OpKind.ATTENTION, qkv + [bias],
                    cost=gemm_cost(batch * 12 * seq, seq, 64))
        o = g.add(f"e{l}_o", OpKind.GEMM, [att], cost=gemm_cost(batch * seq, d, d))
        r1 = g.add(f"e{l}_res", OpKind.ELEMENTWISE, [enc, o],
                   cost=elementwise_cost(batch * seq * d, n_in=2))
        n2 = g.add(f"e{l}_ln2", OpKind.NORM, [r1], cost=norm_cost(batch * seq * d))
        up = _streamed_ff(g, f"e{l}_up", n2, ids, batch * seq, d, dff)
        act = g.add(f"e{l}_relu", OpKind.ELEMENTWISE, [up],
                    cost=elementwise_cost(batch * seq * dff))
        down = _streamed_ff(g, f"e{l}_down", act, ids, batch * seq, dff, d)
        enc = g.add(f"e{l}_res2", OpKind.ELEMENTWISE, [r1, down],
                    cost=elementwise_cost(batch * seq * d, n_in=2))
    dec = g.add("dec_embed", OpKind.GATHER, [ids], cost=gather_cost(batch * seq, d))
    for l in range(n_layers):
        n1 = g.add(f"d{l}_ln1", OpKind.NORM, [dec], cost=norm_cost(batch * seq * d))
        qkv = [g.add(f"d{l}_{n}", OpKind.GEMM, [n1],
                     cost=gemm_cost(batch * seq, d, d),
                     fuse_sig=("sgemm", d, d)) for n in ("q", "k", "v")]
        att = g.add(f"d{l}_self", OpKind.ATTENTION, qkv,
                    cost=gemm_cost(batch * 12 * seq, seq, 64))
        # cross-attention K/V from the encoder — parallel with self-attn QKV
        ck = g.add(f"d{l}_ck", OpKind.GEMM, [enc], cost=gemm_cost(batch * seq, d, d),
                   fuse_sig=("sgemm", d, d))
        cv = g.add(f"d{l}_cv", OpKind.GEMM, [enc], cost=gemm_cost(batch * seq, d, d),
                   fuse_sig=("sgemm", d, d))
        cq = g.add(f"d{l}_cq", OpKind.GEMM, [att], cost=gemm_cost(batch * seq, d, d),
                   fuse_sig=("sgemm", d, d))
        xat = g.add(f"d{l}_cross", OpKind.ATTENTION, [cq, ck, cv],
                    cost=gemm_cost(batch * 12 * seq, seq, 64))
        o = g.add(f"d{l}_o", OpKind.GEMM, [xat], cost=gemm_cost(batch * seq, d, d))
        r1 = g.add(f"d{l}_res", OpKind.ELEMENTWISE, [dec, o],
                   cost=elementwise_cost(batch * seq * d, n_in=2))
        n2 = g.add(f"d{l}_ln2", OpKind.NORM, [r1], cost=norm_cost(batch * seq * d))
        up = _streamed_ff(g, f"d{l}_up", n2, ids, batch * seq, d, dff)
        act = g.add(f"d{l}_relu", OpKind.ELEMENTWISE, [up],
                    cost=elementwise_cost(batch * seq * dff))
        down = _streamed_ff(g, f"d{l}_down", act, ids, batch * seq, dff, d)
        dec = g.add(f"d{l}_res2", OpKind.ELEMENTWISE, [r1, down],
                    cost=elementwise_cost(batch * seq * d, n_in=2))
    g.add("lm_head", OpKind.GEMM, [dec], cost=gemm_cost(batch * seq, d, 32128))
    g.validate()
    return g


PAPER_WORKLOADS = {
    "googlenet": googlenet_like,
    "inception-v3": inception_v3_like,
    "bert": bert_like,
    "t5": t5_like,
}


def _generic_payload(*args):
    """Shared payload for :func:`attach_payloads`: sum the inputs, project by
    the per-node weight, squash.  One module-level function for ALL nodes —
    the capture stacking contract (same ``fuse_sig`` ⇒ same callable, branch
    state in ``meta["consts"]``)."""
    *xs, w = args
    acc = xs[0]
    for x in xs[1:]:
        acc = acc + x
    return jnp.tanh(acc @ w)


def attach_payloads(g: OpGraph, d: int = 32, tokens: int = 4,
                    seed: int = 0) -> OpGraph:
    """Make a cost-only workload DAG executable for differential testing.

    Every non-INPUT node gets the shared :func:`_generic_payload` with a
    per-node ``(d, d)`` weight const and a uniform ``(tokens, d)`` value
    shape, so the compiled executor (wave fusion, stacking, slot env) can be
    checked against naive sequential execution on the *real paper
    topologies*.  The analytic costs — which drive scheduling — are left
    untouched; payload values are deliberately unrelated to them.  ``tanh``
    keeps activations bounded through arbitrarily deep chains.
    """
    rng = np.random.default_rng(seed)
    for node in g:
        if node.kind is OpKind.INPUT:
            node.out_shape = (tokens, d)
            node.out_dtype = jnp.float32
            continue
        w = jnp.asarray(rng.standard_normal((d, d)) * (1.0 / d), jnp.float32)
        node.fn = _generic_payload
        node.meta["consts"] = (w,)
        # exporter-built graphs may carry payload="matmul" markers (branch
        # GEMM routing contract: semantics exactly x @ w).  The generic
        # payload is NOT a plain matmul, so the marker must go — a stale one
        # would route stacked groups to the fused GEMM kernel and silently
        # compute the wrong function.
        node.meta.pop("payload", None)
        node.out_shape = (tokens, d)
        node.out_dtype = jnp.float32
    # fn/consts/out_shape are structural signature inputs — recompute
    g.invalidate_signature()
    return g


def arch_workload(arch: str, batch: int = 1, seq: int = 32, n_layers: int = 4,
                  moe_dispatch: str = "auto"):
    """Assigned-architecture operator graphs in the small-op regime the
    paper targets (batch 1–16, short sequences — BERT in the paper runs
    seq=32; LLM decode microbatches look the same).  At prefill scale
    (seq ≥ 4k) individual GEMMs saturate the device and operator
    parallelism is correctly neutral — shown in examples/opara_schedule_demo.
    """
    from repro.configs import get_config
    from repro.models.opgraph_export import build_lm_opgraph
    cfg = get_config(arch)
    return build_lm_opgraph(cfg, batch=batch, seq=seq, n_layers=n_layers,
                            moe_dispatch=moe_dispatch)


def moe_ragged_workload(batch: int = 1, seq: int = 32, n_layers: int = 4):
    """Routed-MoE topology at bench scale: router → per-expert ragged
    gathers (unequal static capacities) → two grouped-GEMM waves → combine.
    This is the graph shape the grouped ragged-M kernel executes; keeping it
    in the bench set gates the scheduler/simulator trajectory on the
    paper's hardest fan-out case (cost-only here — the differential harness
    owns the executable parity checks)."""
    return arch_workload("kimi-k2-1t-a32b", batch=batch, seq=seq,
                         n_layers=n_layers, moe_dispatch="ragged")
