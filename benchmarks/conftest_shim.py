"""Small payload-bearing graph used by overhead/wallclock benchmarks."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import OpGraph, OpKind
from repro.core.profiler import elementwise_cost, gemm_cost


def build_payload_graph(n_blocks: int = 4, width: int = 4, d: int = 64,
                        tokens: int = 8, seed: int = 0) -> OpGraph:
    rng = np.random.default_rng(seed)
    g = OpGraph("payload")
    cur = g.add("x", OpKind.INPUT, out_shape=(tokens, d))
    for blk in range(n_blocks):
        outs = []
        for b in range(width):
            w = jnp.asarray(rng.standard_normal((d, d)) * 0.05, jnp.float32)
            c = g.add(f"b{blk}_{b}_gemm", OpKind.GEMM, [cur],
                      fn=lambda x, w: x @ w, consts=(w,),
                      cost=gemm_cost(tokens, d, d, 4),
                      fuse_sig=("gemm", tokens, d, d),
                      out_shape=(tokens, d))
            r = g.add(f"b{blk}_{b}_relu", OpKind.ELEMENTWISE, [c],
                      fn=jax.nn.relu, cost=elementwise_cost(tokens * d, 4),
                      fuse_sig=("relu", tokens, d), out_shape=(tokens, d))
            outs.append(r)
        cur = g.add(f"b{blk}_sum", OpKind.ELEMENTWISE, outs,
                    fn=lambda *xs: sum(xs),
                    cost=elementwise_cost(tokens * d, 4, n_in=width),
                    out_shape=(tokens, d))
    g.validate()
    return g
