"""Fig. 2: effect of the operator LAUNCH ORDER alone (same streams) —
depth-first topological order vs Opara's resource/interference-aware order,
GoogLeNet, batch 1..32."""
from __future__ import annotations

from repro.core import SimConfig, schedule, simulate_plan

from .bench_inference import BENCH_HW, SMALL_GPU_SIM
from .workloads import googlenet_like


def run() -> list[str]:
    """Paper Fig. 2 comparison: order 1 = depth-first topological sort,
    order 2 = Opara (Alg. 2), same streams, non-preemptive dispatch.
    Reproduction: ~10% at batch 1 (paper: 29% on RTX 2080 SUPER, 10.3% on
    A100 — our occupancy model is calibrated to the A100-class budget)."""
    rows = ["batch,depth_first_us,opara_order_us,latency_reduction_pct"]
    for batch in (1, 4, 8, 16, 32):
        g = googlenet_like(batch)
        df = simulate_plan(schedule(g, "opara", "depth_first", BENCH_HW),
                           SMALL_GPU_SIM)
        op = simulate_plan(schedule(g, "opara", "opara", BENCH_HW),
                           SMALL_GPU_SIM)
        red = (df.makespan_us - op.makespan_us) / df.makespan_us * 100
        rows.append(f"{batch},{df.makespan_us:.1f},{op.makespan_us:.1f},{red:.1f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
