#!/usr/bin/env python
"""Bench regression gate: compare a fresh benchmark run against the
committed trajectory JSONs and fail on >threshold slowdowns.

    # fresh run into a scratch dir
    PYTHONPATH=src python -m benchmarks.run --quick --json-dir /tmp/bench
    # gate against the committed baseline at the repo root
    python scripts/check_bench_regression.py --old . --new /tmp/bench

On noisy boxes (shared VMs with CPU-steal phases), pass several fresh run
dirs: a metric fails only when it regresses in EVERY run.  A real slowdown
reproduces in each; a scheduler phase flags a different set per run.

    python scripts/check_bench_regression.py --old . --new /tmp/b1 /tmp/b2

Watched metrics (matched per workload name, missing entries skipped):
  BENCH_scheduler.json  workloads[].schedule_ms, overhead[].schedule_ms,
                        overhead[].est_static_us / est_refined_us
                        (deterministic: no envelope, gated even under
                        --makespan-only)
  BENCH_inference.json  workloads[].schedule_ms,
                        workloads[].policies[*].makespan_us,
                        workloads[].autotune.est_makespan_us
                        (deterministic: no envelope)

Non-numeric record fields (policy-name strings, repacked/refined flags)
are skipped explicitly — only int/float metrics enter the comparison.

A metric regresses when ``new > old * (1 + threshold)`` AND the absolute
slowdown exceeds a noise floor (wall-clock ms jitter on loaded CI boxes;
simulated makespans are deterministic so their floor is tiny).  Exit code:
0 clean, 1 regressions found, 2 usage/IO error.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# (relative threshold is the CLI flag; these are per-unit noise floors)
MS_FLOOR = 0.5     # wall-clock timings below this delta are jitter
US_FLOOR = 1.0     # simulated makespan (deterministic, tiny floor)
EST_FLOOR = 0.01   # cost-model estimates are bit-deterministic: anything
                   # above JSON rounding (2 decimals) is a real regression


def _load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}
    except ValueError as e:
        raise SystemExit(f"error: malformed JSON in {path}: {e}")


def _by_workload(records: list[dict]) -> dict[str, dict]:
    return {r.get("workload", f"#{i}"): r for i, r in enumerate(records)}


def _check(name: str, metric: str, old: float, new: float,
           threshold: float, floor: float) -> str | None:
    # Explicitly numeric-only: trajectory records carry string provenance
    # (e.g. tuned policy names) next to the gated metrics, and a bool is
    # a flag, not a timing — neither may reach the arithmetic below.
    if not isinstance(old, (int, float)) or isinstance(old, bool):
        return None
    if not isinstance(new, (int, float)) or isinstance(new, bool):
        return None
    if old <= 0:
        return None
    if new > old * (1.0 + threshold) and (new - old) > floor:
        return (f"REGRESSION {name} {metric}: "
                f"{old:.4g} -> {new:.4g} (+{(new / old - 1) * 100:.0f}%)")
    return None


def compare_records(old_records: list[dict], new_records: list[dict],
                    metrics_ms: list[str], threshold: float,
                    tag: str = "",
                    floor: float = MS_FLOOR) -> list[tuple[str, str]]:
    """Per-workload metric comparison; returns (key, message) pairs.

    ``key`` identifies the metric across runs (``tag`` disambiguates the
    same workload name appearing in several trajectory files) so multi-run
    intersection can match regressions by identity, not by value."""
    out = []
    old_by = _by_workload(old_records)
    for name, new_rec in _by_workload(new_records).items():
        old_rec = old_by.get(name)
        if old_rec is None:
            continue
        for m in metrics_ms:
            msg = _check(name, m, old_rec.get(m), new_rec.get(m),
                         threshold, floor)
            if msg:
                out.append((f"{tag}:{name}:{m}", msg))
    return out


def compare_inference(old: dict, new: dict, threshold: float,
                      makespan_only: bool = False) -> list[tuple[str, str]]:
    out = [] if makespan_only else compare_records(
        old.get("workloads", []), new.get("workloads", []),
        ["schedule_ms"], threshold, tag="inference")
    old_by = _by_workload(old.get("workloads", []))
    for name, new_rec in _by_workload(new.get("workloads", [])).items():
        old_rec = old_by.get(name)
        if old_rec is None:
            continue
        for policy, new_p in new_rec.get("policies", {}).items():
            old_p = old_rec.get("policies", {}).get(policy)
            if old_p is None:
                continue
            msg = _check(f"{name}/{policy}", "makespan_us",
                         old_p.get("makespan_us"), new_p.get("makespan_us"),
                         threshold, US_FLOOR)
            if msg:
                out.append((f"makespan:{name}:{policy}", msg))
        # The autotuned row's predicted makespan is bit-deterministic (pure
        # cost-model arithmetic), so it is gated with NO relative envelope:
        # a search change that returns a worse schedule fails the gate even
        # when the simulated makespans above stay inside their thresholds.
        msg = _check(f"{name}/autotune", "est_makespan_us",
                     (old_rec.get("autotune") or {}).get("est_makespan_us"),
                     (new_rec.get("autotune") or {}).get("est_makespan_us"),
                     0.0, EST_FLOOR)
        if msg:
            out.append((f"est:{name}:autotune", msg))
    return out


def compare_dirs(old_dir: str, new_dir: str, threshold: float,
                 makespan_only: bool = False) -> list[tuple[str, str]]:
    regressions: list[tuple[str, str]] = []
    old_s = _load(os.path.join(old_dir, "BENCH_scheduler.json"))
    new_s = _load(os.path.join(new_dir, "BENCH_scheduler.json"))
    if not makespan_only:
        regressions += compare_records(old_s.get("workloads", []),
                                       new_s.get("workloads", []),
                                       ["schedule_ms"], threshold,
                                       tag="scheduler")
        regressions += compare_records(old_s.get("overhead", []),
                                       new_s.get("overhead", []),
                                       ["schedule_ms"], threshold,
                                       tag="overhead")
    # predicted-makespan trajectory of the autotune+refine pass on the big
    # graph: deterministic cost-model output, gated with no envelope (and
    # under --makespan-only too — it is machine-independent)
    for section, tag in (("overhead", "overhead-est"),
                         ("workloads", "scheduler-est")):
        regressions += compare_records(old_s.get(section, []),
                                       new_s.get(section, []),
                                       ["est_static_us", "est_refined_us"],
                                       0.0, tag=tag, floor=EST_FLOOR)
    old_i = _load(os.path.join(old_dir, "BENCH_inference.json"))
    new_i = _load(os.path.join(new_dir, "BENCH_inference.json"))
    regressions += compare_inference(old_i, new_i, threshold, makespan_only)
    return regressions


def gate(old_dir: str, new_dirs: list[str], threshold: float,
         makespan_only: bool = False) -> list[str]:
    """Regression messages confirmed across ALL fresh runs.

    With one run dir this is the plain comparison.  With several, a metric
    must regress in every run to fail — wall-clock noise on a shared box
    flags a different set per run, a real slowdown reproduces in each."""
    per_run = [dict(compare_dirs(old_dir, d, threshold, makespan_only))
               for d in new_dirs]
    confirmed = set(per_run[0])
    for found in per_run[1:]:
        confirmed &= set(found)
    # report the first run's numbers for each confirmed metric
    return [msg for key, msg in sorted(per_run[0].items()) if key in confirmed]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--old", default=".",
                    help="baseline dir holding committed BENCH_*.json")
    ap.add_argument("--new", required=True, nargs="+",
                    help="dir(s) holding fresh BENCH_*.json runs; with "
                         "several, only regressions confirmed in EVERY "
                         "run fail the gate")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="relative slowdown that fails the gate (0.20 = 20%%)")
    ap.add_argument("--makespan-only", action="store_true",
                    help="gate only the deterministic simulated makespan_us "
                         "metrics — wall-clock ms baselines are machine-"
                         "specific, so cross-machine runs (CI) use this")
    args = ap.parse_args(argv)

    for d in (args.old, *args.new):
        if not any(os.path.exists(os.path.join(d, f))
                   for f in ("BENCH_scheduler.json", "BENCH_inference.json")):
            print(f"error: no BENCH_*.json under {d}", file=sys.stderr)
            return 2

    regressions = gate(args.old, args.new, args.threshold,
                       makespan_only=args.makespan_only)
    for msg in regressions:
        print(msg)
    if regressions:
        print(f"{len(regressions)} metric(s) regressed "
              f">{args.threshold * 100:.0f}%", file=sys.stderr)
        return 1
    print("bench gate clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
