"""Render EXPERIMENTS.md tables from the sweep JSONL results."""
from __future__ import annotations

import json
import sys


def load(path):
    seen = {}
    try:
        for line in open(path):
            r = json.loads(line)
            seen[(r["arch"], r["shape"], r.get("tag"))] = r  # later wins
    except FileNotFoundError:
        pass
    return seen


def gb(x):
    return f"{x / 2**30:.2f}"


def dryrun_table(path, title):
    seen = load(path)
    out = [f"### {title}", "",
           "| arch | shape | status | compile_s | args GB/dev | temp GB/dev | "
           "fits 16GB? | HLO flops/dev | collectives (AR/AG/RS/A2A/CP) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, tag), r in sorted(seen.items()):
        if tag:
            continue
        if r["status"] == "SKIP":
            out.append(f"| {arch} | {shape} | SKIP ({r['reason'][:40]}…) "
                       "| | | | | | |")
            continue
        if r["status"] != "OK":
            out.append(f"| {arch} | {shape} | {r['status']} | | | | | | |")
            continue
        m = r["memory"]
        args, temp = m["argument_size_in_bytes"], m["temp_size_in_bytes"]
        fits = "YES" if (args + temp) <= 16 * 2**30 else f"NO ({gb(args+temp)}GB)"
        c = r["collectives"]["count_by_kind"]
        cc = f"{c.get('all-reduce',0)}/{c.get('all-gather',0)}/" \
             f"{c.get('reduce-scatter',0)}/{c.get('all-to-all',0)}/" \
             f"{c.get('collective-permute',0)}"
        out.append(
            f"| {arch} | {shape} | OK | {r.get('compile_s','')} | {gb(args)} "
            f"| {gb(temp)} | {fits} | {r['cost'].get('flops',0):.3g} | {cc} |")
    return "\n".join(out)


def roofline_table(path):
    seen = load(path)
    out = ["| arch | shape | dominant | roofline frac | compute_s | memory_s "
           "| collective_s | step LB (s) | MODEL_FLOPS | useful ratio |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, tag), r in sorted(seen.items()):
        if tag or r["status"] != "OK":
            if not tag and r["status"] == "SKIP":
                out.append(f"| {arch} | {shape} | SKIP | | | | | | | |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {arch} | {shape} | {rf['dominant'][:-2]} "
            f"| {rf['roofline_fraction']:.3f} | {rf['compute_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| {rf['step_time_lower_bound_s']:.4f} | {r['model_flops']:.3g} "
            f"| {r['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


def perf_table(base_path, iter_path):
    base = load(base_path)
    iters = load(iter_path)
    out = ["| cell | variant | compute_s | memory_s | collective_s | "
           "step LB (s) | roofline frac | temp GB/dev | Δ step LB |",
           "|---|---|---|---|---|---|---|---|---|"]
    cells = sorted({(a, s) for (a, s, t) in iters if t})
    for arch, shape in cells:
        b = base.get((arch, shape, None))
        rows = [(t, r) for (a, s, t), r in iters.items()
                if a == arch and s == shape and t]
        if b and b["status"] == "OK":
            rf = b["roofline"]
            out.append(
                f"| {arch} × {shape} | **baseline (paper-faithful)** "
                f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
                f"| {rf['collective_s']:.4f} | {rf['step_time_lower_bound_s']:.4f} "
                f"| {rf['roofline_fraction']:.3f} "
                f"| {gb(b['memory']['temp_size_in_bytes'])} | — |")
            lb0 = rf["step_time_lower_bound_s"]
        else:
            lb0 = None
        for tag, r in sorted(rows):
            if r["status"] != "OK":
                out.append(f"| {arch} × {shape} | {tag} | {r['status']} | | | | | | |")
                continue
            rf = r["roofline"]
            lb = rf["step_time_lower_bound_s"]
            delta = f"{(1 - lb / lb0) * 100:+.1f}%" if lb0 else ""
            out.append(
                f"| {arch} × {shape} | {tag} | {rf['compute_s']:.4f} "
                f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} | {lb:.4f} "
                f"| {rf['roofline_fraction']:.3f} "
                f"| {gb(r['memory']['temp_size_in_bytes'])} | {delta} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print(dryrun_table("results/roofline_16x16.jsonl",
                           "Single-pod 16×16 (256 chips)"))
        print()
        print(dryrun_table("results/dryrun_2x16x16.jsonl",
                           "Multi-pod 2×16×16 (512 chips)"))
    if which in ("all", "roofline"):
        print()
        print(roofline_table("results/roofline_16x16.jsonl"))
    if which in ("all", "perf"):
        print()
        print(perf_table("results/roofline_16x16.jsonl",
                         "results/perf_iterations.jsonl"))
