"""Chaos smoke: the differential harness, once per injection site.

For each fault site the script arms ``$REPRO_FAULT_PLAN`` (exactly the way
chaos CI would), runs the matching scenario against a fresh
:class:`repro.core.Session` / serving engine, and checks the degradation
contract from ``docs/robustness.md``:

* outputs equal the fault-free ground truth (per-op sequential execution);
* the degradation is reported — ``Session.cache_stats()`` counters /
  ``CompiledModel.explain()["degraded"]`` / a FAILED request record.

Exit status is non-zero if any site breaks the contract.

    PYTHONPATH=src python scripts/chaos_smoke.py [--skip-engine]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import traceback
import warnings

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Session, SessionConfig, run_sequential_uncompiled
from repro.core.graph import OpGraph, OpKind
from repro.core.profiler import gemm_cost
from repro.runtime.faults import ENV_VAR
from repro.runtime.guard import DegradationWarning


def build_branchy_graph(width: int = 3, d: int = 64, tokens: int = 8,
                        seed: int = 0) -> OpGraph:
    """Stackable parallel-matmul DAG (the Inception motivation shape)."""
    rng = np.random.default_rng(seed)
    g = OpGraph("chaos")
    inp = g.add("x", OpKind.INPUT, out_shape=(tokens, d))
    outs = []
    for b in range(width):
        w = jnp.asarray(rng.standard_normal((d, d)) * 0.05, jnp.float32)
        outs.append(g.add(f"gemm{b}", OpKind.GEMM, [inp],
                          fn=lambda x, w: x @ w, cost=gemm_cost(tokens, d, d, 4),
                          fuse_sig=("gemm", tokens, d, d), consts=(w,),
                          payload="matmul"))
    g.add("sum", OpKind.ELEMENTWISE, outs, fn=lambda *xs: sum(xs))
    g.validate()
    return g


def build_ragged_graph(sizes=(8, 24, 16), k: int = 128, f: int = 128,
                       seed: int = 3) -> OpGraph:
    """Ragged-M matmul fan-out (the MoE expert shape, grouped-GEMM route)."""
    rng = np.random.default_rng(seed)
    g = OpGraph("chaos-ragged")
    for i, m in enumerate(sizes):
        x = g.add(f"x{i}", OpKind.INPUT, out_shape=(m, k),
                  out_dtype=jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, f)) * 0.05, jnp.float32)
        g.add(f"gemm{i}", OpKind.GEMM, [x], fn=lambda x, w: x @ w,
              cost=gemm_cost(m, k, f, 4), fuse_sig=("gemm", k, f),
              consts=(w,), payload="matmul", out_shape=(m, f),
              out_dtype=jnp.float32)
    g.validate()
    return g


def _graph_inputs(g: OpGraph, seed: int = 9) -> dict:
    rng = np.random.default_rng(seed)
    return {n.name: jnp.asarray(rng.standard_normal(n.out_shape) * 0.1,
                                jnp.float32)
            for n in g if n.fn is None}


def _assert_matches(got, ref, what: str) -> None:
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=what)


def check_graph_site(site: str, ragged: bool = False) -> None:
    g = build_ragged_graph() if ragged else build_branchy_graph()
    inputs = _graph_inputs(g)
    ref = run_sequential_uncompiled(g, inputs)
    calib = {n.op_id: inputs[n.name] for n in g if n.fn is None}
    if site == "calib_disk_read":       # the read site needs a populated tier
        Session().calibrate(g, calib)
    cfg = SessionConfig(gemm_kernel="auto" if ragged else "pallas",
                        load_calibration=(site == "calib_disk_read"))
    sess = Session(cfg)                 # plan comes from $REPRO_FAULT_PLAN
    model = sess.compile(g, inputs=calib)
    _assert_matches(model(inputs), ref, f"site={site}")
    stats = sess.cache_stats()
    reported = (stats["degraded_routes"] + stats["calib_degraded_analytic"]
                + stats["calib_disk_errors"])
    assert reported >= 1, f"site={site}: degradation not reported ({stats})"


def check_arch_differential_site(site: str) -> None:
    """The differential harness on a real exporter-built arch graph
    (glm4 2L: decomposed attention stages with (w, b) bias consts,
    weight-streamed FFN folded into matmul-marked GEMMs) with a capture
    fault armed: the compiled pipeline must degrade, not diverge."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models import make_model
    from repro.models.opgraph_export import build_lm_opgraph

    cfg = dataclasses.replace(get_config("glm4-9b", smoke=True),
                              dtype=jnp.float32)
    params = make_model(cfg).init(jax.random.key(0))
    g = build_lm_opgraph(cfg, batch=1, seq=4, params=params, n_layers=2)
    tokens = jnp.asarray([[3, 1, 4, 1]], jnp.int32)
    inputs = {"tokens": tokens}
    ref = run_sequential_uncompiled(g, inputs)
    calib = {n.op_id: tokens for n in g if n.fn is None}
    sess = Session(SessionConfig(gemm_kernel="pallas"))
    model = sess.compile(g, inputs=calib)
    _assert_matches(model(inputs), ref, f"site={site} arch=glm4-9b")
    stats = sess.cache_stats()
    assert stats["degraded_routes"] >= 1, \
        f"site={site}: degradation not reported ({stats})"


_SERVE_MODEL = None


def _serve_model():
    """One smoke model shared by every serving scenario (jit reuse)."""
    global _SERVE_MODEL
    if _SERVE_MODEL is None:
        import jax

        from repro.configs import get_config
        from repro.models import make_model

        cfg = get_config("llama3.2-1b", smoke=True)
        model = make_model(cfg)
        params = model.init(jax.random.key(0))
        _SERVE_MODEL = (cfg, model, params)
    return _SERVE_MODEL


def check_engine_site() -> None:
    """decode_step corrupt → ONE poisoned request FAILED, co-batch completes
    with fault-free outputs."""
    from repro.serving import InferenceEngine, Request, RequestState

    cfg, model, params = _serve_model()

    def run():
        engine = InferenceEngine(model, params, max_slots=3, max_len=32)
        for rid in range(3):
            engine.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                                  max_tokens=4))
        return {r.rid: r for r in engine.run()}

    with _disarmed():
        clean = run()
    done = run()
    failed = [r for r in done.values() if r.state is RequestState.FAILED]
    assert len(failed) == 1, f"expected 1 FAILED request, got {len(failed)}"
    survivors = [r for r in done.values() if r.state is RequestState.DONE]
    assert len(survivors) == 2
    for r in survivors:
        assert r.output == clean[r.rid].output, f"rid={r.rid} outputs diverged"


def _overload_run():
    """Deterministic burst trace (arrival > capacity, mixed tenants /
    priorities / deadlines) against a bounded admission queue.  Returns
    (engine, {rid: request}) after every request went terminal."""
    from repro.serving import AdmissionConfig, InferenceEngine, Request

    cfg, model, params = _serve_model()
    engine = InferenceEngine(
        model, params, max_slots=2, max_len=32,
        admission=AdmissionConfig(max_queue=3, tenant_quota=2))
    reqs = []
    for rid in range(7):                          # burst: 7 at once, 2 slots
        reqs.append(Request(
            rid=rid, prompt=[1 + rid, 2, 3], max_tokens=4,
            tenant=f"t{rid % 3}", priority=rid % 2,
            ttl=10 + 2 * rid if rid % 2 else None))
        engine.submit(reqs[-1])
    engine.run(max_ticks=64)
    return engine, {r.rid: r for r in reqs}


def _check_all_terminal(engine, done, site: str) -> None:
    from repro.serving import TERMINAL_STATES

    for r in done.values():
        assert r.state in TERMINAL_STATES, \
            f"site={site}: rid={r.rid} stranded in {r.state}"
    assert len(engine.admission) == 0, f"site={site}: queue not drained"
    assert all(s is None for s in engine.slots), \
        f"site={site}: slot not released"


def check_overload_site(site: str) -> None:
    """Serving overload with an admission-tier fault armed: the engine
    must neither crash nor strand a request, the degradation must be
    counted, and surviving DONE outputs must equal the fault-free run
    (greedy decode is schedule-independent)."""
    from repro.serving import RequestState

    with _disarmed():
        _, clean = _overload_run()
    engine, done = _overload_run()
    _check_all_terminal(engine, done, site)
    counter = {"admission_enqueue": "admission_faults",
               "slot_preempt": "preempt_faults",
               "deadline_check": "deadline_faults"}[site]
    assert engine.fault_stats[counter] >= 1, \
        f"site={site}: fault not counted ({engine.fault_stats})"
    for rid, r in done.items():
        if r.state is RequestState.DONE \
                and clean[rid].state is RequestState.DONE:
            assert r.output == clean[rid].output, \
                f"site={site}: rid={rid} outputs diverged"


def check_preempt_site() -> None:
    """slot_preempt raise → the preemption is skipped (victim keeps its
    slot, the critical request expires instead) — never a crash."""
    from repro.serving import (AdmissionConfig, InferenceEngine, Request,
                               RequestState)

    cfg, model, params = _serve_model()

    def run():
        engine = InferenceEngine(model, params, max_slots=1, max_len=32,
                                 admission=AdmissionConfig())
        batch = Request(rid=0, prompt=[1, 2, 3], max_tokens=8, priority=0)
        engine.submit(batch)
        engine.step()                      # batch takes the only slot
        prod = Request(rid=1, prompt=[4, 5, 6], max_tokens=4, priority=2,
                       ttl=6)              # deadline-critical next tick
        engine.submit(prod)
        engine.run(max_ticks=64)
        return engine, batch, prod

    with _disarmed():
        _, clean_batch, clean_prod = run()
    assert clean_prod.state is RequestState.DONE       # preemption worked
    assert clean_batch.preemptions == 1
    engine, batch, prod = run()
    assert engine.fault_stats["preempt_faults"] >= 1, \
        f"preemption fault not counted ({engine.fault_stats})"
    assert batch.state is RequestState.DONE
    assert batch.output == clean_batch.output
    assert prod.state is RequestState.EXPIRED          # skipped preemption
    _check_all_terminal(engine, {0: batch, 1: prod}, "slot_preempt")


def _paged_run():
    """Burst trace against the paged-KV engine (small pages so every request
    spans several; preemption exercises the keep-pages/resume path).
    Returns (engine, {rid: request}) after every request went terminal."""
    from repro.serving import AdmissionConfig, InferenceEngine, Request

    cfg, model, params = _serve_model()
    engine = InferenceEngine(
        model, params, max_slots=2, max_len=32,
        admission=AdmissionConfig(max_queue=4, preemption=True),
        paged_kv=True, page_size=4)
    reqs = []
    for rid in range(6):                          # burst: 6 at once, 2 slots
        reqs.append(Request(
            rid=rid, prompt=[1 + rid, 2, 3, 4], max_tokens=4,
            tenant=f"t{rid % 2}", priority=rid % 3,
            ttl=12 + 2 * rid if rid % 2 else None))
        engine.submit(reqs[-1])
    engine.run(max_ticks=64)
    return engine, {r.rid: r for r in reqs}


def check_paged_site(site: str) -> None:
    """Paged-KV overload with a page-tier fault armed: no crash, no stranded
    request, the fault counted, DONE outputs equal the fault-free run, and
    the pool's books balance (every non-leaked page back on the free list)."""
    from repro.serving import RequestState

    with _disarmed():
        _, clean = _paged_run()
    engine, done = _paged_run()
    _check_all_terminal(engine, done, site)
    counter = {"page_alloc": "page_alloc_faults",
               "block_table_build": "block_table_faults",
               "page_release": "page_release_faults"}[site]
    assert engine.fault_stats[counter] >= 1, \
        f"site={site}: fault not counted ({engine.fault_stats})"
    for rid, r in done.items():
        if r.state is RequestState.DONE \
                and clean[rid].state is RequestState.DONE:
            assert r.output == clean[rid].output, \
                f"site={site}: rid={rid} outputs diverged"
    leaked = engine.pool.stats["leaked_pages"]
    assert engine.pool.used_pages == leaked, \
        f"site={site}: pool books off (used={engine.pool.used_pages}, " \
        f"leaked={leaked})"
    if site == "page_release":
        assert leaked >= 1, f"site={site}: failed release did not leak"
    if site == "block_table_build":
        assert engine.fault_stats["paged_decode_fallbacks"] >= 1, \
            f"site={site}: dense-gather rung not taken"


class _disarmed:
    def __enter__(self):
        self._saved = os.environ.pop(ENV_VAR, None)

    def __exit__(self, *exc):
        if self._saved is not None:
            os.environ[ENV_VAR] = self._saved


SCENARIOS = [
    ("kernel_compile:raise:-1", lambda: check_graph_site("kernel_compile")),
    ("grouped_gemm_route:raise:-1",
     lambda: check_graph_site("grouped_gemm_route", ragged=True)),
    ("kernel_compile:raise:-1",
     lambda: check_arch_differential_site("kernel_compile")),
    ("calibration_measure:raise:-1",
     lambda: check_graph_site("calibration_measure")),
    ("calib_disk_read:raise:-1", lambda: check_graph_site("calib_disk_read")),
    ("calib_disk_write:raise:-1",
     lambda: check_graph_site("calib_disk_write")),
    ("plan_validate:raise:-1", lambda: check_graph_site("plan_validate")),
    ("decode_step:corrupt:1:0", check_engine_site),
    # serving tier under overload: burst trace × each admission fault site
    ("admission_enqueue:raise:2",
     lambda: check_overload_site("admission_enqueue")),
    ("deadline_check:raise:-1",
     lambda: check_overload_site("deadline_check")),
    ("slot_preempt:raise:-1", check_preempt_site),
    # paged-KV tier: burst trace × each page fault site
    ("page_alloc:raise:2", lambda: check_paged_site("page_alloc")),
    ("block_table_build:raise:1",
     lambda: check_paged_site("block_table_build")),
    ("page_release:raise:1", lambda: check_paged_site("page_release")),
]

# scenarios that spin up the (slower) serving engine — skipped by --skip-engine
_ENGINE_SITES = ("decode_step", "admission_enqueue", "deadline_check",
                 "slot_preempt", "page_alloc", "block_table_build",
                 "page_release")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-engine", action="store_true",
                    help="skip the (slower) serving-engine scenarios")
    args = ap.parse_args(argv)
    failures = 0
    with tempfile.TemporaryDirectory() as calib_dir:
        os.environ["REPRO_CALIB_DIR"] = calib_dir
        for spec, scenario in SCENARIOS:
            if args.skip_engine and spec.startswith(_ENGINE_SITES):
                print(f"[chaos] SKIP {spec}")
                continue
            os.environ[ENV_VAR] = spec
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DegradationWarning)
                    scenario()
                print(f"[chaos] PASS {spec}")
            except Exception:
                failures += 1
                print(f"[chaos] FAIL {spec}")
                traceback.print_exc()
            finally:
                os.environ.pop(ENV_VAR, None)
    print(f"[chaos] {len(SCENARIOS) - failures}/{len(SCENARIOS)} sites clean")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
