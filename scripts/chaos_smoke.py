"""Chaos smoke: the differential harness, once per injection site.

For each fault site the script arms ``$REPRO_FAULT_PLAN`` (exactly the way
chaos CI would), runs the matching scenario against a fresh
:class:`repro.core.Session` / serving engine, and checks the degradation
contract from ``docs/robustness.md``:

* outputs equal the fault-free ground truth (per-op sequential execution);
* the degradation is reported — ``Session.cache_stats()`` counters /
  ``CompiledModel.explain()["degraded"]`` / a FAILED request record.

Exit status is non-zero if any site breaks the contract.

    PYTHONPATH=src python scripts/chaos_smoke.py [--skip-engine]
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import traceback
import warnings

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Session, SessionConfig, run_sequential_uncompiled
from repro.core.graph import OpGraph, OpKind
from repro.core.profiler import gemm_cost
from repro.runtime.faults import ENV_VAR
from repro.runtime.guard import DegradationWarning


def build_branchy_graph(width: int = 3, d: int = 64, tokens: int = 8,
                        seed: int = 0) -> OpGraph:
    """Stackable parallel-matmul DAG (the Inception motivation shape)."""
    rng = np.random.default_rng(seed)
    g = OpGraph("chaos")
    inp = g.add("x", OpKind.INPUT, out_shape=(tokens, d))
    outs = []
    for b in range(width):
        w = jnp.asarray(rng.standard_normal((d, d)) * 0.05, jnp.float32)
        outs.append(g.add(f"gemm{b}", OpKind.GEMM, [inp],
                          fn=lambda x, w: x @ w, cost=gemm_cost(tokens, d, d, 4),
                          fuse_sig=("gemm", tokens, d, d), consts=(w,),
                          payload="matmul"))
    g.add("sum", OpKind.ELEMENTWISE, outs, fn=lambda *xs: sum(xs))
    g.validate()
    return g


def build_ragged_graph(sizes=(8, 24, 16), k: int = 128, f: int = 128,
                       seed: int = 3) -> OpGraph:
    """Ragged-M matmul fan-out (the MoE expert shape, grouped-GEMM route)."""
    rng = np.random.default_rng(seed)
    g = OpGraph("chaos-ragged")
    for i, m in enumerate(sizes):
        x = g.add(f"x{i}", OpKind.INPUT, out_shape=(m, k),
                  out_dtype=jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, f)) * 0.05, jnp.float32)
        g.add(f"gemm{i}", OpKind.GEMM, [x], fn=lambda x, w: x @ w,
              cost=gemm_cost(m, k, f, 4), fuse_sig=("gemm", k, f),
              consts=(w,), payload="matmul", out_shape=(m, f),
              out_dtype=jnp.float32)
    g.validate()
    return g


def _graph_inputs(g: OpGraph, seed: int = 9) -> dict:
    rng = np.random.default_rng(seed)
    return {n.name: jnp.asarray(rng.standard_normal(n.out_shape) * 0.1,
                                jnp.float32)
            for n in g if n.fn is None}


def _assert_matches(got, ref, what: str) -> None:
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5, err_msg=what)


def check_graph_site(site: str, ragged: bool = False) -> None:
    g = build_ragged_graph() if ragged else build_branchy_graph()
    inputs = _graph_inputs(g)
    ref = run_sequential_uncompiled(g, inputs)
    calib = {n.op_id: inputs[n.name] for n in g if n.fn is None}
    if site == "calib_disk_read":       # the read site needs a populated tier
        Session().calibrate(g, calib)
    cfg = SessionConfig(gemm_kernel="auto" if ragged else "pallas",
                        load_calibration=(site == "calib_disk_read"))
    sess = Session(cfg)                 # plan comes from $REPRO_FAULT_PLAN
    model = sess.compile(g, inputs=calib)
    _assert_matches(model(inputs), ref, f"site={site}")
    stats = sess.cache_stats()
    reported = (stats["degraded_routes"] + stats["calib_degraded_analytic"]
                + stats["calib_disk_errors"])
    assert reported >= 1, f"site={site}: degradation not reported ({stats})"


def check_engine_site() -> None:
    """decode_step corrupt → ONE poisoned request FAILED, co-batch completes
    with fault-free outputs."""
    import jax

    from repro.configs import get_config
    from repro.models import make_model
    from repro.serving import InferenceEngine, Request, RequestState

    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))

    def run():
        engine = InferenceEngine(model, params, max_slots=3, max_len=32)
        for rid in range(3):
            engine.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                                  max_tokens=4))
        return {r.rid: r for r in engine.run()}

    with _disarmed():
        clean = run()
    done = run()
    failed = [r for r in done.values() if r.state is RequestState.FAILED]
    assert len(failed) == 1, f"expected 1 FAILED request, got {len(failed)}"
    survivors = [r for r in done.values() if r.state is RequestState.DONE]
    assert len(survivors) == 2
    for r in survivors:
        assert r.output == clean[r.rid].output, f"rid={r.rid} outputs diverged"


class _disarmed:
    def __enter__(self):
        self._saved = os.environ.pop(ENV_VAR, None)

    def __exit__(self, *exc):
        if self._saved is not None:
            os.environ[ENV_VAR] = self._saved


SCENARIOS = [
    ("kernel_compile:raise:-1", lambda: check_graph_site("kernel_compile")),
    ("grouped_gemm_route:raise:-1",
     lambda: check_graph_site("grouped_gemm_route", ragged=True)),
    ("calibration_measure:raise:-1",
     lambda: check_graph_site("calibration_measure")),
    ("calib_disk_read:raise:-1", lambda: check_graph_site("calib_disk_read")),
    ("calib_disk_write:raise:-1",
     lambda: check_graph_site("calib_disk_write")),
    ("plan_validate:raise:-1", lambda: check_graph_site("plan_validate")),
    ("decode_step:corrupt:1:0", check_engine_site),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-engine", action="store_true",
                    help="skip the (slower) serving-engine decode scenario")
    args = ap.parse_args(argv)
    failures = 0
    with tempfile.TemporaryDirectory() as calib_dir:
        os.environ["REPRO_CALIB_DIR"] = calib_dir
        for spec, scenario in SCENARIOS:
            if args.skip_engine and spec.startswith("decode_step"):
                print(f"[chaos] SKIP {spec}")
                continue
            os.environ[ENV_VAR] = spec
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", DegradationWarning)
                    scenario()
                print(f"[chaos] PASS {spec}")
            except Exception:
                failures += 1
                print(f"[chaos] FAIL {spec}")
                traceback.print_exc()
            finally:
                os.environ.pop(ENV_VAR, None)
    print(f"[chaos] {len(SCENARIOS) - failures}/{len(SCENARIOS)} sites clean")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
