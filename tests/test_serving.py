"""Serving engine: completion, continuous batching, greedy consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.runtime import DegradationWarning
from repro.serving import InferenceEngine, Request, RequestState
from repro.serving.sampler import sample_token


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_completes_all_requests(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=2, max_len=64)
    for rid in range(5):
        engine.submit(Request(rid=rid, prompt=[1, 2, 3, 4 + rid], max_tokens=6))
    done = engine.run()
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)


def test_engine_greedy_matches_manual_decode(small_model):
    """Engine output (batched slots) == manual prefill+decode loop."""
    cfg, model, params = small_model
    prompt = [5, 9, 2, 7, 1]
    max_tokens = 5

    engine = InferenceEngine(model, params, max_slots=2, max_len=64)
    engine.submit(Request(rid=0, prompt=prompt, max_tokens=max_tokens))
    # a second concurrent request exercises slot interference
    engine.submit(Request(rid=1, prompt=[3, 3, 3], max_tokens=max_tokens))
    done = {r.rid: r for r in engine.run()}

    # manual loop
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = model.prefill(params, {"tokens": toks},
                                   cache_len=64 + cfg.meta_tokens)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(out) < max_tokens:
        logits, caches = model.decode(params, jnp.asarray([out[-1]], jnp.int32),
                                      caches, jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert done[0].output == out


def test_eos_terminates(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=64)
    # probe: first greedy token becomes the eos so the request ends at len 1
    logits, _ = model.prefill(params, {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)},
                              cache_len=64 + cfg.meta_tokens)
    eos = int(jnp.argmax(logits[0]))
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_tokens=32, eos_id=eos))
    done = engine.run()
    assert len(done) == 1 and len(done[0].output) == 1


def test_engine_reschedule_hits_calibration_cache(small_model):
    """First engine profiles its step graph once; a second engine sharing
    the session (same model structure + batch geometry) and an in-place
    re-schedule both hydrate from the calibration cache — zero re-timing."""
    from repro.core import Session
    from conftest import count_measure_calls

    cfg, model, params = small_model
    sess = Session()
    with count_measure_calls() as timing:
        e1 = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=sess)
        p1 = e1.calibrate_schedule(n_layers=1)
        assert timing["n"] == 1 and p1 is e1.schedule_plan

        e2 = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=sess)
        p2 = e2.calibrate_schedule(n_layers=1)   # warm: cache-served
        p1b = e1.calibrate_schedule(n_layers=1)  # re-schedule: also warm
    assert timing["n"] == 1, "serving re-schedules must not re-time"
    assert p2.order == p1.order == p1b.order
    stats = sess.cache_stats()
    assert stats["calib_misses"] == 1 and stats["calib_hits"] == 2


def test_engine_without_session_uses_default(small_model):
    """Engines constructed without an explicit session share the process
    default (the legacy module-global behavior)."""
    from repro.core import default_session
    from conftest import count_measure_calls

    cfg, model, params = small_model
    with count_measure_calls() as timing:
        e1 = InferenceEngine(model, params, max_slots=2, max_len=32)
        e1.calibrate_schedule(n_layers=1)
        e2 = InferenceEngine(model, params, max_slots=2, max_len=32)
        e2.calibrate_schedule(n_layers=1)
    assert timing["n"] == 1
    assert default_session().cache_stats()["calib_hits"] >= 1


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "hymba-1.5b"])
def test_calibrate_schedule_measures_ssm_archs(arch):
    """rwkv/hybrid exports used to carry cost-only scan operators and forced
    calibrate_schedule down the measured→analytic rung; the traced-kernel
    exporter threads real payloads through those builders, so measured
    calibration now runs end to end with no degradation."""
    import warnings

    from repro.core import Session
    from repro.runtime import DegradationWarning

    cfg = get_config(arch, smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    sess = Session()
    engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=sess)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DegradationWarning)
        plan = engine.calibrate_schedule(n_layers=2)
    assert plan is engine.schedule_plan
    assert plan.n_streams >= 1
    scan = ".wkv_scan" if arch.startswith("rwkv") else ".mamba_scan"
    assert any(n.name.endswith(scan) for n in plan.graph)
    stats = sess.cache_stats()
    assert stats["calib_degraded_analytic"] == 0
    assert stats["calib_misses"] == 1           # measurement really ran
    assert plan.graph.calibration_fp is not None


def test_calibrate_schedule_works_on_routed_moe():
    """MoE engines export the routed (ragged) fan-out with real
    dispatch/combine payloads, so measured calibration — previously
    impossible for MoE — now runs end to end."""
    from repro.core import Session

    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=Session())
    plan = engine.calibrate_schedule(n_layers=2)
    assert plan is engine.schedule_plan
    assert any(".dispatch" in n.name for n in plan.graph)
    assert all(n.cost.measured_us is not None
               for n in plan.graph if n.fn is not None)


def test_sampler_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample_token(logits, jax.random.key(0))[0]) == 1  # greedy
    t = sample_token(logits, jax.random.key(0), temperature=1.0, top_k=2)
    assert int(t[0]) in (1, 2)
    t = sample_token(logits, jax.random.key(0), temperature=1.0, top_p=0.5)
    assert int(t[0]) == 1


# =========================================================================
# Paged KV cache (tentpole): differential vs the dense slab
# =========================================================================

def _terminal_map(done):
    return {r.rid: (r.state, tuple(r.output)) for r in done}


def test_paged_engine_matches_dense_on_overload_trace(small_model):
    """Byte-identical token streams: same trace, same admission policy, same
    seed — the paged engine must emit exactly what the dense engine does,
    through preemptions, sheds and expiries."""
    from benchmarks.bench_serving import _drive, build_trace
    from repro.serving import AdmissionConfig

    cfg, model, params = small_model
    trace = build_trace(n=12, seed=7)

    def run(paged):
        engine = InferenceEngine(
            model, params, max_slots=2, max_len=64, seed=3,
            admission=AdmissionConfig(policy="edf", preemption=True),
            paged_kv=paged, page_size=16)
        done = _drive(engine, trace)
        return engine, _terminal_map(done)

    dense_engine, dense = run(False)
    paged_engine, paged = run(True)
    assert paged_engine.paged
    assert paged == dense
    # every page returned to the pool once the trace drained
    assert paged_engine.pool.used_pages == 0
    assert paged_engine.health()["paged"]["holders"] == 0


def test_paged_resume_skips_reprefill(small_model):
    """A preempted paged request keeps its pages and resumes without
    re-prefilling; the dense engine re-runs the whole prefix."""
    from repro.serving import AdmissionConfig

    cfg, model, params = small_model

    def run(paged):
        engine = InferenceEngine(
            model, params, max_slots=1, max_len=32, seed=5,
            admission=AdmissionConfig(policy="edf", preemption=True),
            paged_kv=paged, page_size=4)
        low = Request(rid="low", prompt=[5, 6, 7], max_tokens=12, priority=0)
        engine.submit(low)
        for _ in range(4):
            engine.step()
        engine.submit(Request(rid="hi", prompt=[9, 9], max_tokens=3,
                              priority=3, ttl=4))
        done = engine.run(200)
        return engine, _terminal_map(done)

    dense_engine, dense = run(False)
    paged_engine, paged = run(True)
    assert paged == dense
    assert dense_engine.fault_stats["preemptions"] == 1
    assert paged_engine.fault_stats["preemptions"] == 1
    # dense pays a full re-prefill of prompt+output on resume; paged resumes
    # from its retained pages
    assert dense_engine.fault_stats["reprefilled_tokens"] > 0
    assert paged_engine.fault_stats["reprefilled_tokens"] == 0
    assert paged_engine.fault_stats["page_resumes"] == 1
    assert paged_engine.fault_stats["resumed_tokens"] > 0
    assert paged_engine.pool.used_pages == 0


def test_page_exhaustion_feeds_admission(small_model):
    """An undersized pool sheds/requeues instead of corrupting state: every
    request goes terminal and the pool drains."""
    from repro.serving import TERMINAL_STATES

    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=3, max_len=32, seed=2,
                             paged_kv=True, page_size=4, num_pages=6)
    reqs = [Request(rid=f"r{i}", prompt=[7, 8, 9, 1, 2], max_tokens=10)
            for i in range(4)]
    for r in reqs:
        engine.submit(r)
    done = engine.run(300)
    assert len(done) == 4
    assert all(r.state in TERMINAL_STATES for r in reqs)
    assert sum(r.state is RequestState.DONE for r in reqs) >= 1
    assert engine.fault_stats["page_exhaustions"] > 0
    assert engine.pool.used_pages == 0


def test_prefix_sharing_cow_is_transparent(small_model):
    """Two requests with the same prompt share prefix pages; COW keeps the
    token streams identical to the unshared run."""
    cfg, model, params = small_model
    prompt = [3, 1, 4, 1, 5, 9]

    def run(sharing):
        engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                                 seed=11, paged_kv=True, page_size=4,
                                 prefix_sharing=sharing)
        engine.submit(Request(rid="a", prompt=list(prompt), max_tokens=6))
        engine.submit(Request(rid="b", prompt=list(prompt), max_tokens=6))
        done = engine.run(200)
        return engine, _terminal_map(done)

    plain_engine, plain = run(False)
    shared_engine, shared = run(True)
    assert shared == plain
    assert plain_engine.pool.stats["shared_hits"] == 0
    assert shared_engine.pool.stats["shared_hits"] > 0
    # the shared partial page is copied before either writer extends it
    assert shared_engine.pool.stats["cow_copies"] >= 1
    assert shared_engine.pool.used_pages == 0


def test_block_table_fault_lands_on_dense_gather_rung(small_model):
    """An injected block-table fault degrades the tick to the dense-gather
    rung — same outputs as the fault-free run, provenance recorded."""
    from repro.runtime.faults import FaultPlan

    cfg, model, params = small_model

    def run(spec):
        plan = FaultPlan.parse(spec) if spec else None
        engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                                 seed=3, paged_kv=True, page_size=4,
                                 fault_plan=plan)
        for i in range(3):
            engine.submit(Request(rid=f"r{i}", prompt=[4, 5, 6, 7],
                                  max_tokens=5))
        done = engine.run(200)
        return engine, _terminal_map(done)

    clean_engine, clean = run(None)
    with pytest.warns(DegradationWarning, match="dense-gather"):
        faulty_engine, faulty = run("block_table_build:raise:1")
    assert faulty == clean
    assert all(s[0] is RequestState.DONE for s in faulty.values())
    assert faulty_engine.fault_stats["block_table_faults"] == 1
    assert faulty_engine.fault_stats["paged_decode_fallbacks"] == 1


def test_page_release_fault_leaks_with_provenance(small_model):
    """A failed release leaks the pages (counted, capacity lost) instead of
    double-freeing or corrupting the free list."""
    from repro.runtime.faults import FaultPlan

    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=2, max_len=32, seed=3,
                             paged_kv=True, page_size=4,
                             fault_plan=FaultPlan.parse("page_release:raise:1"))
    for i in range(3):
        engine.submit(Request(rid=f"r{i}", prompt=[4, 5, 6, 7], max_tokens=5))
    done = engine.run(200)
    assert all(r.state is RequestState.DONE for r in done)
    assert engine.fault_stats["page_release_faults"] == 1
    leaked = engine.pool.stats["leaked_pages"]
    assert leaked > 0
    assert engine.pool.used_pages == leaked        # resident but unheld


def test_paged_matches_dense_on_mla_moe_smoke():
    """The MLA latent-page path (DeepSeek-style) emits the same streams as
    the dense engine."""
    cfg = get_config("deepseek-v3-671b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))

    def run(paged):
        engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                                 seed=9, paged_kv=paged, page_size=4)
        engine.submit(Request(rid="a", prompt=[3, 17, 42, 9], max_tokens=5))
        engine.submit(Request(rid="b", prompt=[11, 2], max_tokens=5))
        return _terminal_map(engine.run(200))

    assert run(True) == run(False)


def test_paged_kv_bytes_beat_dense_when_overcommitted(small_model):
    """Sizing the pool below slot capacity parity is the memory win: the
    paged cache is strictly smaller at equal max_slots."""
    cfg, model, params = small_model
    dense = InferenceEngine(model, params, max_slots=4, max_len=64)
    pages_per_req = -(-(64 + cfg.meta_tokens) // 16)
    paged = InferenceEngine(model, params, max_slots=4, max_len=64,
                            paged_kv=True, page_size=16,
                            num_pages=1 + 2 * pages_per_req)
    assert paged.kv_cache_bytes() < dense.kv_cache_bytes()
    assert dense.health()["paged"] is None
    assert paged.health()["paged"]["free_pages"] == 2 * pages_per_req


def test_paged_unsupported_family_degrades_to_dense():
    """A recurrent-state family cannot page; the engine says so once and
    serves on the dense slab."""
    cfg = get_config("rwkv6-1.6b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    with pytest.warns(DegradationWarning, match="paged_kv unavailable"):
        engine = InferenceEngine(model, params, max_slots=1, max_len=32,
                                 paged_kv=True)
    assert not engine.paged
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_tokens=3))
    done = engine.run(100)
    assert done[0].state is RequestState.DONE
