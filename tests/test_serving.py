"""Serving engine: completion, continuous batching, greedy consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model
from repro.serving import InferenceEngine, Request
from repro.serving.sampler import sample_token


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_engine_completes_all_requests(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=2, max_len=64)
    for rid in range(5):
        engine.submit(Request(rid=rid, prompt=[1, 2, 3, 4 + rid], max_tokens=6))
    done = engine.run()
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)


def test_engine_greedy_matches_manual_decode(small_model):
    """Engine output (batched slots) == manual prefill+decode loop."""
    cfg, model, params = small_model
    prompt = [5, 9, 2, 7, 1]
    max_tokens = 5

    engine = InferenceEngine(model, params, max_slots=2, max_len=64)
    engine.submit(Request(rid=0, prompt=prompt, max_tokens=max_tokens))
    # a second concurrent request exercises slot interference
    engine.submit(Request(rid=1, prompt=[3, 3, 3], max_tokens=max_tokens))
    done = {r.rid: r for r in engine.run()}

    # manual loop
    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = model.prefill(params, {"tokens": toks},
                                   cache_len=64 + cfg.meta_tokens)
    out = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    while len(out) < max_tokens:
        logits, caches = model.decode(params, jnp.asarray([out[-1]], jnp.int32),
                                      caches, jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
        pos += 1
    assert done[0].output == out


def test_eos_terminates(small_model):
    cfg, model, params = small_model
    engine = InferenceEngine(model, params, max_slots=1, max_len=64)
    # probe: first greedy token becomes the eos so the request ends at len 1
    logits, _ = model.prefill(params, {"tokens": jnp.asarray([[1, 2, 3]], jnp.int32)},
                              cache_len=64 + cfg.meta_tokens)
    eos = int(jnp.argmax(logits[0]))
    engine.submit(Request(rid=0, prompt=[1, 2, 3], max_tokens=32, eos_id=eos))
    done = engine.run()
    assert len(done) == 1 and len(done[0].output) == 1


def test_engine_reschedule_hits_calibration_cache(small_model):
    """First engine profiles its step graph once; a second engine sharing
    the session (same model structure + batch geometry) and an in-place
    re-schedule both hydrate from the calibration cache — zero re-timing."""
    from repro.core import Session
    from conftest import count_measure_calls

    cfg, model, params = small_model
    sess = Session()
    with count_measure_calls() as timing:
        e1 = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=sess)
        p1 = e1.calibrate_schedule(n_layers=1)
        assert timing["n"] == 1 and p1 is e1.schedule_plan

        e2 = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=sess)
        p2 = e2.calibrate_schedule(n_layers=1)   # warm: cache-served
        p1b = e1.calibrate_schedule(n_layers=1)  # re-schedule: also warm
    assert timing["n"] == 1, "serving re-schedules must not re-time"
    assert p2.order == p1.order == p1b.order
    stats = sess.cache_stats()
    assert stats["calib_misses"] == 1 and stats["calib_hits"] == 2


def test_engine_without_session_uses_default(small_model):
    """Engines constructed without an explicit session share the process
    default (the legacy module-global behavior)."""
    from repro.core import default_session
    from conftest import count_measure_calls

    cfg, model, params = small_model
    with count_measure_calls() as timing:
        e1 = InferenceEngine(model, params, max_slots=2, max_len=32)
        e1.calibrate_schedule(n_layers=1)
        e2 = InferenceEngine(model, params, max_slots=2, max_len=32)
        e2.calibrate_schedule(n_layers=1)
    assert timing["n"] == 1
    assert default_session().cache_stats()["calib_hits"] >= 1


def test_calibrate_schedule_degrades_partially_payloaded_arch():
    """Exports with cost-only operators (hybrid mamba, rwkv scan — builders
    that don't thread params yet) can't be measured — calibrate_schedule
    degrades to the analytic cost model with ONE structured warning and a
    counted provenance record, instead of failing the serve launch."""
    from repro.core import Session
    from repro.runtime import DegradationWarning

    cfg = get_config("rwkv6-1.6b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    sess = Session()
    engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=sess)
    with pytest.warns(DegradationWarning, match="cost-only"):
        plan = engine.calibrate_schedule(n_layers=2)
    assert plan is engine.schedule_plan
    assert plan.n_streams >= 1                  # analytic schedule exists
    stats = sess.cache_stats()
    assert stats["calib_degraded_analytic"] == 1
    assert stats["calib_misses"] == 0           # measurement never attempted
    events = sess.guard_log.as_dicts()
    assert [e["site"] for e in events] == ["calibration_measure"]
    assert events[0]["action"] == "measured->analytic"


def test_calibrate_schedule_works_on_routed_moe():
    """MoE engines export the routed (ragged) fan-out with real
    dispatch/combine payloads, so measured calibration — previously
    impossible for MoE — now runs end to end."""
    from repro.core import Session

    cfg = get_config("kimi-k2-1t-a32b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, max_slots=2, max_len=32,
                             session=Session())
    plan = engine.calibrate_schedule(n_layers=2)
    assert plan is engine.schedule_plan
    assert any(".dispatch" in n.name for n in plan.graph)
    assert all(n.cost.measured_us is not None
               for n in plan.graph if n.fn is not None)


def test_sampler_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]])
    assert int(sample_token(logits, jax.random.key(0))[0]) == 1  # greedy
    t = sample_token(logits, jax.random.key(0), temperature=1.0, top_k=2)
    assert int(t[0]) in (1, 2)
    t = sample_token(logits, jax.random.key(0), temperature=1.0, top_p=0.5)
    assert int(t[0]) == 1
