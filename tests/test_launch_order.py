"""Unit tests for Algorithm 2 (resource- and interference-aware launcher)."""
import numpy as np

from repro.core.graph import IntensityClass, OpCost, OpGraph, OpKind
from repro.core.launch_order import (
    depth_first_order,
    opara_launch_order,
    resource_only_order,
    topo_order,
    validate_order,
)
from repro.core.profiler import ModelProfiler, V5E

from conftest import build_inception_like


def _profiles(g):
    return ModelProfiler(V5E).profile(g)


def test_order_is_topological(inception_graph):
    profiles = _profiles(inception_graph)
    for fn in (opara_launch_order, resource_only_order):
        validate_order(inception_graph, fn(inception_graph, profiles))
    validate_order(inception_graph, topo_order(inception_graph))
    validate_order(inception_graph, depth_first_order(inception_graph))


def test_smallest_resource_first():
    """Among simultaneously-ready same-class ops, the least-demand launches
    first (paper Alg. 2 lines 5-6)."""
    g = OpGraph()
    root = g.add("root", OpKind.INPUT)
    big = g.add("big", OpKind.GEMM, [root],
                cost=OpCost(flops=1e9, bytes_read=1e6, bytes_written=1e6,
                            vmem_bytes=64e6))
    small = g.add("small", OpKind.GEMM, [root],
                  cost=OpCost(flops=1e9, bytes_read=1e6, bytes_written=1e6,
                              vmem_bytes=1e6))
    profiles = _profiles(g)
    order = opara_launch_order(g, profiles)
    assert order.index(small) < order.index(big)


def test_alternates_memory_and_compute():
    """Ready lists alternate between memory- and compute-intensive ops
    (paper Fig. 3 overlap)."""
    g = OpGraph()
    root = g.add("root", OpKind.INPUT)
    comp, mem = [], []
    for i in range(3):
        comp.append(g.add(f"c{i}", OpKind.GEMM, [root],
                          cost=OpCost(flops=1e12, bytes_read=1e6,
                                      bytes_written=1e6, vmem_bytes=1e6 + i)))
        mem.append(g.add(f"m{i}", OpKind.ELEMENTWISE, [root],
                         cost=OpCost(flops=1e3, bytes_read=1e8,
                                     bytes_written=1e8, vmem_bytes=1e6 + i)))
    profiles = _profiles(g)
    classes = [profiles[i].intensity for i in opara_launch_order(g, profiles)]
    classes = [c for c in classes if c is not None][1:]  # skip the root
    # no three consecutive ops share a class while both lists are non-empty
    runs = 1
    worst = 1
    for a, b in zip(classes, classes[1:]):
        runs = runs + 1 if a == b else 1
        worst = max(worst, runs)
    assert worst <= 2


def test_root_classification():
    prof = ModelProfiler(V5E)
    gemm = OpCost(flops=4e12, bytes_read=1e9, bytes_written=1e9)
    ew = OpCost(flops=1e6, bytes_read=1e9, bytes_written=1e9)
    assert gemm.intensity(V5E.machine_balance) is IntensityClass.COMPUTE
    assert ew.intensity(V5E.machine_balance) is IntensityClass.MEMORY
