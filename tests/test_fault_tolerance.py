"""Fault-tolerance control plane: failure detection, stragglers, elasticity."""
import pytest

from repro.runtime import (
    ElasticController,
    FailureDetector,
    HeartbeatMonitor,
    StragglerDetector,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_failure_detector_flags_silent_host():
    clock = FakeClock()
    mon = HeartbeatMonitor([0, 1, 2], clock)
    det = FailureDetector(mon, min_timeout=10.0)
    for _ in range(20):             # everyone beats every second
        clock.advance(1.0)
        for h in (0, 1, 2):
            mon.beat(h)
        det.observe()
    assert det.dead_hosts() == []
    for _ in range(30):             # host 2 goes silent
        clock.advance(1.0)
        mon.beat(0)
        mon.beat(1)
        det.observe()
    assert det.dead_hosts() == [2]
    assert not mon.hosts[2].alive


def test_straggler_detection_and_escalation():
    clock = FakeClock()
    mon = HeartbeatMonitor(list(range(8)), clock)
    det = StragglerDetector(k=3.0, min_samples=8)
    for step in range(16):
        clock.advance(1.0)
        for h in range(8):
            mon.beat(h, step_time=1.0 + (2.5 if h == 7 else 0.0))
    d1 = det.check(mon)
    assert d1 == {7: "rebalance"}
    d2 = det.check(mon)
    d3 = det.check(mon)
    assert d3 == {7: "evict"}       # third offence escalates


def test_no_straggler_on_uniform_fleet():
    clock = FakeClock()
    mon = HeartbeatMonitor(list(range(4)), clock)
    det = StragglerDetector()
    for _ in range(10):
        for h in range(4):
            mon.beat(h, step_time=1.0)
    assert det.check(mon) == {}


def test_elastic_controller_plans_power_of_two_mesh():
    ctl = ElasticController(hosts_per_pod=16, model_axis=16)
    plan = ctl.plan(alive_hosts=list(range(13)), checkpoint_step=1200)
    assert plan.mesh_shape == (8, 16)         # 13 survivors → 8-row mesh
    assert len(plan.new_hosts) == 8
    assert plan.checkpoint_step == 1200
    assert sorted(plan.data_partition.values()) == list(range(8))


def test_elastic_controller_requires_survivors():
    ctl = ElasticController(16, 16)
    with pytest.raises(RuntimeError):
        ctl.plan([], None)
