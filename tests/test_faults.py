"""Degradation ladder + deterministic fault injection (docs/robustness.md).

Covers, per ISSUE: the :class:`repro.runtime.FaultPlan` grammar and firing
semantics; the calibration measure-retry → analytic degrade chain; the disk
tier under injected read/write faults, concurrent writers and mid-write
corruption; the capture route ladder (branch_gemm→vmap,
grouped_gemm→sequential, plan_validate→sequential schedule); the serving
engine's poisoned-request isolation and decode watchdog; and a differential
property — any single-site fault with an available fallback produces the
same outputs as the fault-free run.
"""
import os
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Session, SessionConfig, run_sequential_uncompiled
from repro.core.profiler import ProfileTable
from repro.core.session import (
    _calib_disk_evict,
    _calib_disk_load,
    _calib_disk_store,
)
from repro.runtime import (
    DegradationLog,
    DegradationWarning,
    FaultInjected,
    FaultPlan,
    FaultSpec,
    activate,
    retry_with_backoff,
)
from repro.runtime import faults as faults_mod

from conftest import build_inception_like, count_measure_calls
from test_grouped_gemm import build_ragged_graph, _inputs_for

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback below
    HAVE_HYPOTHESIS = False


def _inputs(g):
    return {n.op_id: jnp.ones((8, 64), jnp.float32) for n in g if n.fn is None}


# -- FaultPlan unit behavior ---------------------------------------------------

def test_fault_spec_validates_site_and_mode():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultSpec(site="bogus")
    with pytest.raises(ValueError, match="unknown fault mode"):
        FaultSpec(site="decode_step", mode="bogus")
    with pytest.raises(ValueError, match="duplicate"):
        FaultPlan([FaultSpec(site="decode_step"), FaultSpec(site="decode_step")])


def test_fault_plan_parse_grammar():
    plan = FaultPlan.parse(
        "calibration_measure:raise:2; decode_step:corrupt:-1:3,plan_validate")
    assert plan.specs["calibration_measure"] == FaultSpec(
        site="calibration_measure", mode="raise", times=2)
    assert plan.specs["decode_step"] == FaultSpec(
        site="decode_step", mode="corrupt", times=-1, arg=3.0)
    # bare site → raise mode, every activation
    assert plan.specs["plan_validate"] == FaultSpec(
        site="plan_validate", mode="raise", times=-1)


def test_fire_counts_activations_and_disarms():
    plan = FaultPlan.single("kernel_compile", times=1)
    assert plan.armed("kernel_compile")
    with pytest.raises(FaultInjected) as exc:
        plan.fire("kernel_compile")
    assert exc.value.site == "kernel_compile"
    # second activation: disarmed — payload passes through, nothing counted
    assert plan.fire("kernel_compile", payload="ok") == "ok"
    assert plan.fired["kernel_compile"] == 1
    # unkeyed sites are free
    assert plan.fire("decode_step", payload=5) == 5
    assert plan.describe()["kernel_compile"]["fired"] == 1


def test_corrupt_mode_payloads():
    plan = FaultPlan.single("calib_disk_write", mode="corrupt", times=-1)
    mangled = plan.fire("calib_disk_write", payload='{"key": "v"}')
    assert "~CORRUPT~" in mangled
    with pytest.raises(ValueError):
        import json
        json.loads(mangled)
    arr_plan = FaultPlan.single("decode_step", mode="corrupt", times=-1, arg=1)
    poisoned = arr_plan.fire("decode_step", payload=jnp.ones((3, 4)))
    finite = np.isfinite(np.asarray(poisoned)).all(axis=-1)
    assert list(finite) == [True, False, True]   # exactly row 1 poisoned


def test_delay_mode_uses_injected_clock():
    plan = FaultPlan.single("decode_step", mode="delay", times=1, arg=0.7)
    slept = []
    plan.sleep = slept.append
    assert plan.fire("decode_step", payload="x") == "x"
    assert slept == [0.7]


def test_activate_overrides_env_plan(monkeypatch):
    monkeypatch.setenv(faults_mod.ENV_VAR, "plan_validate:raise:1")
    env_plan = faults_mod.get_active()
    assert env_plan is not None and "plan_validate" in env_plan.specs
    assert faults_mod.get_active() is env_plan        # cached per env string
    override = FaultPlan.single("decode_step")
    with activate(override):
        assert faults_mod.get_active() is override
    assert faults_mod.get_active() is env_plan
    monkeypatch.delenv(faults_mod.ENV_VAR)
    assert faults_mod.get_active() is None


def test_retry_with_backoff_bounded_and_clock_injectable():
    calls = {"n": 0}
    slept, retried = [], []

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    out = retry_with_backoff(flaky, retries=2, base_delay_s=0.25,
                             sleep=slept.append,
                             on_retry=lambda a, e: retried.append(a))
    assert out == "ok" and calls["n"] == 3
    assert slept == [0.25, 0.5] and retried == [0, 1]

    with pytest.raises(RuntimeError, match="always"):
        retry_with_backoff(lambda: (_ for _ in ()).throw(RuntimeError("always")),
                           retries=1, sleep=lambda s: None)


# -- calibration ladder --------------------------------------------------------

def test_calibration_measure_retries_then_succeeds():
    sess = Session(fault_plan=FaultPlan.single("calibration_measure", times=1))
    g = build_inception_like(n_blocks=1, width=2)
    with count_measure_calls() as timing:
        table = sess.calibrate(g, _inputs(g))
    assert table is not None and timing["n"] == 1
    stats = sess.cache_stats()
    assert stats["calib_retries"] == 1
    assert stats["calib_degraded_analytic"] == 0
    assert [e.site for e in sess.guard_log.events] == ["calibration_measure"]
    assert sess.guard_log.events[0].action == "retry#1"


def test_calibration_degrades_to_analytic_when_measure_keeps_failing():
    sess = Session(
        fault_plan=FaultPlan.single("calibration_measure", times=-1))
    g = build_inception_like(n_blocks=2, width=3)
    x = jnp.ones((8, 64), jnp.float32)
    with pytest.warns(DegradationWarning, match="measured->analytic"):
        model = sess.compile(g, inputs=_inputs(g))
    assert model.provenance["calibration"] == "analytic (degraded)"
    stats = sess.cache_stats()
    assert stats["calib_degraded_analytic"] == 1
    assert stats["calib_retries"] == sess.config.calib_retries
    # the analytic schedule still computes the right function
    np.testing.assert_allclose(
        np.asarray(model({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)
    degraded = model.explain()["degraded"]
    assert any(d["site"] == "calibration_measure"
               and d["action"] == "measured->analytic" for d in degraded)


def test_calibration_backoff_uses_injected_session_clock():
    sess = Session(
        calib_backoff_s=0.25,
        fault_plan=FaultPlan.single("calibration_measure", times=2))
    delays = []
    sess._sleep = delays.append
    table = sess.calibrate(build_inception_like(n_blocks=1, width=2),
                           {0: jnp.ones((8, 64), jnp.float32)})
    assert table is not None
    assert delays == [0.25, 0.5]                 # doubling, injected clock
    assert sess.cache_stats()["calib_retries"] == 2


def test_disk_write_fault_degrades_to_memory_tier(tmp_path, monkeypatch):
    calib_dir = str(tmp_path / "calib-wf")
    monkeypatch.setenv("REPRO_CALIB_DIR", calib_dir)
    sess = Session(fault_plan=FaultPlan.single("calib_disk_write", times=1))
    g = build_inception_like(n_blocks=1, width=2)
    table = sess.calibrate(g, _inputs(g))
    assert table is not None                      # build survived
    assert sess.cache_stats()["calib_disk_errors"] == 1
    # nothing published, nothing stranded
    if os.path.isdir(calib_dir):
        assert not os.listdir(calib_dir)
    # the memory tier still serves this session
    with count_measure_calls() as timing:
        sess.calibrate(g, _inputs(g))
    assert timing["n"] == 0
    assert sess.cache_stats()["calib_hits"] == 1


def test_corrupt_disk_write_is_survivable_as_a_later_miss():
    """Mid-write corruption publishes an atomically-whole but unparseable
    entry: later sessions treat it as a miss, re-measure, and repair the
    entry in place."""
    g = build_inception_like(n_blocks=1, width=2)
    s1 = Session(fault_plan=FaultPlan.single("calib_disk_write",
                                             mode="corrupt", times=1))
    with count_measure_calls() as timing:
        assert s1.calibrate(g, _inputs(g)) is not None
        assert timing["n"] == 1
        s2 = Session()
        assert s2.calibrate(g, _inputs(g)) is not None
        assert timing["n"] == 2                   # corrupt entry → re-measure
    assert s2.cache_stats()["calib_disk_hits"] == 0
    s3 = Session()
    with count_measure_calls() as timing:
        assert s3.calibrate(g, _inputs(g)) is not None
        assert timing["n"] == 0                   # s2 repaired the entry
    assert s3.cache_stats()["calib_disk_hits"] == 1


def test_disk_read_fault_counts_and_falls_back_to_measure():
    g = build_inception_like(n_blocks=1, width=2)
    Session().calibrate(g, _inputs(g))            # publish a good entry
    sess = Session(fault_plan=FaultPlan.single("calib_disk_read", times=1))
    with count_measure_calls() as timing:
        table = sess.calibrate(g, _inputs(g))
    assert table is not None and timing["n"] == 1
    stats = sess.cache_stats()
    assert stats["calib_disk_errors"] == 1 and stats["calib_disk_hits"] == 0


def test_disk_tier_survives_concurrent_writers_and_corruption(tmp_path):
    d = str(tmp_path / "calib-conc")
    tables = {i: ProfileTable(hw_name="v5e",
                              measured_us=((0, 1.0 + i), (1, 2.0 * i + 1.0)))
              for i in range(8)}
    corrupting = FaultPlan.single("calib_disk_write", mode="corrupt", times=1)

    def write(i):
        _calib_disk_store(("k", i), tables[i], dirpath=d,
                          faults=corrupting if i == 3 else None)

    threads = [threading.Thread(target=write, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every write published atomically — no stranded temp files
    assert not [p for p in os.listdir(d) if p.endswith(".tmp")]
    for i in range(8):
        got = _calib_disk_load(("k", i), dirpath=d)
        if i == 3:
            assert got is None                    # whole but unparseable
        else:
            assert got == tables[i]
    _calib_disk_evict(d, max_entries=3)
    assert len([p for p in os.listdir(d) if p.endswith(".json")]) == 3


# -- capture route ladder ------------------------------------------------------

def test_plan_validate_fault_degrades_to_sequential_schedule():
    sess = Session(fault_plan=FaultPlan.single("plan_validate", times=1))
    g = build_inception_like(n_blocks=2, width=3)
    x = jnp.ones((8, 64), jnp.float32)
    with pytest.warns(DegradationWarning, match="schedule->sequential"):
        model = sess.compile(g)
    assert model.provenance["executable"] == "degraded"
    assert sess.cache_stats()["degraded_routes"] == 1
    assert sess.cache_stats()["exec_entries"] == 0   # degraded → never cached
    np.testing.assert_allclose(
        np.asarray(model({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)
    assert any(d["site"] == "plan_validate"
               for d in model.explain()["degraded"])
    # fault disarmed → next build compiles the real schedule and caches it
    healthy = sess.compile(g)
    assert healthy.provenance["executable"] == "miss"
    assert sess.cache_stats()["exec_entries"] == 1
    assert sess.compile(g).provenance["executable"] == "hit"


def test_kernel_compile_fault_routes_branch_gemm_to_vmap():
    sess = Session(gemm_kernel="pallas",
                   fault_plan=FaultPlan.single("kernel_compile", times=-1))
    g = build_inception_like(n_blocks=2, width=3)
    x = jnp.ones((8, 64), jnp.float32)
    model = sess.compile(g)
    stats = model.executable.program_stats()
    assert stats["n_branch_gemm"] == 0 and stats["n_vmap"] > 0
    assert model.provenance["executable"] == "degraded"
    assert sess.cache_stats()["degraded_routes"] >= 1
    assert sess.cache_stats()["exec_entries"] == 0
    np.testing.assert_allclose(
        np.asarray(model({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)
    assert any(d["action"] == "branch_gemm->vmap"
               for d in model.explain()["degraded"])


def test_grouped_gemm_route_fault_degrades_to_sequential_steps():
    sess = Session(fault_plan=FaultPlan.single("grouped_gemm_route",
                                               times=-1))
    g = build_ragged_graph((8, 24, 16))
    model = sess.compile(g)
    stats = model.executable.program_stats()
    assert stats["n_grouped_gemm"] == 0
    assert model.provenance["executable"] == "degraded"
    inputs = _inputs_for(g)
    got = model(inputs)
    ref = run_sequential_uncompiled(g, inputs,
                                    output_ids=model.executable.output_ids)
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    assert any(d["action"] == "grouped_gemm->sequential"
               for d in model.explain()["degraded"])


def test_kernel_wrappers_fall_back_to_reference_on_injected_launch_failure():
    from repro.kernels.branch_gemm.ops import branch_gemm
    from repro.kernels.branch_gemm.ref import branch_gemm_ref
    from repro.kernels.grouped_gemm.ops import grouped_gemm_parts
    from repro.kernels.grouped_gemm.ref import grouped_gemm_ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 8, 128)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal((2, 128, 128)) * 0.1, jnp.float32)
    with activate(FaultPlan.single("kernel_compile", times=1)):
        with pytest.warns(DegradationWarning, match="einsum reference"):
            out = branch_gemm(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(branch_gemm_ref(x, w)),
                               rtol=1e-5, atol=1e-5)

    xs = [jnp.asarray(rng.standard_normal((m, 128)) * 0.1, jnp.float32)
          for m in (8, 24)]
    with activate(FaultPlan.single("grouped_gemm_route", times=1)):
        with pytest.warns(DegradationWarning, match="einsum reference"):
            outs = grouped_gemm_parts(xs, w)
    for i, (o, x_i) in enumerate(zip(outs, xs)):
        ref = grouped_gemm_ref(x_i, w[i:i + 1], (x_i.shape[0],))
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


# -- serving engine fault isolation --------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    from repro.configs import get_config
    from repro.models import make_model

    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def _run_engine(model, params, fault_plan=None, n_requests=3, max_tokens=4):
    from repro.serving import InferenceEngine, Request

    engine = InferenceEngine(model, params, max_slots=n_requests, max_len=32,
                             fault_plan=fault_plan)
    for rid in range(n_requests):
        engine.submit(Request(rid=rid, prompt=[1 + rid, 2, 3],
                              max_tokens=max_tokens))
    done = {r.rid: r for r in engine.run()}
    return engine, done


def test_engine_poisoned_request_fails_alone(small_model):
    from repro.serving import RequestState

    cfg, model, params = small_model
    _, clean = _run_engine(model, params)
    # corrupt-mode decode_step poisons slot 0's logits on the first decode
    # tick — a poisoned request, co-batched with two healthy ones
    plan = FaultPlan.single("decode_step", mode="corrupt", times=1, arg=0)
    engine, done = _run_engine(model, params, fault_plan=plan)
    assert len(done) == 3
    assert done[0].state is RequestState.FAILED
    assert "non-finite" in done[0].error
    assert engine.fault_stats["failed_requests"] == 1
    for rid in (1, 2):
        assert done[rid].state is RequestState.DONE
        assert done[rid].output == clean[rid].output   # co-batch unaffected


def test_engine_watchdog_falls_back_to_eager_decode(small_model):
    from repro.serving import RequestState

    cfg, model, params = small_model
    _, clean = _run_engine(model, params)
    plan = FaultPlan.single("decode_step", mode="raise", times=1)
    with pytest.warns(DegradationWarning, match="decode watchdog"):
        engine, done = _run_engine(model, params, fault_plan=plan)
    assert engine._use_compiled is False               # latched
    assert engine.fault_stats["watchdog_fallbacks"] == 1
    assert len(done) == 3
    for rid in range(3):
        assert done[rid].state is RequestState.DONE
        assert done[rid].output == clean[rid].output   # eager == jitted


def test_cached_decode_fn_diagnoses_garbage_collected_model():
    import gc

    from repro.configs import get_config
    from repro.models import make_model
    from repro.models.transformer import init_decode_caches
    from repro.serving.engine import _cached_decode_fn

    cfg = get_config("llama3.2-1b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    fn = _cached_decode_fn(model)
    caches = init_decode_caches(cfg, 1, 8)
    del model
    gc.collect()
    with pytest.raises(RuntimeError, match="garbage-collected"):
        fn(params, caches, jnp.zeros((1,), jnp.int32),
           jnp.zeros((1,), jnp.int32))


# -- differential property: single-site fault == fault-free outputs ------------

_GRAPH_SITES = ("kernel_compile", "plan_validate", "calibration_measure",
                "calib_disk_read", "calib_disk_write")


def _check_single_site_fault_preserves_outputs(seed, site):
    rng = np.random.default_rng(seed)
    g = build_inception_like(n_blocks=1 + seed % 3, width=2 + seed % 2,
                             seed=seed)
    x = jnp.asarray(rng.standard_normal((8, 64)) * 0.1, jnp.float32)
    calib_inputs = {n.op_id: x for n in g if n.fn is None}
    ref = run_sequential_uncompiled(g, {"x": x})
    if site == "calib_disk_read":
        # the read site only fires on a populated tier
        Session().calibrate(g, calib_inputs)
    cfg = SessionConfig(gemm_kernel="pallas",
                        load_calibration=(site == "calib_disk_read"),
                        fault_plan=FaultPlan.single(site, times=-1))
    sess = Session(cfg)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DegradationWarning)
        model = sess.compile(g, inputs=calib_inputs)
        got = model({"x": x})
    for a, b in zip(got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    # the degradation is never silent: provenance reports it somewhere
    stats = sess.cache_stats()
    reported = (stats["degraded_routes"] + stats["calib_degraded_analytic"]
                + stats["calib_disk_errors"])
    assert reported >= 1, (site, stats)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10_000), site=st.sampled_from(_GRAPH_SITES))
    def test_any_single_site_fault_matches_fault_free_run(seed, site):
        _check_single_site_fault_preserves_outputs(seed, site)
else:
    @pytest.mark.parametrize("site", _GRAPH_SITES)
    @pytest.mark.parametrize("seed", [0, 1])
    def test_any_single_site_fault_matches_fault_free_run(seed, site):
        _check_single_site_fault_preserves_outputs(seed, site)
