"""Bench regression gate (scripts/check_bench_regression.py): exit codes and
metric matching over synthetic trajectory JSONs."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(REPO, "scripts", "check_bench_regression.py")


def _write(dirpath, scheduler=None, inference=None):
    os.makedirs(dirpath, exist_ok=True)
    if scheduler is not None:
        with open(os.path.join(dirpath, "BENCH_scheduler.json"), "w") as f:
            json.dump(scheduler, f)
    if inference is not None:
        with open(os.path.join(dirpath, "BENCH_inference.json"), "w") as f:
            json.dump(inference, f)


def _run(old, new, *extra):
    return subprocess.run(
        [sys.executable, SCRIPT, "--old", str(old), "--new", str(new), *extra],
        capture_output=True, text=True)


SCHED_OK = {"workloads": [{"workload": "bert", "schedule_ms": 10.0}],
            "overhead": [{"workload": "bert-180L", "schedule_ms": 100.0}]}
INFER_OK = {"workloads": [{
    "workload": "bert", "schedule_ms": 12.0,
    "policies": {"opara": {"makespan_us": 500.0}}}]}


def test_gate_clean_when_unchanged(tmp_path):
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    _write(tmp_path / "new", SCHED_OK, INFER_OK)
    r = _run(tmp_path / "old", tmp_path / "new")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "clean" in r.stdout


def test_gate_fails_on_schedule_ms_regression(tmp_path):
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    bad = json.loads(json.dumps(SCHED_OK))
    bad["workloads"][0]["schedule_ms"] = 13.0  # +30% > 20% gate
    _write(tmp_path / "new", bad, INFER_OK)
    r = _run(tmp_path / "old", tmp_path / "new")
    assert r.returncode == 1
    assert "REGRESSION bert schedule_ms" in r.stdout


def test_gate_fails_on_makespan_regression(tmp_path):
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    bad = json.loads(json.dumps(INFER_OK))
    bad["workloads"][0]["policies"]["opara"]["makespan_us"] = 700.0
    _write(tmp_path / "new", SCHED_OK, bad)
    r = _run(tmp_path / "old", tmp_path / "new")
    assert r.returncode == 1
    assert "REGRESSION bert/opara makespan_us" in r.stdout


def test_gate_tolerates_jitter_below_noise_floor(tmp_path):
    """0.1ms on a 0.3ms metric is >20% relative but under the ms noise
    floor — must not fail the gate."""
    old = {"workloads": [{"workload": "tiny", "schedule_ms": 0.3}]}
    new = {"workloads": [{"workload": "tiny", "schedule_ms": 0.4}]}
    _write(tmp_path / "old", old, INFER_OK)
    _write(tmp_path / "new", new, INFER_OK)
    r = _run(tmp_path / "old", tmp_path / "new")
    assert r.returncode == 0, r.stdout + r.stderr


def test_gate_allows_improvements_and_new_workloads(tmp_path):
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    new = {"workloads": [
        {"workload": "bert", "schedule_ms": 5.0},       # improvement
        {"workload": "brand-new", "schedule_ms": 999.0},  # no baseline
    ]}
    _write(tmp_path / "new", new, INFER_OK)
    r = _run(tmp_path / "old", tmp_path / "new")
    assert r.returncode == 0, r.stdout + r.stderr


def test_gate_threshold_flag(tmp_path):
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    bad = json.loads(json.dumps(SCHED_OK))
    bad["workloads"][0]["schedule_ms"] = 11.5  # +15%
    _write(tmp_path / "new", bad, INFER_OK)
    assert _run(tmp_path / "old", tmp_path / "new").returncode == 0
    assert _run(tmp_path / "old", tmp_path / "new",
                "--threshold", "0.10").returncode == 1


def test_gate_makespan_only_ignores_wallclock(tmp_path):
    """--makespan-only (CI mode): wall-clock ms regressions pass, the
    deterministic makespan metrics still gate."""
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    slow = json.loads(json.dumps(SCHED_OK))
    slow["workloads"][0]["schedule_ms"] = 99.0          # wall-clock blowup
    slow_inf = json.loads(json.dumps(INFER_OK))
    slow_inf["workloads"][0]["schedule_ms"] = 99.0
    _write(tmp_path / "new", slow, slow_inf)
    assert _run(tmp_path / "old", tmp_path / "new").returncode == 1
    assert _run(tmp_path / "old", tmp_path / "new",
                "--makespan-only").returncode == 0
    bad = json.loads(json.dumps(INFER_OK))
    bad["workloads"][0]["policies"]["opara"]["makespan_us"] = 700.0
    _write(tmp_path / "new", slow, bad)
    r = _run(tmp_path / "old", tmp_path / "new", "--makespan-only")
    assert r.returncode == 1
    assert "makespan_us" in r.stdout


def test_gate_multi_run_intersection(tmp_path):
    """With several --new run dirs, only regressions confirmed in EVERY run
    fail — noise flags a different metric per run, a real slowdown repeats."""
    _write(tmp_path / "old", SCHED_OK, INFER_OK)
    # run 1: scheduler bert regresses; run 2: it does not (noise) -> clean
    bad = json.loads(json.dumps(SCHED_OK))
    bad["workloads"][0]["schedule_ms"] = 13.0
    _write(tmp_path / "r1", bad, INFER_OK)
    _write(tmp_path / "r2", SCHED_OK, INFER_OK)
    r = _run(tmp_path / "old", tmp_path / "r1", str(tmp_path / "r2"))
    assert r.returncode == 0, r.stdout + r.stderr
    # the same metric regressed in both runs -> confirmed, gate fails
    _write(tmp_path / "r2", bad, INFER_OK)
    r = _run(tmp_path / "old", tmp_path / "r1", str(tmp_path / "r2"))
    assert r.returncode == 1
    assert "REGRESSION bert schedule_ms" in r.stdout
    # same workload name regressing in DIFFERENT files must not conflate:
    # scheduler-bert in run 1, inference-bert in run 2 -> no intersection
    bad_inf = json.loads(json.dumps(INFER_OK))
    bad_inf["workloads"][0]["schedule_ms"] = 16.0
    _write(tmp_path / "r2", SCHED_OK, bad_inf)
    r = _run(tmp_path / "old", tmp_path / "r1", str(tmp_path / "r2"))
    assert r.returncode == 0, r.stdout + r.stderr


def test_gate_errors_without_baseline(tmp_path):
    _write(tmp_path / "new", SCHED_OK, INFER_OK)
    r = _run(tmp_path / "empty", tmp_path / "new")
    assert r.returncode == 2


def test_gate_skips_non_numeric_fields(tmp_path):
    """Trajectory records carry string provenance (tuned policy names) and
    bool flags next to gated metrics — the gate must skip them explicitly,
    not crash or compare them."""
    doc = {"workloads": [{
        "workload": "bert", "schedule_ms": "not-a-number",
        "policies": {"opara": {"makespan_us": True}},
        "autotune": {"est_makespan_us": "opara"}}]}
    worse = {"workloads": [{
        "workload": "bert", "schedule_ms": "even-worse",
        "policies": {"opara": {"makespan_us": False}},
        "autotune": {"est_makespan_us": "topo"}}]}
    _write(tmp_path / "old", SCHED_OK, doc)
    _write(tmp_path / "new", SCHED_OK, worse)
    r = _run(tmp_path / "old", tmp_path / "new")
    assert r.returncode == 0, r.stdout + r.stderr


def test_gate_autotune_est_makespan_no_envelope(tmp_path):
    """The autotuned row's predicted makespan is deterministic: a regression
    below the 20% wall-clock threshold still fails the gate, and it is
    gated under --makespan-only too."""
    old = json.loads(json.dumps(INFER_OK))
    old["workloads"][0]["autotune"] = {"est_makespan_us": 500.0}
    new = json.loads(json.dumps(INFER_OK))
    new["workloads"][0]["autotune"] = {"est_makespan_us": 510.0}  # +2%
    _write(tmp_path / "old", SCHED_OK, old)
    _write(tmp_path / "new", SCHED_OK, new)
    for extra in ((), ("--makespan-only",)):
        r = _run(tmp_path / "old", tmp_path / "new", *extra)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "bert/autotune est_makespan_us" in r.stdout
    # improvements and sub-rounding jitter pass
    new["workloads"][0]["autotune"]["est_makespan_us"] = 500.005
    _write(tmp_path / "new", SCHED_OK, new)
    assert _run(tmp_path / "old", tmp_path / "new").returncode == 0


def test_gate_refine_est_trajectory_no_envelope(tmp_path):
    """overhead[] / workloads[] est_static_us / est_refined_us (the
    autotune+refine trajectory of BENCH_scheduler.json) are gated with no
    envelope, including under --makespan-only."""
    old = json.loads(json.dumps(SCHED_OK))
    old["overhead"][0].update(est_static_us=1433.1, est_refined_us=1432.4)
    new = json.loads(json.dumps(old))
    new["overhead"][0]["est_refined_us"] = 1433.0   # +0.04%: still fails
    _write(tmp_path / "old", old, INFER_OK)
    _write(tmp_path / "new", new, INFER_OK)
    for extra in ((), ("--makespan-only",)):
        r = _run(tmp_path / "old", tmp_path / "new", *extra)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "est_refined_us" in r.stdout
    _write(tmp_path / "new", old, INFER_OK)
    assert _run(tmp_path / "old", tmp_path / "new").returncode == 0
