"""Exporter granularity: every config arch must emit traced-kernel graphs.

The scheduler can only overlap what the exporter exposes: each layer needs
at least one memory-class stage (transpose copies, softmax, weight-stream
DMAs, scans) AND one compute-class stage (GEMMs above the MXU intensity
floor), or the reported speedups for that arch are fictional (ISSUE 10 /
IOS, arxiv 2011.01302).  These tests pin that property for all assigned
archs, plus the cost-accounting invariants of the decomposition:

* per-stage nodes carry their OWN vmem/occupancy — the folded cost of the
  old monolithic attention node equals the field-wise sum (traffic/FLOPs)
  and max (working set) of the stages that replaced it;
* cost-only exports split FF projections into weight-stream + activation
  GEMM pairs, while payload-backed exports stay single-input executable.
"""
import re

import pytest

from repro import configs
from repro.core.profiler import (
    IntensityClass,
    ModelProfiler,
    attention_cost,
    gemm_cost,
)
from repro.models.opgraph_export import (
    _sum_costs,
    build_encdec_opgraph,
    build_lm_opgraph,
)

_LAYER_RE = re.compile(r"^(L\d+|e\d+|d\d+)\.")


def _build_cost_only(arch: str, n_layers: int = 2, seq: int = 32):
    cfg = configs.get_config(arch)
    if cfg.n_dec_layers:
        return build_encdec_opgraph(cfg, 1, seq, n_layers=n_layers)
    return build_lm_opgraph(cfg, 1, seq, n_layers=n_layers)


@pytest.mark.parametrize("arch", configs.list_archs())
def test_every_layer_exports_both_intensity_classes(arch):
    g = _build_cost_only(arch)
    prof = ModelProfiler()
    per_layer: dict[str, set[IntensityClass]] = {}
    for n in g:
        m = _LAYER_RE.match(n.name)
        if m is None or n.cost is None:
            continue
        per_layer.setdefault(m.group(1), set()).add(prof.classify(n))
    assert per_layer, f"{arch}: no per-layer nodes exported"
    for layer, classes in per_layer.items():
        assert IntensityClass.COMPUTE in classes, (
            f"{arch} {layer}: no compute-class stage — nothing to overlap "
            f"memory ops against")
        assert IntensityClass.MEMORY in classes, (
            f"{arch} {layer}: no memory-class stage — nothing to hide "
            f"behind the GEMMs")


@pytest.mark.parametrize("arch", configs.list_archs())
def test_attention_is_decomposed_not_monolithic(arch):
    """No arch may fall back to a single fused attention node: the
    score/context GEMMs and the mask+softmax stage must be separate
    schedulable ops (rwkv has no attention; its scan plays that role)."""
    g = _build_cost_only(arch)
    names = {n.name for n in g}
    if arch.startswith("rwkv"):
        assert any(n.endswith(".wkv_scan") for n in names)
        return
    assert not any(n.endswith(".attn") for n in names), (
        f"{arch}: monolithic attention node survived the refactor")
    for stage in ("scores", "scale_mask", "softmax", "ctx"):
        assert any(n.endswith(f".{stage}") for n in names), (
            f"{arch}: missing decomposed stage {stage!r}")


def test_folded_cost_equals_sum_of_decomposed_stages():
    """Satellite: the stage costs of one decomposed attention block fold
    back (via ``_sum_costs``) into exactly the old monolithic accounting —
    traffic and FLOPs add, working set is the widest phase — and the
    score/context GEMM pair alone carries the full 4·b·h·s·t·d attention
    FLOPs."""
    cfg = configs.get_config("qwen2-0.5b")
    b, s = 1, 32
    nh, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = build_lm_opgraph(cfg, b, s, n_layers=1)
    stages = {n.name.split(".", 1)[1]: n.cost for n in g
              if n.name.startswith("L0.")
              and n.name.split(".", 1)[1] in
              ("qt", "kt", "vt", "scores", "scale_mask", "softmax",
               "ctx", "ctxt")}
    assert len(stages) == 8

    ref = attention_cost(b, s, s, nh, hd, kvh)
    assert stages["scores"].flops + stages["ctx"].flops == ref.flops

    folded = _sum_costs(*stages.values())
    assert folded.flops == sum(c.flops for c in stages.values())
    assert folded.bytes_read == sum(c.bytes_read for c in stages.values())
    assert folded.bytes_written == sum(c.bytes_written for c in stages.values())
    # per-stage working sets are genuinely per-stage, not one folded bound
    vmems = {c.vmem_bytes for c in stages.values()}
    assert len(vmems) > 1, "stages share one folded vmem bound"
    assert folded.vmem_bytes == max(vmems)
    for c in stages.values():
        assert c.vmem_bytes <= folded.vmem_bytes

    # and the profiler sees both classes within the attention block alone
    prof = ModelProfiler()
    classes = {prof.classify(n) for n in g
               if n.name.startswith("L0.") and n.cost is not None}
    assert classes == {IntensityClass.COMPUTE, IntensityClass.MEMORY}


def test_scores_gemm_clears_compute_intensity_floor():
    """The decomposed score GEMM must classify as compute-bound at bench
    sequence lengths — if it fell below the MXU floor the decomposition
    would *remove* overlap opportunities instead of adding them."""
    cfg = configs.get_config("qwen2-0.5b")
    b, s, hd = 1, 32, cfg.head_dim
    c = gemm_cost(b * cfg.n_heads * s, hd, s)
    prof = ModelProfiler()
    assert c.arithmetic_intensity() >= 16.0
    assert prof.hw.machine_balance > 0


def test_cost_only_exports_stream_ff_weights_payload_graphs_do_not():
    """Cost-only graphs price FF weight traffic as explicit prefetchable
    DMA ops rooted at the graph input; payload-backed graphs must instead
    stay fully executable with a single INPUT node (weights in consts)."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.models import make_model

    g = build_lm_opgraph(configs.get_config("qwen2-0.5b"), 1, 32, n_layers=2)
    streams = [n for n in g if n.name.endswith("_wstream")]
    assert len(streams) == 6          # gate/up/down × 2 layers
    root = next(n for n in g if n.name == "tokens")
    for n in streams:
        assert n.inputs == (root.op_id,), "stream must root at the input"
        assert n.cost.flops == 0 and n.cost.bytes_read > 0

    cfg = dataclasses.replace(configs.get_config("qwen2-0.5b", smoke=True),
                              dtype=jnp.float32)
    params = make_model(cfg).init(jax.random.key(0))
    gp = build_lm_opgraph(cfg, 1, 4, params=params, n_layers=2)
    assert not any(n.name.endswith("_wstream") for n in gp)
    assert sum(1 for n in gp if n.fn is None) == 1
