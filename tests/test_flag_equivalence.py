"""Every §Perf optimization flag must preserve numerics exactly
(the hillclimb trades memory/collectives, never correctness)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import make_model


@pytest.fixture
def clean_env():
    keys = ["REPRO_CACHE_UPDATE", "REPRO_CHUNKED_CE", "REPRO_CAUSAL_SKIP",
            "REPRO_WINDOW_SLICE_DECODE", "REPRO_KV_QUANT"]
    saved = {k: os.environ.pop(k, None) for k in keys}
    yield
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v


def test_chunked_ce_matches(clean_env):
    cfg = get_config("llama3.2-1b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size,
             "labels": jnp.ones((2, 16), jnp.int32)}
    l0, _ = m.loss(params, batch)
    os.environ["REPRO_CHUNKED_CE"] = "1"
    l1, _ = m.loss(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-3


def test_scatter_cache_matches(clean_env):
    cfg = get_config("qwen2-0.5b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    logits, caches = m.prefill(params, {"tokens": jnp.ones((2, 8), jnp.int32)},
                               cache_len=16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    d0, _ = m.decode(params, tok, caches, pos)
    os.environ["REPRO_CACHE_UPDATE"] = "scatter"
    d1, _ = m.decode(params, tok, caches, pos)
    np.testing.assert_allclose(np.asarray(d0, np.float32),
                               np.asarray(d1, np.float32), rtol=1e-3, atol=1e-3)


def test_mla_scatter_cache_matches(clean_env):
    cfg = get_config("deepseek-v3-671b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    logits, caches = m.prefill(params, {"tokens": jnp.ones((2, 8), jnp.int32)},
                               cache_len=16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    d0, _ = m.decode(params, tok, caches, pos)
    os.environ["REPRO_CACHE_UPDATE"] = "scatter"
    d1, _ = m.decode(params, tok, caches, pos)
    np.testing.assert_allclose(np.asarray(d0, np.float32),
                               np.asarray(d1, np.float32), rtol=1e-3, atol=1e-3)


def test_causal_skip_matches(clean_env):
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 96, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 96, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 96, 2, 16)), jnp.float32)
    a0 = chunked_attention(q, k, v, causal=True, window=24,
                           q_chunk=16, kv_chunk=16)
    os.environ["REPRO_CAUSAL_SKIP"] = "1"
    a1 = chunked_attention(q, k, v, causal=True, window=24,
                           q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(a1),
                               rtol=1e-5, atol=1e-5)


def test_kv_quant_decode_close(clean_env):
    """O8 int8 latent cache: decode logits within 5% of full precision."""
    cfg = get_config("deepseek-v3-671b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    inp = {"tokens": (jnp.arange(16).reshape(2, 8) * 7) % cfg.vocab_size}
    logits, caches = m.prefill(params, inp, cache_len=16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 8, jnp.int32)
    d0, _ = m.decode(params, tok, caches, pos)

    os.environ["REPRO_KV_QUANT"] = "1"
    quant = []
    for ck, rk in caches:
        scale = jnp.maximum(jnp.max(jnp.abs(ck), axis=-1), 1e-6) / 127.0
        q = jnp.clip(jnp.round(ck / scale[..., None]), -127, 127).astype(jnp.int8)
        quant.append((q, scale.astype(jnp.float16), rk))
    d1, new_cache = m.decode(params, tok, quant, pos)
    assert new_cache[0][0].dtype == jnp.int8
    rel = float(jnp.max(jnp.abs(d0.astype(jnp.float32) - d1.astype(jnp.float32)))
                ) / (float(jnp.max(jnp.abs(d0))) + 1e-9)
    assert rel < 0.05, rel


def test_window_slice_decode_matches(clean_env):
    cfg = get_config("hymba-1.5b", smoke=True)
    m = make_model(cfg)
    params = m.init(jax.random.key(0))
    inp = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size}
    logits, caches = m.prefill(params, inp, cache_len=64 + cfg.meta_tokens)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((2,), 16, jnp.int32)
    d0, _ = m.decode(params, tok, caches, pos)
    os.environ["REPRO_WINDOW_SLICE_DECODE"] = "1"
    d1, _ = m.decode(params, tok, caches, pos)
    np.testing.assert_allclose(np.asarray(d0, np.float32),
                               np.asarray(d1, np.float32), rtol=2e-2, atol=2e-2)
