"""Graph Capturer: wave fusion + single-program execution correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_waves,
    capture,
    compile_plan,
    fusion_stats,
    run_sequential_uncompiled,
    schedule,
)

from conftest import build_inception_like


def test_capture_matches_sequential():
    g = build_inception_like(n_blocks=3, width=4, with_payloads=True)
    plan = schedule(g, "opara", "opara")
    exe = compile_plan(plan)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 64)), jnp.float32)
    got = exe({"x": x})
    ref = run_sequential_uncompiled(g, {"x": x})
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=1e-5, atol=1e-5)


def test_capture_matches_for_all_policies():
    g = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=3)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 64)), jnp.float32)
    ref = run_sequential_uncompiled(g, {"x": x})
    for alloc in ("opara", "nimble", "sequential"):
        for order in ("opara", "topo", "depth_first"):
            plan = schedule(g, alloc, order)
            got = compile_plan(plan)({"x": x})
            np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{alloc}/{order}")


def test_horizontal_fusion_reduces_kernels():
    g = build_inception_like(n_blocks=3, width=4)
    plan = schedule(g, "opara", "opara")
    stats = fusion_stats(plan.waves)
    # 4 same-signature branch GEMMs per block must fuse into one kernel
    assert stats["fusion_ratio"] > 1.5
    assert stats["n_kernels_after_fusion"] < stats["n_ops"]


def test_sequential_policy_single_wave_width():
    g = build_inception_like(n_blocks=2, width=4)
    plan = schedule(g, "sequential", "topo")
    assert plan.waves.n_waves == len(g)  # one op per wave: no parallelism


def test_wave_independence():
    g = build_inception_like(n_blocks=3, width=4)
    plan = schedule(g, "opara", "opara")
    pos = {}
    for w in plan.waves.waves:
        for op in w.op_ids:
            pos[op] = w.index
    for node in g:
        for p in node.inputs:
            assert pos[p] < pos[node.op_id], "producer must be in earlier wave"
