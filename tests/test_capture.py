"""Graph Capturer: wave fusion + single-program execution correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    build_waves,
    capture,
    compile_plan,
    fusion_stats,
    run_sequential_uncompiled,
    schedule,
)

from conftest import build_inception_like


def test_capture_matches_sequential():
    g = build_inception_like(n_blocks=3, width=4, with_payloads=True)
    plan = schedule(g, "opara", "opara")
    exe = compile_plan(plan)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((8, 64)), jnp.float32)
    got = exe({"x": x})
    ref = run_sequential_uncompiled(g, {"x": x})
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=1e-5, atol=1e-5)


def test_capture_matches_for_all_policies():
    g = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=3)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 64)), jnp.float32)
    ref = run_sequential_uncompiled(g, {"x": x})
    for alloc in ("opara", "nimble", "sequential"):
        for order in ("opara", "topo", "depth_first"):
            plan = schedule(g, alloc, order)
            got = compile_plan(plan)({"x": x})
            np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{alloc}/{order}")


def test_horizontal_fusion_reduces_kernels():
    g = build_inception_like(n_blocks=3, width=4)
    plan = schedule(g, "opara", "opara")
    stats = fusion_stats(plan.waves)
    # 4 same-signature branch GEMMs per block must fuse into one kernel
    assert stats["fusion_ratio"] > 1.5
    assert stats["n_kernels_after_fusion"] < stats["n_ops"]


def test_sequential_policy_single_wave_width():
    g = build_inception_like(n_blocks=2, width=4)
    plan = schedule(g, "sequential", "topo")
    assert plan.waves.n_waves == len(g)  # one op per wave: no parallelism


def test_wave_independence():
    g = build_inception_like(n_blocks=3, width=4)
    plan = schedule(g, "opara", "opara")
    pos = {}
    for w in plan.waves.waves:
        for op in w.op_ids:
            pos[op] = w.index
    for node in g:
        for p in node.inputs:
            assert pos[p] < pos[node.op_id], "producer must be in earlier wave"


def test_mixed_dtype_consts_do_not_stack():
    """jnp.stack over mixed-dtype branch weights silently promotes, so a
    fused group would return different dtypes than unfused execution — the
    capturer must refuse to stack and run the branches as singles."""
    from repro.core.graph import OpGraph, OpKind
    from repro.core.profiler import gemm_cost

    g = OpGraph("mixed")
    x = g.add("x", OpKind.INPUT, out_shape=(8, 32))
    rng = np.random.default_rng(0)
    for i, dt in enumerate((jnp.float32, jnp.float16)):
        w = jnp.asarray(rng.standard_normal((32, 32)) * 0.1, dt)
        g.add(f"gemm{i}", OpKind.GEMM, [x], fn=lambda a, w: a @ w,
              cost=gemm_cost(8, 32, 32, 4), fuse_sig=("gemm", 32, 32),
              consts=(w,), payload="matmul")
    exe = compile_plan(schedule(g, "opara", "opara"))
    stats = exe.program_stats()
    assert stats["n_vmap"] == stats["n_branch_gemm"] == 0, stats
    assert stats["n_single"] == 2
    x_val = jnp.ones((8, 32), jnp.float32)
    got = exe({"x": x_val})
    ref = run_sequential_uncompiled(g, {"x": x_val}, output_ids=exe.output_ids)
    for a, b in zip(got, ref):
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-3)


def test_unconsumed_non_output_slot_freed_at_producer():
    """An op whose result nothing consumes (and which is not a program
    output) must be freed right after its producing step, not pinned for
    the whole program."""
    from repro.core import capture
    from repro.core.fusion import build_waves
    from repro.core.graph import OpGraph, OpKind
    from repro.core.launch_order import ORDER_POLICIES
    from repro.core.stream_alloc import allocate_streams

    g = OpGraph("dangling")
    x = g.add("x", OpKind.INPUT, out_shape=(4, 4))
    dead = g.add("dead", OpKind.ELEMENTWISE, [x], fn=lambda a: a * 2)
    live = g.add("live", OpKind.ELEMENTWISE, [x], fn=lambda a: a + 1)
    out = g.add("out", OpKind.ELEMENTWISE, [live], fn=lambda a: a - 1)
    plan_streams = allocate_streams(g)
    order = ORDER_POLICIES["topo"](g, None)
    waves = build_waves(g, plan_streams, order)
    exe = capture(g, waves, output_ids=[out])
    slot_of = {op: k for k, op in enumerate(g.nodes)}
    producing = next(s for s in exe.steps if s.op_ids == (dead,))
    assert slot_of[dead] in producing.free_slots, (
        "unconsumed non-output result must die at its producing step")
    got = exe({"x": jnp.ones((4, 4), jnp.float32)})
    np.testing.assert_allclose(np.asarray(got[0]),
                               np.ones((4, 4), np.float32))


def test_bind_rejects_unknown_input_names():
    g = build_inception_like(n_blocks=1, width=2, with_payloads=True)
    exe = compile_plan(schedule(g, "opara", "opara"))
    x = jnp.ones((8, 64), jnp.float32)
    with pytest.raises(KeyError, match="unrecognized"):
        exe({"x": x, "xx": x})      # typo'd extra name
    with pytest.raises(KeyError, match="missing"):
        exe({})


def test_run_sequential_honors_output_ids():
    g = build_inception_like(n_blocks=2, width=2, with_payloads=True)
    x = jnp.ones((8, 64), jnp.float32)
    mid = [n.op_id for n in g if n.name == "b0_sum"]
    full = run_sequential_uncompiled(g, {"x": x})
    sel = run_sequential_uncompiled(g, {"x": x}, output_ids=mid)
    assert len(full) == len(g.leaves()) and len(sel) == 1
    assert sel[0].shape == (8, 64)


def test_pick_gemm_route_estimate_matches_kernel_tiles():
    """The interpret-mode grid estimate must count the grid the branch_gemm
    wrapper actually launches (shared select_tiles), M included — the old
    hardcoded k//512 divisor undercounted non-dividing K and ignored M."""
    from repro.core.capture import _VMAP, _BRANCH_GEMM, _pick_gemm_route
    from repro.kernels.branch_gemm.ops import select_tiles

    # K=640 halves down to bk=128 → 5 K-tiles; the old k//512 estimate saw 1
    w = jnp.zeros((640, 128), jnp.float32)
    bm, bf, bk = select_tiles(8, 640, 128)
    assert (640 // bk) == 5
    assert _pick_gemm_route(w, 16, "auto", m=8) == _VMAP       # 16·5 > 64
    assert _pick_gemm_route(w, 8, "auto", m=8) == _BRANCH_GEMM  # 8·5 ≤ 64

    # M scales the grid too: 4 branches fit at m=512, not at m=4096
    w2 = jnp.zeros((128, 128), jnp.float32)
    assert _pick_gemm_route(w2, 4, "auto", m=512) == _BRANCH_GEMM
    assert _pick_gemm_route(w2, 4, "auto", m=4096) == _VMAP
    # explicit kernel choice still wins
    assert _pick_gemm_route(w, 64, "pallas", m=4096) == _BRANCH_GEMM
    assert _pick_gemm_route(w2, 2, "vmap", m=8) == _VMAP
