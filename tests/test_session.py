"""`opara.Session`: config-scoped compilation, cache isolation, explain(),
and the deprecation behavior of the legacy ``repro.core.api`` shims."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompiledModel,
    Session,
    SessionConfig,
    SimConfig,
    default_session,
    reset_default_session,
    run_sequential_uncompiled,
)
from repro.core import api as opara
from repro.core.profiler import HardwareSpec

from conftest import build_inception_like, count_measure_calls


def _inputs(g):
    return {n.op_id: jnp.ones((8, 64), jnp.float32) for n in g if n.fn is None}


# -- SessionConfig -------------------------------------------------------------

def test_session_config_is_frozen_hashable_and_validating():
    cfg = SessionConfig(autotune=True, sim_cfg=SimConfig(resource_cap=1e6))
    assert hash(cfg) == hash(dataclasses.replace(cfg))
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.autotune = False
    with pytest.raises(ValueError):
        SessionConfig(alloc_policy="bogus")
    with pytest.raises(ValueError):
        SessionConfig(order_policy="bogus")
    with pytest.raises(ValueError):
        SessionConfig(gemm_kernel="bogus")
    with pytest.raises(ValueError):
        SessionConfig(cache_size=0)


def test_session_kwarg_overrides_build_config():
    base = SessionConfig(autotune=True)
    s = Session(base, order_policy="topo")
    assert s.config.autotune and s.config.order_policy == "topo"
    assert base.order_policy == "opara"          # original untouched
    assert Session(hw=HardwareSpec(name="x")).config.hw.name == "x"


# -- compile() / CompiledModel -------------------------------------------------

def test_compile_returns_working_model_with_cold_then_warm_provenance():
    sess = Session()
    g = build_inception_like(n_blocks=3, width=4)
    x = jnp.ones((8, 64), jnp.float32)

    cold = sess.compile(g)
    assert isinstance(cold, CompiledModel)
    assert cold.provenance == {"calibration": "off", "plan": "miss",
                               "executable": "miss"}
    np.testing.assert_allclose(
        np.asarray(cold({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)

    warm = sess.compile(g)
    assert warm.provenance == {"calibration": "off", "plan": "hit",
                               "executable": "hit"}
    assert warm.executable is cold.executable
    assert warm.plan is cold.plan


def test_explain_reports_stages_and_cache_provenance():
    sess = Session()
    g = build_inception_like(n_blocks=2, width=3)
    cold = sess.compile(g, inputs=_inputs(g))
    rep = cold.explain()
    assert rep["cache"] == {"calibration": "measured", "plan": "miss",
                            "executable": "miss"}
    assert rep["graph"]["n_ops"] == len(g)
    assert rep["config"]["hw"] == sess.config.hw.name
    for stage in ("calibrate", "plan", "compile", "total",
                  "alloc", "order", "profile", "waves", "autotune"):
        assert stage in rep["stages_ms"], stage
    assert rep["stages_ms"]["total"] >= rep["stages_ms"]["plan"]
    assert rep["schedule"]["n_streams"] >= 1

    warm = sess.compile(g, inputs=_inputs(g)).explain()
    assert warm["cache"] == {"calibration": "memory", "plan": "hit",
                             "executable": "hit"}

    # a fresh session sharing only the disk tier: calibration rehydrates
    # from disk, plan/executable recompile
    sess2 = Session()
    disk = sess2.compile(g, inputs=_inputs(g)).explain()
    assert disk["cache"]["calibration"] == "disk"
    assert disk["cache"]["plan"] == "miss"


def test_compiled_model_stats_match_plan():
    sess = Session()
    g = build_inception_like(n_blocks=2, width=3)
    m = sess.compile(g)
    assert m.stats == m.plan.stats()


def test_autotuned_session_compile_and_explain():
    sess = Session(autotune=True,
                   sim_cfg=SimConfig(resource_cap=24e6, head_of_line=True))
    g = build_inception_like(n_blocks=3, width=4)
    m = sess.compile(g)
    assert m.plan.n_candidates >= 2
    rep = m.explain()
    assert rep["config"]["autotune"] is True
    # the tuned policies are reported, not the config defaults' sentinel
    assert rep["config"]["alloc_policy"] in ("opara", "nimble", "sequential")
    x = jnp.ones((8, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(m({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)
    assert sess.compile(g).provenance["plan"] == "hit"


# -- isolation -----------------------------------------------------------------

def test_sessions_with_different_configs_never_share_entries():
    """Two sessions (different configs) compile the same graph: neither sees
    the other's plan/exec/calib entries."""
    g = build_inception_like(n_blocks=2, width=3)
    s1 = Session()
    s2 = Session(order_policy="topo")

    s1.compile(g, inputs=_inputs(g))
    assert s1.cache_stats()["plan_entries"] == 1
    assert s2.cache_stats() == {k: 0 for k in s2.cache_stats()}

    m2 = s2.compile(g)
    assert m2.provenance["plan"] == "miss", "s2 must not see s1's plan"
    assert s2.cache_stats()["plan_hits"] == 0
    assert s1.cache_stats()["plan_entries"] == 1
    assert s2.cache_stats()["plan_entries"] == 1


def test_sessions_with_equal_configs_still_isolated():
    """Isolation is per-instance, not per-config-value."""
    g = build_inception_like(n_blocks=2, width=3)
    s1, s2 = Session(), Session()
    s1.optimize(g)
    exe2 = s2.optimize(g)
    assert s2.cache_stats()["exec_misses"] == 1
    assert s2.cache_stats()["exec_hits"] == 0
    assert exe2 is not s1.optimize(g)


def test_clear_caches_on_one_session_leaves_the_other_warm():
    g = build_inception_like(n_blocks=2, width=3)
    s1, s2 = Session(), Session()
    s1.compile(g, inputs=_inputs(g))
    s2.compile(g, inputs=_inputs(g))
    s1.clear_caches()
    assert s1.cache_stats()["plan_entries"] == 0

    warm = s2.compile(g, inputs=_inputs(g))
    assert warm.provenance == {"calibration": "memory", "plan": "hit",
                               "executable": "hit"}
    # and s1 really is cold again (modulo the shared disk tier)
    cold = s1.compile(g, inputs=_inputs(g))
    assert cold.provenance["plan"] == "miss"
    assert cold.provenance["calibration"] == "disk"


def test_session_calibration_does_not_retime_across_sessions_only_via_disk():
    """Memory tiers are isolated: a second session re-times unless the disk
    tier (shared by construction when calib_dir matches) serves it."""
    g = build_inception_like(n_blocks=1, width=2)
    s1 = Session(load_calibration=False)
    s2 = Session(load_calibration=False)
    with count_measure_calls() as calls:
        s1.calibrate(g, _inputs(g), repeats=1)
        s2.calibrate(g, _inputs(g), repeats=1)
    assert calls["n"] == 2, "isolated memory tiers must both measure"


# -- default session + legacy shims --------------------------------------------

def test_default_session_backs_api_shims():
    g = build_inception_like(n_blocks=2, width=3)
    p = opara.plan(g)
    assert default_session().cache_stats()["plan_misses"] == 1
    assert default_session().plan(g) is p
    opara.clear_caches()
    assert opara.cache_stats()["plan_entries"] == 0


def test_reset_default_session_swaps_state():
    g = build_inception_like(n_blocks=2, width=3)
    opara.plan(g)
    old = default_session()
    new = reset_default_session()
    assert new is default_session() and new is not old
    assert new.cache_stats()["plan_entries"] == 0


def test_api_shims_warn_on_superseded_kwargs_only():
    import warnings
    g = build_inception_like(n_blocks=2, width=3)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # plain calls: no warning
        opara.plan(g)
        opara.optimize(g)
        opara.plan(g, measured_inputs=_inputs(g))   # per-call data: silent
        opara.calibrate(g, _inputs(g), repeats=1)
    with pytest.warns(DeprecationWarning, match="alloc_policy"):
        opara.plan(g, alloc_policy="nimble")
    with pytest.warns(DeprecationWarning, match="weights_key"):
        opara.optimize(g, weights_key="content")
    with pytest.warns(DeprecationWarning, match="hw"):
        opara.calibrate(g, _inputs(g), hw=default_session().config.hw,
                        repeats=1)


def test_api_shim_kwargs_still_delegate_correctly():
    """The deprecated spellings keep working — distinct config → distinct
    cache entries in the default session, same values → shared entry."""
    g = build_inception_like(n_blocks=2, width=3)
    with pytest.warns(DeprecationWarning):
        p_topo = opara.plan(g, order_policy="topo")
    p_def = opara.plan(g)
    assert p_topo.order_policy == "topo" and p_def.order_policy == "opara"
    assert default_session().cache_stats()["plan_misses"] == 2
    with pytest.warns(DeprecationWarning):
        assert opara.plan(g, order_policy="topo") is p_topo


def test_session_cache_size_bounds_plan_entries():
    sess = Session(cache_size=2)
    for blocks in (1, 2, 3, 4):
        sess.plan(build_inception_like(n_blocks=blocks, width=2,
                                       with_payloads=False))
    assert sess.cache_stats()["plan_entries"] == 2
