"""Per-kernel shape/dtype sweeps asserting allclose against the ref oracle
(interpret=True on CPU; same code path targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.default_rng(0)


def _rand(shape, dtype, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype)


def _assert_close(a, b, rtol, atol):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=rtol, atol=atol)


# ------------------------------------------------------------- branch_gemm
@pytest.mark.parametrize("n,m,k,f", [(1, 8, 128, 128), (3, 16, 256, 128),
                                     (4, 32, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_branch_gemm(n, m, k, f, dtype):
    from repro.kernels.branch_gemm.ops import branch_gemm
    from repro.kernels.branch_gemm.ref import branch_gemm_ref
    x = _rand((n, m, k), dtype, 0.1)
    w = _rand((n, k, f), dtype, 0.1)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    _assert_close(branch_gemm(x, w), branch_gemm_ref(x, w), tol, tol)


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("s,t,h,kvh,d", [(128, 128, 4, 2, 32),
                                         (256, 256, 4, 4, 64),
                                         (128, 256, 8, 2, 16)])
@pytest.mark.parametrize("window", [0, 48])
def test_flash_attention(s, t, h, kvh, d, window):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = _rand((2, h, s, d), jnp.float32)
    k = _rand((2, kvh, t, d), jnp.float32)
    v = _rand((2, kvh, t, d), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    _assert_close(got, ref, 2e-3, 2e-3)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    q = _rand((1, 4, 128, 32), jnp.bfloat16)
    k = _rand((1, 2, 128, 32), jnp.bfloat16)
    v = _rand((1, 2, 128, 32), jnp.bfloat16)
    _assert_close(flash_attention(q, k, v, bq=64, bk=64),
                  flash_attention_ref(q, k, v), 3e-2, 3e-2)


# -------------------------------------------------------- decode_attention
@pytest.mark.parametrize("t,h,kvh,d", [(256, 4, 2, 32), (512, 8, 8, 64),
                                       (384, 4, 1, 16)])
def test_decode_attention(t, h, kvh, d):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    q = _rand((2, h, d), jnp.float32)
    k = _rand((2, kvh, t, d), jnp.float32)
    v = _rand((2, kvh, t, d), jnp.float32)
    valid = jnp.asarray(np.arange(t)[None] <= np.array([t // 3, t - 1])[:, None])
    got = decode_attention(q, k, v, valid, bk=128)
    ref = decode_attention_ref(q, k, v, valid)
    _assert_close(got, ref, 2e-3, 2e-3)


# ------------------------------------------------------------------ rwkv6
@pytest.mark.parametrize("t,ct", [(32, 8), (64, 16), (24, 8)])
def test_rwkv6(t, ct):
    from repro.kernels.rwkv6.ops import rwkv6
    from repro.kernels.rwkv6.ref import rwkv6_ref
    b, h, k = 2, 2, 16
    r, kk, vv = [_rand((b, h, t, k), jnp.float32) for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.8, 0.999, (b, h, t, k)), jnp.float32)
    u = _rand((h, k), jnp.float32)
    s0 = _rand((b, h, k, k), jnp.float32)
    o1, s1 = rwkv6(r, kk, vv, w, u, s0, ct=ct)
    o2, s2 = rwkv6_ref(r, kk, vv, w, u, s0)
    _assert_close(o1, o2, 1e-4, 1e-4)
    _assert_close(s1, s2, 1e-4, 1e-4)


# --------------------------------------------------------------- moe_gemm
@pytest.mark.parametrize("e,c,d,f", [(2, 8, 128, 128), (4, 16, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gemm(e, c, d, f, dtype):
    from repro.kernels.moe_gemm.ops import moe_mlp
    from repro.kernels.moe_gemm.ref import moe_mlp_ref
    buf = _rand((e, c, d), dtype, 0.1)
    g = _rand((e, d, f), dtype, 0.05)
    u = _rand((e, d, f), dtype, 0.05)
    dn = _rand((e, f, d), dtype, 0.05)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    _assert_close(moe_mlp(buf, g, u, dn, bc=8, bf=128),
                  moe_mlp_ref(buf, g, u, dn), tol, tol)


# ---------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape", [(16, 128), (2, 8, 256), (32, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = _rand(shape, dtype)
    sc = _rand(shape[-1:], dtype)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    _assert_close(rmsnorm(x, sc), rmsnorm_ref(x, sc), tol, tol)


# --------------------------------------- chunked attention (jnp flash twin)
@pytest.mark.parametrize("s,window", [(96, None), (96, 24), (100, 17)])
def test_chunked_attention_matches_naive(s, window):
    from repro.models.attention import _sdpa, causal_window_mask, chunked_attention
    b, h, kvh, d, dv = 2, 4, 2, 16, 24
    q = _rand((b, s, h, d), jnp.float32)
    k = _rand((b, s, kvh, d), jnp.float32)
    v = _rand((b, s, kvh, dv), jnp.float32)
    pos = jnp.arange(s)
    ref = _sdpa(q, k, v, causal_window_mask(pos, pos, window))
    got = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=32, kv_chunk=16)
    _assert_close(got, ref, 1e-5, 1e-5)


def test_chunked_attention_grads_match_naive():
    from repro.models.attention import _sdpa, causal_window_mask, chunked_attention
    b, s, h, kvh, d = 2, 64, 4, 2, 16
    q = _rand((b, s, h, d), jnp.float32)
    k = _rand((b, s, kvh, d), jnp.float32)
    v = _rand((b, s, kvh, d), jnp.float32)
    pos = jnp.arange(s)
    w = jnp.asarray(rng.standard_normal((d,)), jnp.float32)

    def loss_naive(q, k, v):
        return (_sdpa(q, k, v, causal_window_mask(pos, pos, None)) * w).sum()

    def loss_chunk(q, k, v):
        return (chunked_attention(q, k, v, q_chunk=16, kv_chunk=32) * w).sum()

    g1 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        _assert_close(a, b_, 1e-4, 1e-4)
