"""End-to-end system behaviour: the paper's pipeline on real model graphs,
training convergence, and the train→checkpoint→restart loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compare_policies, compile_plan, schedule
from repro.models import make_model
from repro.models.opgraph_export import build_lm_opgraph


def test_opara_pipeline_on_real_arch_graphs():
    """Stream-alloc + launch-order + waves on every arch's exported DAG."""
    from repro.configs import list_archs
    for arch in list_archs():
        cfg = get_config(arch)
        if cfg.family == "encdec":
            continue  # exporter covers decoder-only topologies
        g = build_lm_opgraph(cfg, batch=1, seq=128, n_layers=2)
        plan = schedule(g, "opara", "opara")
        stats = plan.stats()
        assert stats["n_streams"] >= 1
        assert stats["n_kernels_after_fusion"] <= stats["n_ops"]


def test_opara_beats_sequential_on_branchy_archs():
    """Fig. 5a analogue on exported graphs: archs with parallel operators
    (MoE fan-out, hybrid attn∥ssm, rwkv 5-proj) must show simulated speedup
    over the sequential CUDA-Graph baseline."""
    for arch in ("kimi-k2-1t-a32b", "hymba-1.5b", "rwkv6-1.6b"):
        cfg = get_config(arch)
        g = build_lm_opgraph(cfg, batch=1, seq=256, n_layers=2)
        res = compare_policies(g)
        speedup = res["opara"]["makespan_us"]
        seq = res["cuda_graph_sequential"]["makespan_us"]
        assert speedup < seq * 1.05, (arch, res)


def test_captured_graph_executes_real_dense_model():
    """Capture an executable graph for a dense smoke model and check the
    fused program reproduces the layer math."""
    cfg = get_config("qwen2-0.5b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    g = build_lm_opgraph(cfg, batch=2, seq=8, params=params)
    plan = schedule(g, "opara", "opara")
    exe = compile_plan(plan)
    tokens = jnp.zeros((2, 8), jnp.int32)
    outs = exe({"tokens": tokens})
    logits = outs[-1]
    assert logits.shape == (2, 8, cfg.vocab_size)
    assert bool(jnp.isfinite(jnp.asarray(logits)).all())


def test_training_reduces_loss():
    """A couple hundred steps on a tiny model must reduce loss materially."""
    from repro.launch.train import train
    res = train("llama3.2-1b", smoke=True, steps=120, batch=8, seq=32,
                ckpt_dir=None, resume=False, log_every=1000)
    assert res["last_loss"] < res["first_loss"] - 0.3, res


def test_train_checkpoint_restart_consistency(tmp_path):
    """Crash/restart: resuming from step k must give the same loss curve as
    an uninterrupted run (determinism of data + optimizer)."""
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    train("qwen2-0.5b", smoke=True, steps=12, batch=4, seq=16,
          ckpt_dir=d, resume=False, ckpt_every=6, log_every=1000)
    r2 = train("qwen2-0.5b", smoke=True, steps=18, batch=4, seq=16,
               ckpt_dir=d, resume=True, ckpt_every=6, log_every=1000)
    r_full = train("qwen2-0.5b", smoke=True, steps=18, batch=4, seq=16,
                   ckpt_dir=None, resume=False, log_every=1000)
    assert abs(r2["last_loss"] - r_full["last_loss"]) < 5e-3, (r2, r_full)
