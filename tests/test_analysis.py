"""Roofline machinery unit tests: HLO collective parser, analytic cost model,
enc-dec opgraph export."""
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, get_config
from repro.launch.analytic_cost import cell_cost
from repro.launch.hlo_analysis import parse_collectives
from repro.launch.roofline import model_flops, roofline_terms


HLO_SAMPLE = """
ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128] parameter(0)
  %ar = f32[16,128] all-reduce(%p0), replica_groups={}
  %ag = bf16[32,128]{1,0} all-gather(%p0), dimensions={0}
  ROOT %out = f32[16,128] add(%ar, %ar)
}
%body.while (x: f32[8]) -> f32[8] {
  %x = f32[8] parameter(0)
  %rs = f32[8] reduce-scatter(%x), dimensions={0}
}
"""


def test_parse_collectives_counts_and_bytes():
    stats = parse_collectives(HLO_SAMPLE, while_multiplier=4.0)
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.count_by_kind["reduce-scatter"] == 1
    # all-reduce weighted 2×: 16·128·4·2; all-gather 1×: 32·128·2
    assert stats.bytes_by_kind["all-reduce"] == 16 * 128 * 4 * 2
    assert stats.bytes_by_kind["all-gather"] == 32 * 128 * 2
    # the reduce-scatter sits in a while body → ×4
    assert stats.bytes_by_kind["reduce-scatter"] == 8 * 4 * 4


def test_analytic_cost_scales_with_tokens():
    cfg = get_config("llama3.2-1b")
    train = cell_cost(cfg, SHAPES["train_4k"])
    prefill = cell_cost(cfg, SHAPES["prefill_32k"])
    decode = cell_cost(cfg, SHAPES["decode_32k"])
    assert train.flops > prefill.flops > decode.flops
    # train tokens = prefill tokens; train factor 8 (remat) vs 2 → ~4×
    ratio = train.detail["matmul_flops"] / prefill.detail["matmul_flops"]
    assert 3.5 < ratio < 4.5
    # decode is memory-heavy: bytes/flops far above the machine balance
    assert decode.bytes * 240 > decode.flops


def test_model_flops_moe_uses_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    dense_equal = model_flops(kimi, SHAPES["train_4k"])
    assert dense_equal == 6.0 * kimi.n_active_params() * 256 * 4096


def test_roofline_terms_dominance():
    t = roofline_terms(flops=1e18, bytes_=1e12, coll_bytes_per_dev=1e9, chips=256)
    assert t["dominant"] == "compute_s"
    assert t["roofline_fraction"] == 1.0
    t = roofline_terms(flops=1e15, bytes_=1e13, coll_bytes_per_dev=1e9, chips=256)
    assert t["dominant"] == "memory_s"
    assert 0 < t["roofline_fraction"] < 1


def test_encdec_opgraph_exports_and_schedules():
    from repro.core import schedule
    from repro.models.opgraph_export import build_encdec_opgraph
    cfg = get_config("whisper-medium")
    g = build_encdec_opgraph(cfg, batch=1, dec_seq=64, n_layers=2)
    plan = schedule(g, "opara", "opara")
    stats = plan.stats()
    # encoder chain ∥ decoder embedding + cross-KV branches → multiple streams
    assert stats["n_streams"] >= 4
    assert stats["n_kernels_after_fusion"] < stats["n_ops"]


def test_cache_bytes_kv_quant_halves(monkeypatch):
    monkeypatch.setenv("REPRO_KV_QUANT", "1")
    cfg = get_config("deepseek-v3-671b")
    quant = cell_cost(cfg, SHAPES["decode_32k"]).detail["cache_bytes"]
    monkeypatch.setenv("REPRO_KV_QUANT", "0")
    full = cell_cost(cfg, SHAPES["decode_32k"]).detail["cache_bytes"]
    assert quant < 0.6 * full
