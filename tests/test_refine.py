"""IOS-style iterative schedule refinement (``scheduler.refine``) and the
staged-repack autotune fix.

Pins:
  (a) the root-cause staging bug — on large graphs (>NIMBLE_ALLOC_OP_LIMIT
      ops) the repack leg must rank a repacked candidate PER ORDER, so an
      order that loses the plain sweep but wins after repacking is found
      (``repacked: true``);
  (b) ``repack_options=(True,)`` ranks every order instead of falling back
      to an arbitrary first order;
  (c) refinement invariants on random DAGs: never worse than the autotune
      seed, dependency / resource-cap / permutation validity after every
      accepted move, budget + plateau termination;
  (d) ``SweepState.fork`` delta re-estimation semantics;
  (e) session wiring: ``SessionConfig.refine`` validation, plan-cache
      keying, ``CompiledModel.explain()`` provenance.
"""
import dataclasses

import numpy as np
import pytest

from conftest import random_dag

from repro.core import (RefineConfig, Session, SessionConfig, autotune,
                        refine, schedule)
from repro.core.fusion import repack_waves
from repro.core.graph import OpCost, OpGraph, OpKind
from repro.core.launch_order import ORDER_POLICIES, validate_order
from repro.core.profiler import ModelProfiler, V5E
from repro.core.scheduler import (ALLOC_POLICIES,
                                  AUTOTUNE_ORDER_POLICIES_LARGE,
                                  NIMBLE_ALLOC_OP_LIMIT, _sweep, op_tables)
from repro.core.simulator import (SimConfig, SweepState, estimate_makespan,
                                  sweep_extend)

UNIT = OpCost.OCCUPANCY_UNIT
GAP_SIM = SimConfig(resource_cap=float(UNIT), sync_us=0.5, launch_us=1.0,
                    head_of_line=True)


def _comp_cost(us_scale: float, occ: float) -> OpCost:
    """Compute-class op: duration scales with ``us_scale``, resource demand
    is ``occ`` of the occupancy unit (= the test's resource cap)."""
    return OpCost(flops=us_scale * 1e9, bytes_read=1e3, bytes_written=1e3,
                  vmem_bytes=1e3, occupancy=occ)


def staged_gap_graph(n_hol_units: int = 125, n_tail_units: int = 2,
                     m_shorts: int = 16) -> OpGraph:
    """Adversarial >512-op graph where the best REPACKED order is not the
    best PLAIN order.

    Head-of-line section (repeated ``n_hol_units`` times): a fan-out of
    {small a, huge b, small c} whose insertion order (a, b, c) blocks c
    behind b under head-of-line dispatch — the plain topo sweep pays one
    small-op latency per unit, Alg. 2's demand-ascending order (a, c, b)
    does not, and the repacker emits identical waves {a,c},{b} for both
    orders (order-neutral).

    Tail section: one long op carrying the LARGEST demand next to many
    shorts — demand-ascending order launches the long op last (tail
    penalty), insertion order launches it first and overlaps it.  The
    penalty survives repacking because the packer draws in launch-order
    position within a class.

    Net: topo loses the plain sweep (head-of-line section dominates) but
    wins the repack leg (head-of-line section neutralized, tail section
    decides) — exactly the interaction the staged autotune path missed when
    it repacked only the plain-sweep winner.
    """
    g = OpGraph("staged-gap")
    prev = g.add("src", OpKind.ELEMENTWISE, [], cost=_comp_cost(0.01, 0.01))
    for u in range(n_hol_units):
        a = g.add(f"a{u}", OpKind.GEMM, [prev], cost=_comp_cost(0.5, 0.10))
        b = g.add(f"b{u}", OpKind.GEMM, [prev], cost=_comp_cost(2.0, 0.95))
        c = g.add(f"c{u}", OpKind.GEMM, [prev], cost=_comp_cost(0.5, 0.11))
        prev = g.add(f"bar_a{u}", OpKind.ELEMENTWISE, [a, b, c],
                     cost=_comp_cost(0.01, 0.01))
    for u in range(n_tail_units):
        tail = [g.add(f"T{u}", OpKind.GEMM, [prev],
                      cost=_comp_cost(3.0, 0.46))]
        tail += [g.add(f"s{u}_{i}", OpKind.GEMM, [prev],
                       cost=_comp_cost(0.2, 0.45)) for i in range(m_shorts)]
        prev = g.add(f"bar_b{u}", OpKind.ELEMENTWISE, tail,
                     cost=_comp_cost(0.01, 0.01))
    g.validate()
    return g


def _exhaustive_candidates(g, cfg):
    """{(order_policy, repacked): est} over the large-graph candidate space,
    computed independently of autotune's staging."""
    profiles = ModelProfiler(V5E).profile(g)
    splan = ALLOC_POLICIES["opara"](g)
    tables = op_tables(g, splan, profiles)
    ests = {}
    for op_ in AUTOTUNE_ORDER_POLICIES_LARGE:
        order = ORDER_POLICIES[op_](g, profiles)
        ests[(op_, False)] = _sweep(tables, order, cfg)
        ws = repack_waves(g, splan, order, profiles, cfg=cfg, group=False)
        ests[(op_, True)] = _sweep(tables, ws.flat_order(), cfg)
    return ests


# =========================================================================
# (a) staged-repack regression
# =========================================================================

def test_staged_gap_graph_is_adversarial():
    """The construction actually exhibits the gap the fix closes: best plain
    order != best repacked order, and a repacked non-plain-winner is the
    global optimum."""
    g = staged_gap_graph()
    assert len(g) > NIMBLE_ALLOC_OP_LIMIT
    ests = _exhaustive_candidates(g, GAP_SIM)
    plain = {k[0]: v for k, v in ests.items() if not k[1]}
    repacked = {k[0]: v for k, v in ests.items() if k[1]}
    best_plain = min(plain, key=plain.get)
    best_repacked = min(repacked, key=repacked.get)
    assert best_plain != best_repacked
    assert repacked[best_repacked] < min(plain.values())


def test_autotune_finds_repacked_nonwinner_order_on_large_graph():
    """Regression: the staged path used to repack only the plain-sweep
    winner, returning ``repacked: false`` (or the winner's inferior repack)
    whenever a repacked non-winner order was the true optimum."""
    g = staged_gap_graph()
    ests = _exhaustive_candidates(g, GAP_SIM)
    tuned = autotune(g, cfg=GAP_SIM)
    assert tuned.repacked
    best_key = min(ests, key=ests.get)
    assert (tuned.order_policy, tuned.repacked) == best_key
    assert tuned.est_makespan_us == pytest.approx(ests[best_key])
    # the est the old staging would have reported (best plain, repacked or
    # not) is strictly worse
    plain_winner = min((k for k in ests if not k[1]), key=ests.get)[0]
    assert tuned.est_makespan_us < ests[(plain_winner, True)]
    assert tuned.est_makespan_us < ests[(plain_winner, False)]


# =========================================================================
# (b) repack_options=(True,) ranks all orders
# =========================================================================

def test_repack_only_option_ranks_every_order():
    g = staged_gap_graph()
    ests = _exhaustive_candidates(g, GAP_SIM)
    tuned = autotune(g, cfg=GAP_SIM, repack_options=(True,))
    repacked = {k[0]: v for k, v in ests.items() if k[1]}
    assert tuned.repacked
    assert tuned.est_makespan_us == pytest.approx(min(repacked.values()))
    assert tuned.order_policy == min(repacked, key=repacked.get)


# =========================================================================
# (c) refinement invariants
# =========================================================================

def _check_plan_valid(g, plan, cfg):
    validate_order(g, plan.order)
    assert plan.waves.flat_order() == plan.order
    all_ops = [op for w in plan.waves.waves for op in w.op_ids]
    assert sorted(all_ops) == sorted(n.op_id for n in g)
    nodes = g.nodes
    for w in plan.waves.waves:
        members = set(w.op_ids)
        # no intra-wave dependency edges
        for op in w.op_ids:
            assert not (set(nodes[op].inputs) & members)
        # wave demand under the cap (singletons exempt, as in the packer)
        used = sum(plan.profiles[o].cost.resource_demand() for o in w.op_ids)
        assert used <= cfg.resource_cap * (1 + 1e-9) or len(w.op_ids) == 1
        # fusion groups partition the wave
        grouped = [op for grp in w.fusion_groups for op in grp]
        assert sorted(grouped) == sorted(w.op_ids)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_refine_never_worse_and_valid_on_random_dags(seed):
    cap = 16e6 if seed % 2 else 48e6
    cfg = SimConfig(resource_cap=cap, sync_us=0.5, launch_us=1.0,
                    head_of_line=True)
    g = random_dag(np.random.default_rng(seed), 120)
    seeded = autotune(g, cfg=cfg)
    refined = refine(seeded, cfg=cfg,
                     refine_cfg=RefineConfig(min_budget=1 << 16))
    assert refined.est_makespan_us <= seeded.est_makespan_us + 1e-9
    _check_plan_valid(g, refined, cfg)
    # the reported est is the cost model's value for the refined order
    assert refined.est_makespan_us == pytest.approx(
        estimate_makespan(g, refined.stream_plan, refined.order,
                          refined.profiles, cfg))
    # bookkeeping: refined <=> at least one accepted move and a positive delta
    if refined.refined:
        assert refined.refine_iters > 0
        assert refined.refine_delta_us > 0
        assert refined.est_makespan_us < seeded.est_makespan_us
    else:
        assert refined.refine_iters == 0
        assert refined.order == seeded.order


def test_refine_strictly_improves_a_refinable_plan():
    """On the ragged-MoE fan-out the boundary walk finds real moves — the
    acceptance-style strict improvement, deterministic under the fixed
    cost model."""
    from benchmarks.bench_inference import BENCH_SIM
    from benchmarks.workloads import moe_ragged_workload
    g = moe_ragged_workload()
    seeded = autotune(g, cfg=BENCH_SIM)
    refined = refine(seeded, cfg=BENCH_SIM)
    assert refined.refined
    assert refined.est_makespan_us < seeded.est_makespan_us
    assert refined.refine_delta_us == pytest.approx(
        seeded.est_makespan_us - refined.est_makespan_us)
    _check_plan_valid(g, refined, BENCH_SIM)
    # stats() surfaces the provenance (floats, for the bench writers)
    s = refined.stats()
    assert s["refined"] == 1.0
    assert s["refine_iters"] == float(refined.refine_iters)
    assert s["est_makespan_us"] == pytest.approx(refined.est_makespan_us)


def test_refine_stale_sibling_regression():
    """Accepting a candidate at a boundary invalidates its sibling
    proposals; applying one used to corrupt the op multiset.  A generous
    budget drives many accepts — the result must stay a permutation."""
    from benchmarks.bench_inference import BENCH_SIM
    from benchmarks.workloads import moe_ragged_workload
    g = moe_ragged_workload()
    seeded = autotune(g, cfg=BENCH_SIM)
    refined = refine(seeded, cfg=BENCH_SIM,
                     refine_cfg=RefineConfig(budget_factor=64.0,
                                             min_budget=1 << 18,
                                             plateau=256, max_rounds=6))
    _check_plan_valid(g, refined, BENCH_SIM)


def test_refine_respects_tiny_budget_and_terminates():
    cfg = SimConfig(resource_cap=32e6, head_of_line=True)
    g = random_dag(np.random.default_rng(7), 200)
    seeded = autotune(g, cfg=cfg)
    rcfg = RefineConfig(budget_factor=0.001, min_budget=0)
    refined = refine(seeded, cfg=cfg, refine_cfg=rcfg)
    # no budget for any candidate: the seed comes back untouched (with
    # bookkeeping attached), never a worse or invalid plan
    assert refined.est_makespan_us <= seeded.est_makespan_us + 1e-9
    _check_plan_valid(g, refined, cfg)


def test_refine_is_deterministic():
    from benchmarks.bench_inference import BENCH_SIM
    from benchmarks.workloads import moe_ragged_workload
    g = moe_ragged_workload()
    a = autotune(g, cfg=BENCH_SIM, refine=True)
    b = autotune(g, cfg=BENCH_SIM, refine=True)
    assert a.order == b.order
    assert a.est_makespan_us == b.est_makespan_us
    assert a.refine_iters == b.refine_iters


def test_refine_config_validation():
    with pytest.raises(ValueError):
        RefineConfig(budget_factor=0)
    with pytest.raises(ValueError):
        RefineConfig(plateau=0)
    with pytest.raises(ValueError):
        RefineConfig(rebalance=((0.0, None),))
    with pytest.raises(ValueError):
        RefineConfig(rebalance=((0.75, 0),))
    with pytest.raises(TypeError):
        autotune(staged_gap_graph(2, 0), cfg=GAP_SIM, refine="yes")


# =========================================================================
# (d) SweepState.fork delta re-estimation
# =========================================================================

def test_sweep_state_fork_matches_full_sweep():
    cfg = SimConfig(resource_cap=24e6, sync_us=0.5, launch_us=1.0,
                    head_of_line=True)
    g = random_dag(np.random.default_rng(11), 60)
    profiles = ModelProfiler(V5E).profile(g)
    splan = ALLOC_POLICIES["opara"](g)
    tables = op_tables(g, splan, profiles)
    order = ORDER_POLICIES["opara"](g, profiles)
    full = _sweep(tables, order, cfg)
    # checkpoint mid-order, fork, finish on the fork: same makespan
    st = SweepState(len(g))
    sweep_extend(tables, order[:30], cfg, st)
    fork = st.fork()
    sweep_extend(tables, order[30:], cfg, fork)
    assert fork.makespan == pytest.approx(full)
    # the parent's scalar state is untouched by the fork's progress
    assert st.makespan < fork.makespan
    assert len(st.active) <= len(g)
    # a second fork from the same checkpoint reproduces the result (entries
    # in the shared end array are rewritten before any read)
    fork2 = st.fork()
    sweep_extend(tables, order[30:], cfg, fork2)
    assert fork2.makespan == pytest.approx(full)


# =========================================================================
# (e) session wiring
# =========================================================================

def test_session_config_refine_validation():
    with pytest.raises(ValueError, match="autotune"):
        SessionConfig(refine=True)
    with pytest.raises(TypeError):
        SessionConfig(autotune=True, refine="always")
    SessionConfig(autotune=True, refine=RefineConfig())   # fine


def test_plan_cache_keys_by_refine_config():
    from repro.core.session import _plan_key
    g = staged_gap_graph(4, 1, 4)
    base = SessionConfig(autotune=True)
    on = SessionConfig(autotune=True, refine=True)
    explicit = SessionConfig(autotune=True, refine=RefineConfig())
    custom = SessionConfig(autotune=True,
                           refine=RefineConfig(budget_factor=8.0))
    assert _plan_key(g, base) != _plan_key(g, on)
    assert _plan_key(g, on) == _plan_key(g, explicit)
    assert _plan_key(g, custom) != _plan_key(g, on)


def test_session_refine_plan_and_explain():
    from benchmarks.bench_inference import BENCH_SIM
    from benchmarks.workloads import moe_ragged_workload
    g = moe_ragged_workload()
    sess = Session(SessionConfig(autotune=True, refine=True,
                                 sim_cfg=BENCH_SIM))
    m = sess.compile(g)
    assert m.plan.refined
    ex = m.explain()
    assert ex["config"]["refine"] is True
    assert ex["schedule"]["refined"] is True
    assert ex["schedule"]["refine_iters"] == m.plan.refine_iters
    assert ex["schedule"]["refine_delta_us"] == pytest.approx(
        m.plan.refine_delta_us)
    assert ex["stages_ms"]["refine"] == m.plan.refine_ms
    # warm path: the refined plan is a cache hit, not a re-search
    before = sess.cache_stats()["plan_hits"]
    p2 = sess.plan(g)
    assert sess.cache_stats()["plan_hits"] == before + 1
    assert p2.order == m.plan.order
