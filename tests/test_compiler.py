"""Capture-time program compiler: pre-lowered executor, const hoisting,
branch-GEMM routing, topology cache and the compiled-plan cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Session
from repro.core import (
    OpGraph,
    OpKind,
    capture,
    compile_plan,
    run_sequential_uncompiled,
    schedule,
)
from repro.core.profiler import ModelProfiler

from conftest import build_inception_like


@pytest.fixture
def sess():
    return Session()


# -- executor correctness on real model graphs --------------------------------

def test_compiled_executor_matches_sequential_on_model_graph(sess):
    """Captured outputs match the uncompiled sequential reference on a real
    opgraph_export model graph with fusion groups present."""
    from repro.configs import get_config
    from repro.models import make_model
    from repro.models.opgraph_export import build_lm_opgraph

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    g = build_lm_opgraph(cfg, batch=2, seq=8, params=params, n_layers=2)

    exe = sess.optimize(g)
    # fusion groups must actually be exercised (stacked steps present)
    stats = exe.program_stats()
    assert stats["n_vmap"] + stats["n_branch_gemm"] >= 1, stats

    tokens = jnp.zeros((2, 8), jnp.int32)
    got = exe({"tokens": tokens})
    ref = run_sequential_uncompiled(g, {"tokens": tokens})
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        # smoke models run in bfloat16: stacked vs per-op GEMMs may differ
        # by one bf16 ulp; f32 graphs must match tightly.
        tol = 1e-2 if jnp.asarray(a).dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)


def test_branch_gemm_routing_agrees_with_vmap_path():
    """The Pallas fused-GEMM route and the generic vmap route are the same
    function (tileable shapes: d=128 → the kernel path actually runs)."""
    g = build_inception_like(n_blocks=3, width=4, d=128, tokens=8,
                             with_payloads=True, seed=7)
    plan = schedule(g, "opara", "opara")
    exe_pallas = compile_plan(plan, gemm_kernel="pallas")
    exe_vmap = compile_plan(plan, gemm_kernel="vmap")

    assert exe_pallas.program_stats()["n_branch_gemm"] >= 1
    assert exe_vmap.program_stats()["n_branch_gemm"] == 0

    x = jnp.asarray(np.random.default_rng(5).standard_normal((8, 128)),
                    jnp.float32)
    got_p = exe_pallas({"x": x})
    got_v = exe_vmap({"x": x})
    ref = run_sequential_uncompiled(g, {"x": x})
    np.testing.assert_allclose(np.asarray(got_p[0]), np.asarray(got_v[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_p[0]), np.asarray(ref[0]),
                               rtol=1e-5, atol=1e-5)


def test_consts_hoisted_and_stacked_once_at_capture():
    """Stacked groups hold device arrays stacked at capture time (leading
    dim == group width) — nothing is re-stacked inside the trace."""
    g = build_inception_like(n_blocks=2, width=4, with_payloads=True)
    exe = compile_plan(schedule(g, "opara", "opara"))
    stacked = [s for s in exe.steps if len(s.op_ids) > 1]
    assert stacked, "expected at least one fused group"
    for s in stacked:
        for c in s.consts:
            assert isinstance(c, jax.Array)
            assert c.shape[0] == len(s.op_ids)


def test_slot_env_frees_dead_intermediates():
    """Last-use analysis marks intermediates dead; outputs stay correct."""
    g = build_inception_like(n_blocks=3, width=4, with_payloads=True)
    exe = compile_plan(schedule(g, "opara", "opara"))
    freed = {s for step in exe.steps for s in step.free_slots}
    assert freed, "expected dead intermediates to be freed"
    # output slots are never freed
    slot_of = {op: k for k, op in enumerate(g.nodes)}
    assert not freed & {slot_of[o] for o in exe.output_ids}
    x = jnp.ones((8, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(exe({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)


# -- compiled-plan cache -------------------------------------------------------

def test_plan_cache_hit_returns_identical_executable(sess):
    g = build_inception_like(n_blocks=2, width=3, with_payloads=True)
    e1 = sess.optimize(g)
    e2 = sess.optimize(g)
    assert e1 is e2
    stats = sess.cache_stats()
    assert stats["exec_hits"] == 1 and stats["exec_misses"] == 1
    assert stats["plan_hits"] == 1 and stats["plan_misses"] == 1


def test_second_schedule_does_zero_reprofiling(monkeypatch, sess):
    calls = {"profile": 0}
    orig = ModelProfiler.profile

    def counting(self, graph):
        calls["profile"] += 1
        return orig(self, graph)

    monkeypatch.setattr(ModelProfiler, "profile", counting)
    g = build_inception_like(n_blocks=2, width=3, with_payloads=True)
    sess.plan(g)
    assert calls["profile"] == 1
    sess.plan(g)
    assert calls["profile"] == 1, "cache hit must not re-profile"


def test_plan_cache_rebinds_structurally_equal_graph(sess):
    """Two separately-built graphs with the same structure but different
    weights share the schedule, NOT the executable — each output matches
    its own weights."""
    g1 = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=1)
    g2 = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=2)
    p1 = sess.plan(g1)
    p2 = sess.plan(g2)
    assert sess.cache_stats()["plan_hits"] == 1
    assert p2.graph is g2 and p1.graph is g1
    assert p1.order == p2.order

    e1, e2 = sess.optimize(g1), sess.optimize(g2)
    assert e1 is not e2, "different weights must not share an executable"
    x = jnp.ones((8, 64), jnp.float32)
    for g, e in ((g1, e1), (g2, e2)):
        np.testing.assert_allclose(
            np.asarray(e({"x": x})[0]),
            np.asarray(run_sequential_uncompiled(g, {"x": x})[0]),
            rtol=1e-5, atol=1e-5)


def test_graph_mutation_changes_signature():
    from repro.core import graph_signature
    g = build_inception_like(n_blocks=2, width=3, with_payloads=False)
    sig1 = graph_signature(g)
    g.add("extra", OpKind.ELEMENTWISE, [0])
    assert graph_signature(g) != sig1


def test_content_weights_key_reuses_executable_on_reload():
    """Checkpoint-reload scenario: rebuilding the same model recreates
    identical weight ARRAYS (new objects, same bytes).  The default identity
    fingerprint misses; ``weights_key="content"`` reuses the executable."""
    g1 = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=5)
    g2 = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=5)

    content = Session(weights_key="content")
    e1 = content.optimize(g1)
    e2 = content.optimize(g2)
    assert e1 is e2, "identical weight content must share the executable"
    assert content.cache_stats()["exec_hits"] == 1

    # identity mode on the same pair: arrays are distinct objects → miss
    identity = Session()
    i1 = identity.optimize(g1)
    i2 = identity.optimize(g2)
    assert i1 is not i2

    # different weight values must NOT collide in content mode
    g3 = build_inception_like(n_blocks=2, width=3, with_payloads=True, seed=6)
    e3 = content.optimize(g3)
    assert e3 is not e1
    # and the shared executable computes with the weights it closed over
    x = jnp.ones((8, 64), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(e2({"x": x})[0]),
        np.asarray(run_sequential_uncompiled(g1, {"x": x})[0]),
        rtol=1e-5, atol=1e-5)


def test_weights_key_rejects_unknown_mode():
    from repro.core import SessionConfig
    with pytest.raises(ValueError):
        SessionConfig(weights_key="values")
    g = build_inception_like(n_blocks=1, width=2, with_payloads=True)
    with pytest.raises(ValueError), pytest.warns(DeprecationWarning):
        import repro.core.api as opara
        opara.optimize(g, weights_key="values")


# -- topology cache ------------------------------------------------------------

def test_topology_cache_invalidated_by_add():
    g = OpGraph("t")
    a = g.add("a", OpKind.INPUT)
    b = g.add("b", OpKind.GEMM, [a])
    assert g.topological_order() == [a, b]
    assert g.leaves() == [b]
    c = g.add("c", OpKind.GEMM, [b])
    assert g.topological_order() == [a, b, c]
    assert g.leaves() == [c]
    assert g.unique_successors_map()[b] == [c]


def test_topology_queries_are_consistent_with_recompute():
    from conftest import random_dag
    rng = np.random.default_rng(0)
    g = random_dag(rng, 60)
    order = g.topological_order()
    pos = {i: k for k, i in enumerate(order)}
    for node in g:
        for p in node.inputs:
            assert pos[p] < pos[node.op_id]
    indeg = g.indegree_map()
    assert indeg == {i: len(set(n.inputs)) for i, n in g.nodes.items()}
    # indegree_map must hand out a private copy (schedulers decrement it)
    indeg[order[0]] = 999
    assert g.indegree_map()[order[0]] != 999
