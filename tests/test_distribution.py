"""Distribution layer tests that need >1 device: run in subprocesses with
xla_force_host_platform_device_count (the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, n_devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_param_sharding_rules_on_debug_mesh():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_debug_mesh
        from repro.parallel.sharding import param_shardings
        from repro.configs import get_config
        from repro.models import Model

        mesh = make_debug_mesh(2, 4)
        cfg = get_config("llama3.2-1b", smoke=True)
        shapes = Model(cfg).init_shapes()
        sh = param_shardings(mesh, shapes)
        leaves = jax.tree_util.tree_leaves(sh)
        assert all(hasattr(l, "spec") for l in leaves)
        specs = {str(l.spec) for l in leaves}
        assert any("model" in s for s in specs), specs   # TP applied
        assert any("data" in s for s in specs), specs    # FSDP applied
        print("OK", len(leaves), "params sharded")
    """)
    assert "OK" in out


def test_sharded_train_step_runs_on_debug_mesh():
    """A REAL sharded train step executes on an 8-device host mesh and
    matches the single-device loss."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.parallel.sharding import (activation_rules, batch_specs,
                                             param_shardings)
        from repro.utils import logical_axis_rules
        from repro.configs import get_config, SHAPES
        from repro.configs.base import ShapeCell
        from repro.models import Model

        cfg = get_config("llama3.2-1b", smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_loss, _ = model.loss(params, batch)

        mesh = make_debug_mesh(2, 4)
        cell = ShapeCell("dbg", 16, 8, "train")
        rules = activation_rules(mesh, cell)
        psh = param_shardings(mesh, jax.eval_shape(lambda: params))
        params_s = jax.tree_util.tree_map(jax.device_put, params, psh)
        with mesh, logical_axis_rules(rules, mesh):
            loss, _ = jax.jit(lambda p, b: model.loss(p, b))(params_s, batch)
        np.testing.assert_allclose(float(loss), float(ref_loss), rtol=2e-2)
        print("OK sharded loss", float(loss))
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_serial():
    """GPipe stage hand-off over a 4-stage mesh equals serial layer apply."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_debug_mesh
        from repro.parallel.pipeline import pipeline_apply, split_microbatches

        mesh = jax.make_mesh((4,), ("pod",))
        n_stages, layers_per_stage, d = 4, 2, 16
        key = jax.random.key(0)
        w = jax.random.normal(key, (n_stages, layers_per_stage, d, d)) * 0.3

        def layer_fn(p_l, h):
            return jnp.tanh(h @ p_l)

        x = jax.random.normal(jax.random.key(1), (8, 4, d))  # [n_micro, mb, d]

        # serial reference
        ref = x
        for s in range(n_stages):
            for l in range(layers_per_stage):
                ref = layer_fn(w[s, l], ref)

        got = pipeline_apply(layer_fn, w, x, mesh, axis="pod")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("OK pipeline matches serial")
    """, n_devices=4)
    assert "OK" in out


def test_collective_matmul_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.collectives import collective_matmul
        from repro.parallel.compat import shard_map

        mesh = jax.make_mesh((4,), ("model",))
        m, k, n = 8, 32, 16
        x = jax.random.normal(jax.random.key(0), (m, k))
        w = jax.random.normal(jax.random.key(1), (k, n)) * 0.1
        ref = x @ w

        def f(x_sh, w_rep):
            return collective_matmul(x_sh, w_rep, "model")

        out = shard_map(f, mesh=mesh, in_specs=(P(None, "model"), P()),
                        out_specs=P())(x, w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("OK collective matmul")
    """, n_devices=4)
    assert "OK" in out


def test_quantized_psum_approximates_sum():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.collectives import quantized_psum
        from repro.parallel.compat import shard_map

        mesh = jax.make_mesh((4,), ("data",))
        g = jax.random.normal(jax.random.key(0), (4, 64))

        def f(g_sh):
            return quantized_psum(g_sh[0], "data")

        out = shard_map(f, mesh=mesh, in_specs=(P("data"),),
                        out_specs=P())(g)
        ref = np.asarray(g).sum(0)
        err = np.abs(np.asarray(out) - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 0.05, err
        print("OK quantized psum err", err)
    """, n_devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_production_cell_multipod():
    """The REAL dry-run path: one cell on the 2×16×16 = 512-chip mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2-0.5b",
         "--shape", "decode_32k", "--multi-pod"],
        capture_output=True, text=True, timeout=560, env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    assert ": OK" in res.stdout
