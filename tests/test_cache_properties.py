"""Property tests for the per-session LRU plan/executable/calibration caches.

Each property runs under ``hypothesis`` when it is installed and falls back
to deterministic seeded cases otherwise (tier-1 images without hypothesis
still get coverage).  The LRU model check drives the real ``_lru_get`` /
``_lru_put`` primitives against a reference implementation; the rest
exercise the public :class:`repro.core.Session` surface (signature
invalidation on ``add``, ``clear_caches`` zeroing ``cache_stats``,
calibration keying/eviction via ``SessionConfig.cache_size``).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OpGraph, OpKind, Session, SessionConfig
from repro.core import calibration_key, graph_signature
from repro.core.session import _lru_get, _lru_put
from repro.core.profiler import ProfileTable

from conftest import build_inception_like

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback below
    HAVE_HYPOTHESIS = False


# -- LRU model check -----------------------------------------------------------

def _reference_lru(ops, capacity):
    """Reference semantics: dict + recency list, evict least-recent on put."""
    store, recency = {}, []

    def touch(k):
        if k in recency:
            recency.remove(k)
        recency.append(k)

    out = []
    for op, key, val in ops:
        if op == "put":
            store[key] = val
            touch(key)
            while len(store) > capacity:
                victim = recency.pop(0)
                del store[victim]
        else:
            if key in store:
                touch(key)
                out.append(store[key])
            else:
                out.append(None)
    return store, out


def _check_lru_matches_model(ops, capacity):
    from collections import OrderedDict
    cache = OrderedDict()
    got = []
    for op, key, val in ops:
        if op == "put":
            _lru_put(cache, key, val, max_entries=capacity)
        else:
            got.append(_lru_get(cache, key))
    ref_store, ref_gets = _reference_lru(ops, capacity)
    assert dict(cache) == ref_store
    assert got == ref_gets
    assert len(cache) <= capacity


def _ops_from_seed(seed, n=60, n_keys=8):
    rng = np.random.default_rng(seed)
    return [("put" if rng.random() < 0.5 else "get",
             (int(rng.integers(n_keys)),), int(rng.integers(1000)))
            for _ in range(n)]


if HAVE_HYPOTHESIS:
    op_strategy = st.lists(
        st.tuples(st.sampled_from(["put", "get"]),
                  st.tuples(st.integers(0, 7)), st.integers(0, 999)),
        max_size=80)

    @settings(max_examples=60, deadline=None)
    @given(ops=op_strategy, capacity=st.integers(1, 6))
    def test_lru_matches_reference_model(ops, capacity):
        _check_lru_matches_model(ops, capacity)
else:
    @pytest.mark.parametrize("seed", range(30))
    def test_lru_matches_reference_model(seed):
        _check_lru_matches_model(_ops_from_seed(seed), 1 + seed % 6)


def test_lru_hit_after_put_and_eviction_order():
    """Explicit sanity on top of the model check: hit-after-put, LRU victim
    selection, and get-refreshes-recency."""
    from collections import OrderedDict
    c = OrderedDict()
    _lru_put(c, ("a",), 1, max_entries=2)
    assert _lru_get(c, ("a",)) == 1          # hit after put
    _lru_put(c, ("b",), 2, max_entries=2)
    assert _lru_get(c, ("a",)) == 1          # refresh "a"
    _lru_put(c, ("c",), 3, max_entries=2)    # evicts "b" (LRU), not "a"
    assert _lru_get(c, ("b",)) is None
    assert _lru_get(c, ("a",)) == 1
    assert _lru_get(c, ("c",)) == 3


# -- signature invalidation / stats --------------------------------------------

def _check_add_invalidates(seed):
    sess = Session()
    g = build_inception_like(n_blocks=1 + seed % 3, width=2 + seed % 3,
                             with_payloads=False, seed=seed)
    sig1 = graph_signature(g)
    sess.plan(g)
    assert sess.cache_stats()["plan_misses"] >= 1
    g.add(f"extra{seed}", OpKind.ELEMENTWISE, [0])
    assert graph_signature(g) != sig1
    before_hits = sess.cache_stats()["plan_hits"]
    sess.plan(g)  # must NOT hit the stale pre-mutation entry
    assert sess.cache_stats()["plan_hits"] == before_hits


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_add_invalidates_signature_and_plan_cache(seed):
        _check_add_invalidates(seed)
else:
    @pytest.mark.parametrize("seed", range(10))
    def test_add_invalidates_signature_and_plan_cache(seed):
        _check_add_invalidates(seed)


def test_add_drops_hydrated_calibration():
    sess = Session()
    g = build_inception_like(n_blocks=2, width=2)
    inputs = {0: jnp.ones((8, 64), jnp.float32)}
    sess.calibrate(g, inputs, repeats=1)
    assert g.calibration_fp is not None
    g.add("extra", OpKind.ELEMENTWISE, [0])
    assert g.calibration_fp is None
    assert all(n.cost.measured_us is None for n in g)


def test_clear_caches_zeroes_stats_and_entries():
    sess = Session()
    g = build_inception_like(n_blocks=2, width=2)
    sess.plan(g)
    sess.optimize(g)
    sess.calibrate(g, {0: jnp.ones((8, 64), jnp.float32)}, repeats=1)
    stats = sess.cache_stats()
    assert stats["plan_misses"] and stats["exec_misses"] \
        and stats["calib_misses"]
    sess.clear_caches()
    assert all(v == 0 for v in sess.cache_stats().values())


# -- calibration-cache keying --------------------------------------------------

def test_calibration_key_distinguishes_input_geometry():
    g = OpGraph("g")
    a = g.add("x", OpKind.INPUT)
    g.add("y", OpKind.ELEMENTWISE, [a], fn=lambda v: v * 2)
    k1 = calibration_key(g, {a: jnp.ones((4, 8), jnp.float32)})
    k2 = calibration_key(g, {a: jnp.ones((8, 8), jnp.float32)})
    k3 = calibration_key(g, {a: jnp.ones((4, 8), jnp.bfloat16)})
    assert len({k1, k2, k3}) == 3
    # same geometry, different values → same key (timings are value-blind)
    k4 = calibration_key(g, {a: jnp.zeros((4, 8), jnp.float32)})
    assert k4 == k1


def test_calibration_cache_evicts_lru():
    sess = Session(SessionConfig(cache_size=2))
    g = build_inception_like(n_blocks=1, width=2)
    shapes = [(4, 64), (8, 64), (16, 64)]
    for s in shapes:
        sess.calibrate(g, {0: jnp.ones(s, jnp.float32)}, repeats=1)
    assert sess.cache_stats()["calib_entries"] == 2
    # oldest geometry was evicted → re-calibrating it misses the memory LRU
    # (load=False pins the check to the in-memory tier; with the disk tier
    # enabled the eviction would instead resolve as a calib_disk_hit)
    misses = sess.cache_stats()["calib_misses"]
    sess.calibrate(g, {0: jnp.ones(shapes[0], jnp.float32)}, repeats=1,
                   load=False)
    assert sess.cache_stats()["calib_misses"] == misses + 1
    # most-recent geometry is still warm
    hits = sess.cache_stats()["calib_hits"]
    sess.calibrate(g, {0: jnp.ones(shapes[2], jnp.float32)}, repeats=1)
    assert sess.cache_stats()["calib_hits"] == hits + 1


def test_profile_table_is_detachable_and_reappliable():
    from repro.core import apply_profile, detach_profile
    sess = Session()
    g = build_inception_like(n_blocks=1, width=2)
    sess.calibrate(g, {0: jnp.ones((8, 64), jnp.float32)}, repeats=1)
    table = detach_profile(g)
    assert isinstance(table, ProfileTable)
    assert g.calibration_fp is None
    assert all(n.cost.measured_us is None for n in g)
    apply_profile(g, table)
    assert g.calibration_fp == table.fingerprint
    assert dict(table.measured_us) == {
        n.op_id: n.cost.measured_us for n in g
        if n.cost.measured_us is not None}
