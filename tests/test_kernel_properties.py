"""Hypothesis property sweeps over kernel shape space (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 4),
       m=st.sampled_from([8, 16, 24]),
       k=st.sampled_from([128, 256]),
       f=st.sampled_from([128, 256]),
       seed=st.integers(0, 100))
def test_branch_gemm_property(n, m, k, f, seed):
    from repro.kernels.branch_gemm.ops import branch_gemm
    from repro.kernels.branch_gemm.ref import branch_gemm_ref
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, m, k)) * 0.1, jnp.float32)
    w = jnp.asarray(rng.standard_normal((n, k, f)) * 0.1, jnp.float32)
    np.testing.assert_allclose(np.asarray(branch_gemm(x, w)),
                               np.asarray(branch_gemm_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([64, 128]),
       h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]),
       d=st.sampled_from([16, 32]),
       window=st.sampled_from([0, 17, 40]),
       seed=st.integers(0, 100))
def test_flash_attention_property(s, h, g, d, window, seed):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    rng = np.random.default_rng(seed)
    kvh = h // g if h % g == 0 else h
    q = jnp.asarray(rng.standard_normal((1, kvh * g, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, kvh, s, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, kvh, s, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window, bq=32, bk=64)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([128, 256, 384]),
       kvh=st.sampled_from([1, 2]),
       d=st.sampled_from([16, 32]),
       seed=st.integers(0, 100))
def test_decode_attention_property(t, kvh, d, seed):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((2, kvh * 2, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, kvh, t, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, kvh, t, d)), jnp.float32)
    lens = rng.integers(1, t, size=2)
    valid = jnp.asarray(np.arange(t)[None] < lens[:, None])
    np.testing.assert_allclose(
        np.asarray(decode_attention(q, k, v, valid, bk=128)),
        np.asarray(decode_attention_ref(q, k, v, valid)),
        rtol=2e-3, atol=2e-3)
