"""MoE dispatch: sort-based path ≡ dense one-hot oracle; capacity; routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.ffn import init_moe, moe_ffn_dense, moe_ffn_sort, route


def _cfg(n_experts=8, top_k=2, cf=2.0, aux_free=False):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=16,
                      n_shared=1, capacity_factor=cf,
                      router_aux_free=aux_free),
        dtype=jnp.float32)


@pytest.mark.parametrize("aux_free", [False, True])
@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_sort_equals_dense_dispatch(top_k, aux_free):
    """The production sort-based dispatch must match the one-hot oracle."""
    cfg = _cfg(top_k=top_k, cf=8.0, aux_free=aux_free)  # cf big → no drops
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32), jnp.float32)
    y1, aux1 = moe_ffn_dense(p, x, cfg)
    y2, aux2 = moe_ffn_sort(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(aux1["load"]),
                               np.asarray(aux2["load"]), rtol=1e-6, atol=1e-6)


def test_capacity_drops_tokens():
    """With tiny capacity the two paths still agree and outputs shrink."""
    cfg_small = _cfg(cf=0.25)
    p = init_moe(jax.random.key(0), cfg_small)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)
    y_small, _ = moe_ffn_dense(p, x, cfg_small)
    y_sort, _ = moe_ffn_sort(p, x, cfg_small)
    np.testing.assert_allclose(np.asarray(y_small), np.asarray(y_sort),
                               rtol=1e-4, atol=1e-4)
    cfg_big = _cfg(cf=8.0)
    y_big, _ = moe_ffn_dense(p, x, cfg_big)
    # dropping must change (reduce) routed mass for some tokens
    assert float(jnp.abs(y_big - y_small).max()) > 1e-6


def test_router_weights_normalized():
    cfg = _cfg(top_k=4)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (16, 32), jnp.float32)
    w, idx, aux = route(p["router"], x, cfg.moe)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), np.ones(16),
                               rtol=1e-5, atol=1e-5)
    # top-k indices distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == len(row)
    assert float(aux["aux_loss"]) > 0


def test_aux_free_bias_steers_routing():
    """DeepSeek-V3 aux-free balancing: raising one expert's bias must
    attract more tokens to it without changing combine weights' source."""
    cfg = _cfg(aux_free=True, top_k=1, n_experts=4)
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (64, 32), jnp.float32)
    _, idx0, _ = route(p["router"], x, cfg.moe)
    count0 = int((idx0 == 0).sum())
    p["router"]["bias"] = p["router"]["bias"].at[0].add(10.0)
    _, idx1, _ = route(p["router"], x, cfg.moe)
    count1 = int((idx1 == 0).sum())
    assert count1 > count0


def test_moe_grads_flow():
    cfg = _cfg()
    p = init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 32), jnp.float32)

    def loss(p):
        y, _ = moe_ffn_sort(p, x, cfg)
        return (y ** 2).mean()

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(l).sum()) for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
