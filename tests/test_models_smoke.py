"""Per-architecture smoke tests: reduced same-family config, one forward /
train / decode step on CPU, asserting output shapes and no NaNs — as
mandated by the assignment.  One test per assigned architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import make_model

B, S = 2, 16


def _batch(cfg):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones(
            (B, cfg.frontend.n_tokens, cfg.frontend.feat_dim), cfg.dtype)
    if cfg.family == "vlm":
        batch["extra_embeds"] = jnp.ones(
            (B, cfg.frontend.n_tokens, cfg.frontend.feat_dim), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)

    # train step objective
    loss, metrics = model.loss(params, batch, jax.random.key(1))
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0

    # prefill + one decode step
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    extra = cfg.frontend.n_tokens if cfg.family == "vlm" else 0
    cache_len = S + 8 + cfg.meta_tokens + extra
    logits, caches = model.prefill(params, inputs, cache_len=cache_len)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite prefill logits"

    pos = jnp.full((B,), S + extra, jnp.int32)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, caches2 = model.decode(params, tok, caches, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: non-finite decode logits"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-0.5b", "rwkv6-1.6b"])
def test_decode_matches_teacher_forcing(arch):
    """Decoding token-by-token must reproduce the teacher-forced logits."""
    from repro.models import transformer as tf
    cfg = get_config(arch, smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(2), (1, 8), 0, cfg.vocab_size)

    # teacher-forced full forward
    full_logits, _, _ = tf.lm_forward(params, toks, cfg)

    # prefill on the first 4, then decode the rest one at a time
    cache_len = 16 + cfg.meta_tokens
    logits, caches = model.prefill(params, {"tokens": toks[:, :4]},
                                   cache_len=cache_len)
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(full_logits[0, 3]),
                               rtol=2e-2, atol=2e-2)
    for i in range(4, 8):
        pos = jnp.asarray([i], jnp.int32)
        logits, caches = model.decode(params, toks[:, i], caches, pos)
        np.testing.assert_allclose(np.asarray(logits[0]),
                                   np.asarray(full_logits[0, i]),
                                   rtol=2e-2, atol=2e-2,
                                   err_msg=f"{arch} step {i}")


def test_param_count_matches_analytic():
    """ModelConfig.n_params() within 15% of the real initialized count."""
    from repro.utils.tree import tree_param_count
    for arch in ("llama3.2-1b", "qwen2-0.5b", "glm4-9b"):
        cfg = get_config(arch, smoke=True)
        params = make_model(cfg).init(jax.random.key(0))
        real = tree_param_count(params)
        est = cfg.n_params()
        assert abs(real - est) / real < 0.15, (arch, real, est)


def test_moe_active_params_smaller_than_total():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.n_active_params() < cfg.n_params() / 5
    # sanity: the real K2 is ~1T total / ~32B active
    assert 0.6e12 < cfg.n_params() < 1.6e12
    assert 15e9 < cfg.n_active_params() < 60e9
