"""Checkpointer: roundtrip, async, atomicity, GC, resume semantics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointSpec, latest_step


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
              "d": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_including_bf16(tmp_path):
    ck = Checkpointer(CheckpointSpec(str(tmp_path)))
    tree = _tree()
    ck.save(3, tree, blocking=True)
    assert latest_step(str(tmp_path)) == 3
    got = ck.restore(3, tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == np.asarray(b).dtype or str(a.dtype) == str(b.dtype)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(CheckpointSpec(str(tmp_path)))
    ck.save(1, _tree())          # returns immediately
    ck.wait()
    assert latest_step(str(tmp_path)) == 1


def test_gc_keeps_newest(tmp_path):
    ck = Checkpointer(CheckpointSpec(str(tmp_path), keep=2))
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), blocking=True)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004"]


def test_no_tmp_dirs_after_save(tmp_path):
    ck = Checkpointer(CheckpointSpec(str(tmp_path)))
    ck.save(5, _tree(), blocking=True)
    assert not [n for n in os.listdir(tmp_path) if ".tmp" in n]


def test_latest_step_empty(tmp_path):
    assert latest_step(str(tmp_path / "nope")) is None


def test_restore_onto_current_devices(tmp_path):
    """Cross-topology restore: shardings argument re-places arrays (single
    device here; the multi-device path is the same device_put call)."""
    ck = Checkpointer(CheckpointSpec(str(tmp_path)))
    tree = _tree()
    ck.save(1, tree, blocking=True)
    dev = jax.devices()[0]
    shardings = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), tree)
    got = ck.restore(1, tree, shardings)
    assert all(l.devices() == {dev} for l in jax.tree_util.tree_leaves(got))
